"""Arch registry: published param counts, reduced configs, shape rules."""
import pytest

from repro.configs import (ARCH_IDS, REGISTRY, SHAPES, get_config,
                           reduced_config, shape_applicable)

# published sizes (B params); tolerance covers counting conventions.
# internvl2-1b's published 0.94B INCLUDES the ~0.3B InternViT frontend,
# which is a stub here (assignment: backbone only) -> LM-only expectation.
PUBLISHED = {
    "whisper-small": 0.244, "zamba2-7b": 7.0, "mistral-nemo-12b": 12.2,
    "yi-34b": 34.4, "granite-8b": 8.1, "command-r-35b": 35.0,
    "llama4-scout-17b-a16e": 109.0, "grok-1-314b": 314.0,
    "rwkv6-1.6b": 1.6, "internvl2-1b": 0.50,
}
ACTIVE = {"llama4-scout-17b-a16e": 17.0, "grok-1-314b": 86.0}


def test_ten_archs():
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.total_params() / 1e9
    assert abs(n - PUBLISHED[arch]) / PUBLISHED[arch] < 0.25, (arch, n)
    if arch in ACTIVE:
        na = cfg.active_params() / 1e9
        assert abs(na - ACTIVE[arch]) / ACTIVE[arch] < 0.15, (arch, na)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_preserves_structure(arch):
    full, red = get_config(arch), reduced_config(get_config(arch))
    assert red.family == full.family
    assert (red.n_experts > 0) == (full.n_experts > 0)
    assert red.rwkv == full.rwkv
    assert (red.attn_every > 0) == (full.attn_every > 0)
    assert (red.n_enc_layers > 0) == (full.n_enc_layers > 0)
    assert red.total_params() < 20e6


def test_long_500k_applicability():
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"zamba2-7b", "rwkv6-1.6b", "llama4-scout-17b-a16e"}


def test_shapes():
    assert SHAPES["train_4k"].tokens_per_step == 256 * 4096
    assert SHAPES["decode_32k"].tokens_per_step == 128
    assert SHAPES["long_500k"].seq_len == 524288
