"""gspmd_pp stacked-pipeline correctness (subprocess; see test_pipeline.py)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RunConfig, ShapeConfig, get_config, reduced_config
from repro.core import pipeline_gspmd as gpp
from repro.models.api import build_model
from repro.optim import adamw


def check(arch):
    full = get_config(arch)
    cfg = dataclasses.replace(reduced_config(full), n_layers=8)
    seq = 64 if cfg.attention == "chunked_local" else 32
    shape = ShapeConfig("t", seq_len=seq, global_batch=8, kind="train")
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False, microbatches=4)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    oc = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, schedule="const",
                           weight_decay=0.0)
    built = gpp.make_gspmd_pp_train_step(cfg, shape, rcfg, mesh, oc)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    pp = built["to_pipeline"](params)
    opt = adamw.init(pp)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, seq), 0,
                                          cfg.vocab_size)}
    with mesh:
        j = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                    out_shardings=built["out_shardings"])
        newpp, _, metrics = j(pp, opt, batch)

    def ref_loss(p, b):
        toks = b["tokens"].reshape(4, 2, seq)
        return jnp.mean(jax.vmap(
            lambda t: model.loss(p, {"tokens": t})[0])(toks))

    rl, rg = jax.value_and_grad(ref_loss)(params, batch)
    lerr = abs(float(metrics["loss"]) - float(rl))
    newp = built["from_pipeline"](jax.device_get(newpp))
    rnew, _, _ = adamw.update(oc, rg, adamw.init(params), params)
    perr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(rnew)))
    print(f"[gpp_check] {arch} loss_err={lerr:.2e} param_err={perr:.2e}")
    assert lerr < 3e-4 and perr < 2.5e-3


if __name__ == "__main__":
    archs = sys.argv[1].split(",") if len(sys.argv) > 1 else ["grok-1-314b"]
    for a in archs:
        check(a)
    print("[gpp_check] OK")
