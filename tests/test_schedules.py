"""Schedule tables (paper Fig. 3): tick math + dataflow invariants."""
from hypothesis import given, settings, strategies as st

from repro.core import schedules as S


@given(st.integers(2, 8), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_dataflow_invariants(s, m):
    S.verify_dataflow(S.gpipe_table(s, m), s, m, "gpipe")
    S.verify_dataflow(S.hybrid_table(s, m), s, m, "hybrid")


@given(st.integers(2, 8), st.integers(1, 12))
@settings(max_examples=40, deadline=None)
def test_tick_counts(s, m):
    assert len(S.gpipe_table(s, m)) == 2 * (m + s - 1)
    assert len(S.hybrid_table(s, m)) == m + 2 * s - 2


def test_paper_fig3_two_stage_equivalence():
    """Paper: hybrid is 'essentially equivalent to GPipe efficiency-wise for
    2 stages, bubble spread out in the backward pass'."""
    s, m = 2, 8
    g = S.schedule_stats(S.gpipe_table(s, m), s, m)
    h = S.schedule_stats(S.hybrid_table(s, m), s, m)
    # same total work
    assert g["busy_units"] == h["busy_units"] == 3 * m * s
    # equivalent wall time within one tick's work
    assert abs(g["wall_units"] - h["wall_units"]) <= 3.0
    # hybrid uses strictly fewer ticks (the fused F+B saves the loss tick)
    assert len(S.hybrid_table(s, m)) < len(S.gpipe_table(s, m))


def test_last_stage_always_fused():
    t = S.hybrid_table(4, 6)
    for tk in t:
        assert tk.stage_ops[-1] in (S.FUSED, S.IDLE)
