"""Thermal monitor + mitigation policies + fault plan (paper §4.2/§5.2)."""
import jax

from repro.core.partition import split_blocks
from repro.hw.specs import IPHONE_11_PRO, IPHONE_16, XEON_E3_1225V3
from repro.runtime.elastic import DutyCyclePolicy, RebalancePolicy, SwapPolicy
from repro.runtime.faults import FaultPlan, WorkerFailure
from repro.runtime.monitor import ThermalMonitor, ThermalState


def _heat(mon, worker, base, curve):
    for x in curve:
        mon.observe(worker, base * x)


def test_thermal_states_paper_curve():
    """Paper Fig. 6: Minimal -> Fair (~batch 13) -> Serious (~batch 17)."""
    mon = ThermalMonitor(alpha=0.5, calibration_steps=3, warmup_skip=1)
    curve = [1.15] + [1.0] * 10 + [1.03] * 4 + [1.10] * 6
    _heat(mon, "iphone", 15.3, curve)
    hist = mon.workers["iphone"].state_history
    assert hist[5] == ThermalState.MINIMAL
    assert ThermalState.FAIR in hist
    assert mon.workers["iphone"].state in (ThermalState.SERIOUS,
                                           ThermalState.CRITICAL)


def test_swap_policy():
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    pol = SwapPolicy(spares=["spare0"])
    _heat(mon, "w0", 1.0, [1.0, 1.0, 1.30, 1.30])
    acts = pol.step(mon)
    assert acts and acts[0].kind == "swap"
    assert acts[0].detail["replacement"] == "spare0"
    assert "w0" in pol.cooling and not pol.spares


def test_duty_cycle_policy():
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    _heat(mon, "w0", 1.0, [1.0, 1.0, 1.10])
    acts = DutyCyclePolicy().step(mon)
    assert acts and acts[0].kind == "duty_cycle"
    assert acts[0].detail["duty"] < 1.0


def test_rebalance_policy_moves_cut():
    """A throttled worker must get FEWER layers after rebalance — the
    paper's split-point search rerun online (calibrated device rates)."""
    from repro.core.calibrate import calibrated_profiles, resnet_costs
    costs = resnet_costs()
    profs = calibrated_profiles()
    pol = RebalancePolicy(costs, [profs["xeon"], profs["iphone16"]],
                          efficiency=1.0)
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    _heat(mon, "host", 1.0, [1.0, 1.0])
    _heat(mon, "phone", 1.0, [1.0, 1.0])
    a0 = pol.step(mon, ["host", "phone"])
    assert a0 and a0[0].kind == "rebalance"
    cut0 = a0[0].detail["cuts"][0]
    _heat(mon, "phone", 1.0, [2.5, 2.5, 2.5, 2.5])   # phone throttles hard
    a1 = pol.step(mon, ["host", "phone"])
    assert a1, "expected a re-split"
    assert a1[0].detail["cuts"][0] > cut0            # phone's share shrank


def test_fault_plan():
    fp = FaultPlan(fail_at={3: "w0"}, throttle={"w0": (0, 1.5, 2)})
    fp.check(2)
    try:
        fp.check(3)
        assert False
    except WorkerFailure as e:
        assert e.worker == "w0"
    assert fp.slowdown("w0", 0) == 1.0
    assert 1.4 < fp.slowdown("w0", 50) <= 1.5
