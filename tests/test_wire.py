"""Wire codec (paper Fig. 2): roundtrip + integrity properties."""
import io

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.wire import codec

DTYPES = [np.float32, np.float64, np.float16, np.int8, np.int32, np.int64,
          np.uint8, np.uint16, np.bool_]


@given(st.integers(0, len(DTYPES) - 1),
       st.lists(st.integers(0, 7), min_size=0, max_size=4),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_tensor_roundtrip(dti, shape, seed):
    rng = np.random.default_rng(seed)
    dt = DTYPES[dti]
    arr = (rng.standard_normal(shape) * 10).astype(dt)
    buf = io.BytesIO()
    codec.encode_tensor(arr, buf)
    buf.seek(0)
    out = codec.decode_tensor(buf)
    np.testing.assert_array_equal(arr, out)
    assert out.dtype == arr.dtype


def test_bfloat16_roundtrip():
    import ml_dtypes
    arr = np.arange(-8, 8, 0.5, dtype=np.float32).astype(ml_dtypes.bfloat16)
    out = codec.loads(codec.dumps({"x": arr}))
    np.testing.assert_array_equal(arr.view(np.uint16), out["x"].view(np.uint16))


@given(st.recursive(
    st.just(None) | st.integers(0, 3).map(
        lambda s: np.arange(max(s, 1), dtype=np.float32)),
    lambda inner: st.lists(inner, max_size=3).map(tuple)
    | st.dictionaries(st.sampled_from("abcd"), inner, max_size=3),
    max_leaves=8))
@settings(max_examples=30, deadline=None)
def test_pytree_roundtrip(tree):
    out = codec.loads(codec.dumps(tree))
    import jax
    l1, d1 = jax.tree.flatten(tree)
    l2, d2 = jax.tree.flatten(out)
    assert d1 == d2
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(20, 300), st.integers(0, 255))
@settings(max_examples=25, deadline=None)
def test_corruption_detected(pos, val):
    data = bytearray(codec.dumps({"a": np.arange(64, dtype=np.float32)}))
    pos = min(pos, len(data) - 5)
    if data[pos] == val:
        val = (val + 1) % 256
    data[pos] = val
    with pytest.raises(codec.WireError):
        codec.loads(bytes(data))


def test_truncation_detected():
    data = codec.dumps({"a": np.arange(64, dtype=np.float32)})
    with pytest.raises(codec.WireError):
        codec.loads(data[:-6])


# ---------------------------------------------------------------------------
# boundary-activation frames (the pipeline-split serving plane)
# ---------------------------------------------------------------------------
def test_bf16_boundary_activation_frame_roundtrip():
    """A (B, 1, D) bf16 decode-step boundary frame — what pipeline-split
    decode ships every step — must round-trip BIT-exactly (bf16 rides the
    wire as its uint16 pattern; any value change would break the
    token-identity contract), with a small fixed framing overhead."""
    import ml_dtypes
    rng = np.random.default_rng(0)
    bf16 = np.dtype(ml_dtypes.bfloat16)
    for shape in [(3, 1, 256), (1, 48, 256)]:    # decode + prefill frames
        arr = rng.standard_normal(shape).astype(np.float32).astype(bf16)
        data = codec.dumps(arr)
        out = codec.loads(data)
        assert out.dtype == bf16
        np.testing.assert_array_equal(arr.view(np.uint16),
                                      out.view(np.uint16))
        raw = arr.size * 2
        assert raw < len(data) < raw + 256       # header + dims + CRC only


def test_bf16_tensor_frame_crc_covers_payload():
    import io

    import ml_dtypes
    arr = np.ones((4, 1, 8), np.dtype(ml_dtypes.bfloat16))
    buf = io.BytesIO()
    codec.encode_tensor(arr, buf)
    data = bytearray(buf.getvalue())
    data[-6] ^= 0x40                             # flip a payload bit
    with pytest.raises(codec.WireError, match="CRC"):
        codec.decode_tensor(io.BytesIO(bytes(data)))
