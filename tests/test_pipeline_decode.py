"""Pipeline-split decode: stage models, PipelineEngine, fleet StageGroup."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.hw.specs import DeviceProfile
from repro.models.api import (build_model, param_bytes, split_stage_params,
                              stage_eligible, stage_model)
from repro.runtime.elastic import ServingElasticPolicy
from repro.serving.engine import ServeEngine
from repro.serving.fleet import (ServingFleet, StageGroup, ThrottleTrace,
                                 WorkerSpec, drive_sim)
from repro.serving.pipeline_decode import (PipelineEngine,
                                           boundary_frame_bytes,
                                           plan_decode_split)
from repro.serving.sampling import SamplingParams

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)
MAX_LEN = 48


@pytest.fixture(scope="module")
def lm4():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=4)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


def _traffic(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=6 + i) for i in range(n)]
    samplings = [SamplingParams(temperature=2.0, top_k=16, seed=700 + i)
                 if i % 2 else None for i in range(n)]
    return prompts, samplings


def _reference(model, params, prompts, samplings, max_new=8):
    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=MAX_LEN)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=max_new, sampling=sp)
    return {r.rid: r.out_tokens for r in ref.run_until_drained()}


def _profile(name, rate=20.0, link=1e6, mem=1e12, **kw):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=mem,
                         mem_bw=1e9, link_bw=link, decode_steps_per_s=rate,
                         prefill_tokens_per_s=1e5, **kw)


# ---------------------------------------------------------------------------
# stage execution hooks
# ---------------------------------------------------------------------------
def test_stage_composition_matches_full_model(lm4):
    """Layer-sliced stages composed through the boundary hidden must be
    BIT-identical to the full model — prefill logits, caches advancing,
    and decode logits — for 2 and 3 stages."""
    model, params = lm4
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    want, cache = model.prefill(params, {"tokens": toks}, MAX_LEN)
    for cuts in [(2,), (1, 3)]:
        sps = split_stage_params(model, params, cuts)
        bounds = (0,) + cuts + (model.cfg.n_layers,)
        stages = [stage_model(model, bounds[i], bounds[i + 1])
                  for i in range(len(bounds) - 1)]
        x, caches = None, []
        for i, (sm, sp) in enumerate(zip(stages, sps)):
            b = {"tokens": toks} if i == 0 else {"hidden": x}
            x, c = sm.prefill(sp, b, MAX_LEN)
            caches.append(c)
        assert jnp.array_equal(want, x), cuts
        # two decode steps stay bit-identical too
        w, full_c = want, cache
        for tok in (5, 17):
            t = jnp.asarray([[tok]], jnp.int32)
            w, full_c = model.decode_step(params, full_c, t)
            x = t
            for i, (sm, sp) in enumerate(zip(stages, sps)):
                x, caches[i] = sm.decode_step(sp, caches[i], x)
            assert jnp.array_equal(w, x), (cuts, tok)


def test_stage_eligibility_gating():
    assert stage_eligible(reduced_config(get_config("granite-8b")))
    assert stage_eligible(reduced_config(get_config("grok-1-314b")))   # moe
    for arch in ("zamba2-7b", "rwkv6-1.6b", "whisper-small"):
        cfg = reduced_config(get_config(arch))
        assert not stage_eligible(cfg), arch
        model = build_model(cfg, RCFG)
        with pytest.raises(ValueError, match="cannot be layer-split"):
            stage_model(model, 0, 1)


def test_split_stage_params_memory_accounting(lm4):
    """Each stage holds ONLY its slice (plus ends): the memory-wall
    arithmetic the split exists for.  Tied embeddings are charged on both
    ends, so the stage sum exceeds the full tree by exactly one table."""
    model, params = lm4
    sps = split_stage_params(model, params, (2,))
    total = param_bytes(params)
    embed = param_bytes(params["embed"])
    assert all(param_bytes(p) < total for p in sps)
    assert sum(param_bytes(p) for p in sps) == total + embed  # tied: 2 tables
    assert "final_ln" not in sps[0] and "blocks" in sps[0]
    b0 = jax.tree.leaves(sps[0]["blocks"])[0]
    assert b0.shape[0] == 2


def test_stage_model_stubs_and_bounds(lm4):
    model, params = lm4
    with pytest.raises(ValueError, match="bad stage range"):
        stage_model(model, 2, 2)
    sm = stage_model(model, 0, 2)
    with pytest.raises(RuntimeError, match="full model"):
        sm.init(jax.random.key(0))
    # lru-cached: same cut -> same object -> shared jitted programs
    assert stage_model(model, 0, 2) is sm


# ---------------------------------------------------------------------------
# PipelineEngine
# ---------------------------------------------------------------------------
def test_pipeline_engine_token_identical(lm4):
    model, params = lm4
    prompts, samplings = _traffic(model.cfg, 5)
    want = _reference(model, params, prompts, samplings)
    pipe = PipelineEngine(model, params, max_batch=3, max_len=MAX_LEN,
                          cuts=(2,))
    for p, sp in zip(prompts, samplings):
        pipe.submit(p, max_new=8, sampling=sp)
    got = {r.rid: r.out_tokens for r in pipe.run_until_drained()}
    assert got == want
    # every decode step shipped one real frame per boundary, and prefill
    # shipped the full-prompt hidden — all through the codec
    assert pipe.frames_sent > 0
    assert pipe.decode_frame_bytes_total > 0
    assert pipe.prefill_frame_bytes_total > 0
    assert (pipe.frame_bytes_total == pipe.decode_frame_bytes_total
            + pipe.prefill_frame_bytes_total)


def test_pipeline_engine_rejects_extra_inputs(lm4):
    model, params = lm4
    pipe = PipelineEngine(model, params, max_batch=2, max_len=MAX_LEN,
                          cuts=(2,))
    with pytest.raises(ValueError, match="extra model inputs"):
        pipe.submit(np.arange(4, dtype=np.int32), max_new=2,
                    frontend=np.zeros((2, 8), np.float32))


def test_pipeline_engine_finish_at_admission(lm4):
    model, params = lm4
    pipe = PipelineEngine(model, params, max_batch=2, max_len=MAX_LEN,
                          cuts=(2,))
    pipe.submit(np.arange(1, 5, dtype=np.int32), max_new=1)
    done = pipe.run_until_drained(max_steps=5)
    assert len(done) == 1 and len(done[0].out_tokens) == 1
    assert pipe.active() == 0


def test_recut_is_token_identical_and_charges_moved_layers(lm4):
    model, params = lm4
    prompts, samplings = _traffic(model.cfg, 5, seed=3)
    want = _reference(model, params, prompts, samplings)
    pipe = PipelineEngine(model, params, max_batch=3, max_len=MAX_LEN,
                          cuts=(1,))
    for p, sp in zip(prompts, samplings):
        pipe.submit(p, max_new=8, sampling=sp)
    for _ in range(3):
        pipe.step()
    layer_bytes = param_bytes(
        {"blocks": jax.tree.map(lambda a: a, params["blocks"])}) // 4
    moved = pipe.recut((3,))
    assert moved == 2 * layer_bytes          # layers 1 and 2 changed stage
    assert pipe.recut((3,)) == 0             # same cut: nothing to do
    assert pipe.cuts == (3,) and pipe.recuts == 1
    got = {r.rid: r.out_tokens for r in pipe.run_until_drained()}
    assert got == want


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------
def test_plan_decode_split_respects_memory_wall(lm4):
    """When the model fits NEITHER worker whole, the planner must find a
    feasible cut; when one stage's device is tighter, the cut shifts
    layers off it."""
    model, params = lm4
    total = param_bytes(params)
    devs = [_profile("a", mem=0.75 * total), _profile("b", mem=0.75 * total)]
    plan = plan_decode_split(model, params, devs, max_batch=3,
                             max_len=MAX_LEN)
    assert plan.feasible
    assert all(m <= d.mem_bytes for m, d in zip(plan.stage_mem_bytes, devs))
    assert total > max(d.mem_bytes for d in devs)   # the wall is real
    # squeeze worker b: it must end up with fewer layers
    tight = [_profile("a", mem=0.9 * total), _profile("b", mem=0.45 * total)]
    plan2 = plan_decode_split(model, params, tight, max_batch=3,
                              max_len=MAX_LEN)
    assert plan2.feasible
    assert plan2.cuts[0] >= plan.cuts[0]


def test_boundary_frame_bytes_is_real_codec_framing(lm4):
    model, _ = lm4
    raw = 3 * 1 * model.cfg.d_model * 4          # (B=3, 1, D) float32
    framed = boundary_frame_bytes(model, 3)
    assert framed > raw                          # header + dims + CRC
    assert framed < raw + 256                    # ...but only by framing


# ---------------------------------------------------------------------------
# fleet StageGroup
# ---------------------------------------------------------------------------
def test_fleet_stage_group_serves_and_charges_transfers(lm4):
    model, params = lm4
    total = param_bytes(params)
    grp = StageGroup("pair", (WorkerSpec("s0", _profile("d0",
                                                        mem=0.75 * total)),
                              WorkerSpec("s1", _profile("d1",
                                                        mem=0.75 * total))),
                     max_batch=3)
    fleet = ServingFleet(model, params, groups=[grp], max_len=MAX_LEN,
                         tick_s=0.05)
    prompts, samplings = _traffic(model.cfg, 6, seed=5)
    arrivals = np.linspace(0.0, 0.5, len(prompts))
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=8,
                                     sampling=samplings[i]))
    snap = fleet.snapshot()
    g = snap.per_group["pair"]
    assert snap.completed == len(prompts)
    assert g.completed == len(prompts)
    # transfers are NOT free: real frames crossed, and the link spent
    # simulated seconds carrying them
    assert g.frames_sent > 0 and g.frame_bytes > 0
    assert g.transfer_s > 0.0
    assert snap.transfer_bytes == g.frame_bytes
    # the split pair serves a model bigger than either member alone: the
    # full params exceed each member's mem_bytes, every stage slice fits
    eng = fleet.group("pair").engine
    assert all(total > w.profile.mem_bytes for w in grp.workers)
    for sb, w in zip(eng.stage_param_bytes, grp.workers):
        assert sb <= w.profile.mem_bytes
    want = _reference(model, params, prompts, samplings)
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want


def test_fleet_narrow_link_slows_the_group(lm4):
    """The link model must bite: the same group on a 1000x narrower link
    finishes strictly later in SIM time, with frames crossing ticks."""
    model, params = lm4
    prompts, samplings = _traffic(model.cfg, 4, seed=7)
    arrivals = np.zeros(len(prompts))

    def run(link):
        grp = StageGroup("pair", (WorkerSpec("s0", _profile("d0", link=link)),
                                  WorkerSpec("s1", _profile("d1", link=link))),
                         cuts=(2,), max_batch=4)
        fleet = ServingFleet(model, params, groups=[grp], max_len=MAX_LEN,
                             tick_s=0.05)
        drive_sim(fleet, arrivals,
                  lambda i: fleet.submit(prompts[i], max_new=8,
                                         sampling=samplings[i]))
        return fleet.snapshot()

    wide, narrow = run(1e9), run(2e4)
    assert wide.completed == narrow.completed == len(prompts)
    assert narrow.sim_t > wide.sim_t
    assert narrow.per_group["pair"].transfer_s \
        > wide.per_group["pair"].transfer_s
    # at 20 kB/s a multi-kB frame outlives the 50 ms tick: it must have
    # stayed in flight across tick boundaries
    assert narrow.per_group["pair"].link_stall_ticks > 0
    assert narrow.goodput_tokens_per_s < wide.goodput_tokens_per_s


def test_fleet_rebalance_recuts_split_token_identically(lm4):
    """A throttling stage member triggers the elastic REBALANCE action:
    the cut moves layers off the hot stage, the moved weights are charged
    over the link, and every request stays token-identical."""
    model, params = lm4
    grp = StageGroup("pair", (WorkerSpec("s0", _profile("d0")),
                              WorkerSpec("s1", _profile("d1"))),
                     cuts=(2,), max_batch=3)
    fleet = ServingFleet(model, params, groups=[grp], max_len=MAX_LEN,
                         tick_s=0.05, policy=ServingElasticPolicy(),
                         throttle=ThrottleTrace({"s1": (0.3, 6.0, 0.1)}))
    prompts, samplings = _traffic(model.cfg, 6, seed=9)
    arrivals = np.linspace(0.0, 0.5, len(prompts))
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=8,
                                     sampling=samplings[i]))
    snap = fleet.snapshot()
    g = snap.per_group["pair"]
    assert snap.completed == len(prompts)
    assert snap.recuts >= 1 and g.recuts >= 1
    assert g.cuts[0] > 2                     # layers moved OFF the hot stage
    assert g.recut_bytes > 0                 # ...and were paid for
    assert any(a.kind == "rebalance" for _, a in fleet.action_log)
    want = _reference(model, params, prompts, samplings)
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want


def test_fleet_group_routes_alongside_replica_worker(lm4):
    """A stage group is a routable unit like any worker: admissions
    balance across both, and both serve token-identically."""
    model, params = lm4
    grp = StageGroup("pair", (WorkerSpec("s0", _profile("d0")),
                              WorkerSpec("s1", _profile("d1"))),
                     cuts=(2,), max_batch=2)
    fleet = ServingFleet(model, params,
                         [WorkerSpec("solo", _profile("ds"))],
                         groups=[grp], max_len=MAX_LEN, tick_s=0.05)
    prompts, samplings = _traffic(model.cfg, 6, seed=11)
    arrivals = np.linspace(0.0, 0.4, len(prompts))
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=6,
                                     sampling=samplings[i]))
    homes = set(fleet.routed.values())
    assert homes == {"solo", "pair"}
    want = _reference(model, params, prompts, samplings, max_new=6)
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want
