"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba2_ssd import mamba2_ssd_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.rwkv6_scan import rwkv6_chunked_fwd

KEY = jax.random.key(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


@pytest.mark.parametrize("b,t,h,g,d,causal,chunk", [
    (2, 256, 4, 2, 32, True, 0),
    (1, 200, 4, 4, 64, True, 0),        # MHA + ragged T
    (2, 256, 8, 2, 64, True, 64),       # chunked-local (llama4)
    (1, 128, 2, 1, 32, False, 0),       # non-causal (whisper encoder)
    (1, 96, 6, 3, 128, True, 0),        # head_dim 128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, t, h, g, d, causal, chunk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, g, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, g, d)).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, chunk=chunk,
                              block_q=64, block_k=128, interpret=True)
    ref = kref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal=causal,
                                   chunk=chunk)
    err = float(jnp.abs(out.astype(jnp.float32) - ref).max())
    assert err < _tol(dtype), err


@given(st.integers(1, 3), st.integers(16, 160), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]), st.sampled_from([32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(b, t, h, gdiv, d):
    g = h // gdiv
    ks = jax.random.split(jax.random.key(t * h + b), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, g, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, g, d), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=64,
                              interpret=True)
    ref = kref.flash_attention_ref(q, k, v, causal=True)
    assert float(jnp.abs(out - ref).max()) < 3e-5


@pytest.mark.parametrize("b,t,h,dk,chunk", [
    (2, 128, 4, 32, 32), (1, 100, 2, 64, 64), (2, 64, 3, 16, 16)])
def test_rwkv6(b, t, h, dk, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, t, h, dk), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, dk), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, dk), jnp.float32)
    dec = -jnp.exp(jax.random.normal(ks[3], (b, t, h, dk)) * 0.5 - 1.0)
    u = 0.3 * jax.random.normal(ks[4], (h, dk))
    out = rwkv6_chunked_fwd(r, k, v, dec, u, chunk=chunk, interpret=True)
    ref, _ = kref.rwkv6_scan_ref(r, k, v, jnp.exp(dec), u)
    rel = float(jnp.abs(out - ref).max()) / (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 3e-5


@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (2, 96, 3, 32, 16, 32), (1, 64, 2, 64, 32, 16), (2, 50, 4, 16, 8, 25)])
def test_mamba2_ssd(b, t, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, t, n), jnp.float32)
    D = jnp.ones((h,))
    y, S = mamba2_ssd_fwd(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    yr, Sr = kref.mamba2_scan_ref(x, dt, A, B, C, D)
    assert float(jnp.abs(y - yr).max()) / (float(jnp.abs(yr).max()) + 1e-9) < 3e-5
    assert float(jnp.abs(S - Sr).max()) / (float(jnp.abs(Sr).max()) + 1e-9) < 3e-5


@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 128), (130, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape).astype(dtype)
    s = 1.0 + 0.1 * jax.random.normal(jax.random.key(1), (shape[-1],))
    out = rmsnorm_fwd(x, s.astype(dtype), block_rows=32, interpret=True)
    ref = kref.rmsnorm_ref(x, s.astype(dtype))
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < _tol(dtype)


@pytest.mark.parametrize("b,h,g,d,span", [(2, 4, 2, 32, 96), (1, 8, 8, 64, 64)])
def test_decode_attention(b, h, g, d, span):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    ck = jax.random.normal(ks[1], (b, span, g, d), jnp.float32)
    cv = jax.random.normal(ks[2], (b, span, g, d), jnp.float32)
    pos = jax.random.randint(ks[3], (b,), 1, span)
    valid = jnp.arange(span)[None] <= pos[:, None]
    out = decode_attention_fwd(q, ck, cv, valid, scale=d ** -0.5,
                               block_s=32, interpret=True)
    ref = kref.decode_attention_ref(q, ck, cv, valid, d ** -0.5)
    assert float(jnp.abs(out - ref).max()) < 3e-5


def test_flash_custom_vjp_grads():
    """ops.flash_attention gradient == oracle gradient."""
    from repro.kernels import ops
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    g1 = jax.grad(lambda q: ops.flash_attention(q, k, v, True, None, 0).sum())(q)
    g2 = jax.grad(lambda q: kref.flash_attention_ref(q, k, v, causal=True).sum())(q)
    assert float(jnp.abs(g1 - g2).max()) < 3e-5
