"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RunConfig, get_config, reduced_config
from repro.models.api import build_model

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


def _batch(cfg, b=2, t=64, seed=1):
    key = jax.random.key(seed)
    batch = {"tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (b, cfg.frontend_seq, cfg.d_model))
    elif cfg.frontend:
        batch = {"tokens": batch["tokens"][:, : t - cfg.frontend_seq],
                 "frontend": 0.1 * jax.random.normal(
                     key, (b, cfg.frontend_seq, cfg.d_model))}
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 96))(params, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert logits2.shape[0] == 2


@pytest.mark.parametrize("arch", ["granite-8b", "rwkv6-1.6b", "zamba2-7b"])
def test_decode_matches_prefill(arch):
    """prefill(x[:n]) + decode(x[n]) logits == prefill(x[:n+1]) logits."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 17), 0, cfg.vocab_size)
    l1, cache = model.prefill(params, {"tokens": toks[:, :16]}, 32)
    l2, _ = model.decode_step(params, cache, toks[:, 16:17])
    lfull, _ = model.prefill(params, {"tokens": toks}, 32)
    err = float(jnp.abs(l2 - lfull).max())
    assert err < 5e-4, err


def test_input_specs_cells():
    """Every (arch × shape) cell produces well-formed input specs."""
    from repro.configs import SHAPES, shape_applicable
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg, RunConfig())
        for shape in SHAPES.values():
            if not shape_applicable(cfg, shape)[0]:
                continue
            specs = model.input_specs(shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert "cache" in specs
                assert specs["tokens"].shape == (shape.global_batch, 1)
