"""Multi-device pipeline correctness check (run in a subprocess with
xla_force_host_platform_device_count set — see test_pipeline.py).

Validates THE paper claim that matters numerically: the hybrid fused-F+B
schedule and GPipe produce gradients identical to each other and to the
non-pipelined single-program reference, for every pp-eligible family.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RunConfig, ShapeConfig, get_config, reduced_config
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.optim import adamw


def check_arch(arch: str, schedule: str, seed: int = 0) -> float:
    full = get_config(arch)
    import dataclasses
    cfg = dataclasses.replace(reduced_config(full), n_layers=8)
    cfg = dataclasses.replace(cfg, arch_id=cfg.arch_id + f"-{schedule}")
    shape = ShapeConfig("t", seq_len=32 + (cfg.frontend_seq if cfg.frontend else 0),
                        global_batch=8, kind="train")
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False, schedule=schedule, microbatches=4)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((2, 4), ("data", "model"))

    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, schedule="const",
                                weight_decay=0.0)
    built = pp.make_pp_train_step(cfg, shape, rcfg, mesh, opt_cfg)
    model = build_model(cfg, rcfg)
    key = jax.random.key(seed)
    params = model.init(key)
    params_pp = built["to_pipeline"](params)
    opt_pp = adamw.init(params_pp)

    kb = jax.random.key(seed + 1)
    batch = {"tokens": jax.random.randint(
        kb, (shape.global_batch, shape.seq_len -
             (cfg.frontend_seq if cfg.frontend else 0)), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = 0.1 * jax.random.normal(
            kb, (shape.global_batch, cfg.frontend_seq, cfg.d_model))

    with mesh:
        jitted = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                         out_shardings=built["out_shardings"])
        newp_pp, _, metrics = jitted(params_pp, opt_pp, batch)
    newp = built["from_pipeline"](jax.device_get(newp_pp))

    # reference: single-program loss + same optimizer
    def ref_loss(p, b):
        return model.loss(p, b)[0]

    rloss, rgrads = jax.value_and_grad(ref_loss)(params, batch)
    ref_newp, _, _ = adamw.update(opt_cfg, rgrads, adamw.init(params), params)

    lerr = abs(float(metrics["loss"]) - float(rloss))
    perr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(newp),
                               jax.tree.leaves(ref_newp)))
    print(f"[pp_check] {arch:22s} {schedule:7s} loss_err={lerr:.2e} "
          f"param_err={perr:.2e} (loss {float(rloss):.4f})")
    assert lerr < 2e-4, (arch, schedule, lerr, float(metrics["loss"]), float(rloss))
    assert perr < 2e-3, (arch, schedule, perr)
    return perr


if __name__ == "__main__":
    archs = sys.argv[1].split(",") if len(sys.argv) > 1 else \
        ["granite-8b", "rwkv6-1.6b", "zamba2-7b", "internvl2-1b"]
    schedules = sys.argv[2].split(",") if len(sys.argv) > 2 else \
        ["gpipe", "hybrid"]
    for a in archs:
        for s in schedules:
            check_arch(a, s)
    print("[pp_check] OK")
