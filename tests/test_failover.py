"""Failure plane: heartbeats, kill traces, lane resurrection, chaos runs.

The jax-free half (FaultPlan, KillTrace, HeartbeatMonitor, SimFleet chaos)
runs anywhere — select it with ``-k sim or not jax`` in lint-tier CI.  The
jax half drives real ServingFleet engines through seeded kill traces and
holds the repo's core claim under fire: a dead worker's requests finish
**token-identically** on survivors.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.hw.specs import DeviceProfile
from repro.runtime.faults import (FaultPlan, KillEvent, KillTrace,
                                  WorkerFailure, make_kill_trace)
from repro.runtime.guard import seeded_replay_check
from repro.serving.failover import (ALIVE, DEAD, SUSPECT, FailoverConfig,
                                    HeartbeatMonitor)
from repro.serving.metrics import OUTCOME_DONE
from repro.serving.scale import ScaleWorkerSpec, SimFleet, make_rows


# ---------------------------------------------------------------------------
# fault schedule primitives (jax-free)
# ---------------------------------------------------------------------------
def test_fault_plan_check_is_nonmutating():
    """Regression: check() used to pop fail_at, so a seeded replay saw
    the failure on the first run only."""
    plan = FaultPlan(fail_at={3: "w1"})
    for _ in range(2):
        with pytest.raises(WorkerFailure) as ei:
            plan.check(3)
        assert (ei.value.worker, ei.value.step) == ("w1", 3)
    assert plan.fail_at == {3: "w1"}
    plan.check(2)                                # non-failure steps are free


def test_kill_event_validation_and_returns():
    with pytest.raises(ValueError):
        KillEvent(t_s=1.0, worker="a", kind="meteor")
    assert not KillEvent(t_s=1.0, worker="a", kind="crash").returns
    assert KillEvent(t_s=1.0, worker="a", kind="partition", down_s=0.5).returns
    assert not KillEvent(t_s=1.0, worker="a", kind="zombie",
                         down_s=math.inf).returns


def test_make_kill_trace_is_seeded_and_sorted():
    workers = ["a", "b", "c", "d"]
    t1 = make_kill_trace(workers, 3, t0_s=0.5, t1_s=4.0, seed=11,
                         kinds=("crash", "partition", "zombie"))
    t2 = make_kill_trace(workers, 3, t0_s=0.5, t1_s=4.0, seed=11,
                         kinds=("crash", "partition", "zombie"))
    assert tuple(t1) == tuple(t2) and len(t1) == 3
    times = [e.t_s for e in t1]
    assert times == sorted(times)
    assert all(0.5 <= t <= 4.0 for t in times)
    victims = [e.worker for e in t1]
    assert len(set(victims)) == 3                # distinct victims
    t3 = make_kill_trace(workers, 3, t0_s=0.5, t1_s=4.0, seed=12,
                         kinds=("crash", "partition", "zombie"))
    assert tuple(t3) != tuple(t1)
    with pytest.raises(ValueError):
        make_kill_trace(["a"], 2)

    def mk(seed):
        return [dataclasses.astuple(e)
                for e in make_kill_trace(workers, 2, seed=seed)]
    seeded_replay_check(mk, seed=5)


def test_heartbeat_monitor_thresholds():
    cfg = FailoverConfig(suspect_after=2.0, dead_after=4.0)
    hb = HeartbeatMonitor(["a", "b"], probe_every_s=0.25, cfg=cfg)
    assert hb.state("a", 0.1) == ALIVE
    assert hb.state("a", 0.6) == SUSPECT         # gap >= 2 * 0.25
    assert hb.state("a", 1.1) == DEAD            # gap >= 4 * 0.25
    hb.beat("a", 1.1)
    assert hb.state("a", 1.2) == ALIVE           # beats resurrect the state
    assert hb.state("b", 1.1) == DEAD            # independent per worker


def test_failover_config_validation():
    with pytest.raises(ValueError):
        FailoverConfig(suspect_after=4.0, dead_after=2.0)
    with pytest.raises(ValueError):
        FailoverConfig(checkpoint_every_s=0.0)


# ---------------------------------------------------------------------------
# SimFleet chaos (jax-free scale plane)
# ---------------------------------------------------------------------------
def _sim_profile(decode=10.0):
    return DeviceProfile(name="sim", year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=decode,
                         prefill_tokens_per_s=1e4,
                         thermal_sustained=0.85, thermal_tau_s=60.0)


def _sim_fleet(trace=None, n=4, **kw):
    spec = ScaleWorkerSpec(profile=_sim_profile(), max_batch=4, max_queue=32)
    kw.setdefault("tick_s", 0.05)
    kw.setdefault("admission", False)
    kw.setdefault("detect_s", 0.3)
    kw.setdefault("ckpt_every_s", 0.25)
    return SimFleet(make_rows(spec, n), kill_trace=trace, **kw)


def _sim_chaos_run(impl="vector", seed=0, n_kills=2,
                   kinds=("crash",), **kw):
    trace = make_kill_trace(list(range(3)), n_kills, t0_s=0.3, t1_s=1.2,
                            seed=seed, kinds=kinds)
    fleet = _sim_fleet(trace, impl=impl, **kw)
    rng = np.random.default_rng(seed + 100)
    for _ in range(40):
        fleet.submit(int(rng.integers(4, 30)), int(rng.integers(4, 24)))
    while not fleet.idle() and fleet.ticks < 20000:
        fleet.tick()
    return fleet


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sim_crash_loses_nothing_and_bounds_recompute(seed):
    fleet = _sim_chaos_run(seed=seed)
    snap = fleet.snapshot()
    assert not [r for r, st in enumerate(fleet.q_status) if st < 0]
    assert snap.completed == snap.offered == 40
    assert snap.deaths == 2 and snap.resurrections >= 1
    assert snap.orphaned == 0
    # redo per stranded lane is bounded by one checkpoint window of decode
    # plus a prompt re-prefill (2x slack for tick granularity)
    lanes = snap.deaths * 4
    assert 0 < snap.recompute_tokens <= lanes * (2 * 0.25 * 10.0 + 30 + 2)


def test_sim_loop_and_vector_identical_under_kills():
    a = _sim_chaos_run(impl="vector", kinds=("crash", "zombie", "partition"))
    b = _sim_chaos_run(impl="loop", kinds=("crash", "zombie", "partition"))
    sa, sb = a.snapshot(), b.snapshot()
    assert sa.deaths >= 1 and sa == sb


def test_sim_partition_heal_before_detection_is_transparent():
    trace = KillTrace(events=(
        KillEvent(t_s=0.3, worker=0, kind="partition", down_s=0.1),))
    fleet = _sim_fleet(trace, detect_s=0.5)
    for _ in range(10):
        fleet.submit(10, 12)
    while not fleet.idle() and fleet.ticks < 20000:
        fleet.tick()
    snap = fleet.snapshot()
    assert snap.completed == 10
    assert snap.deaths == 0 and snap.resurrections == 0
    assert snap.recompute_tokens == 0
    kinds = [k for _, k, _ in snap.events]
    assert "kill" in kinds and "return" in kinds and "death" not in kinds


def test_sim_zombie_returns_cold_and_serves_again():
    trace = KillTrace(events=(
        KillEvent(t_s=0.2, worker=0, kind="zombie", down_s=0.5),))
    spec = ScaleWorkerSpec(profile=_sim_profile(), max_batch=4, max_queue=32)
    fleet = SimFleet(make_rows(spec, 2), tick_s=0.05, admission=False,
                     kill_trace=trace, detect_s=0.1, ckpt_every_s=0.25,
                     warm_param_bytes=1e9)
    for _ in range(8):
        fleet.submit(10, 10)
    while fleet.sim_t < 0.75:
        fleet.tick()
    # back from the dead, but COLD: params must re-stream before serving
    assert not fleet.dead[0] and fleet.warm_rem[0] > 0.0
    while not fleet.idle() and fleet.ticks < 20000:
        fleet.tick()
    snap = fleet.snapshot()
    assert snap.completed == 8 and snap.deaths == 1


def test_sim_dead_rows_are_not_spare_capacity():
    trace = KillTrace(events=(KillEvent(t_s=0.2, worker=0, kind="crash"),))
    fleet = _sim_fleet(trace, n=4, detect_s=0.1)
    fleet.submit(10, 10)
    while fleet.sim_t < 0.5:
        fleet.tick()
    assert fleet.dead[0] and fleet.alive[0]      # dead, but NOT reusable
    assert fleet.load().spare == 0
    fleet._scale_up(4)                           # must not revive the corpse
    assert fleet.dead[0] and not fleet._serving_mask()[0]
    assert not fleet.retiring[0]


def test_sim_all_dead_blip_parks_then_recovers():
    trace = KillTrace(events=tuple(
        KillEvent(t_s=0.3, worker=w, kind="partition", down_s=2.0)
        for w in range(2)))
    fleet = _sim_fleet(trace, n=2, detect_s=0.1)
    for _ in range(6):
        fleet.submit(10, 10)
    orphan_peak = 0
    while not fleet.idle() and fleet.ticks < 20000:
        fleet.tick()
        orphan_peak = max(orphan_peak, fleet.snapshot().orphaned)
    snap = fleet.snapshot()
    assert orphan_peak > 0                       # work parked with no home
    assert snap.completed == 6 and snap.orphaned == 0
    assert all(st == OUTCOME_DONE for st in fleet.q_status)


def test_sim_chaos_run_is_seed_deterministic():
    def run(seed):
        return _sim_chaos_run(seed=seed,
                              kinds=("crash", "zombie")).snapshot()
    seeded_replay_check(run, seed=3)


# ---------------------------------------------------------------------------
# ServingFleet chaos (real engines, token-identity under fire)
# ---------------------------------------------------------------------------
RCFG = None  # set lazily, RunConfig needs no jax but keep imports grouped


@pytest.fixture(scope="module")
def small_lm():
    jax = pytest.importorskip("jax")
    from repro.configs import RunConfig, get_config, reduced_config
    from repro.models.api import build_model
    cfg = dataclasses.replace(
        reduced_config(get_config("granite-8b")), n_layers=2)
    model = build_model(cfg, RunConfig(param_dtype="float32",
                                       compute_dtype="float32", remat=False))
    return model, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def small_rnn():
    jax = pytest.importorskip("jax")
    from repro.configs import RunConfig, get_config, reduced_config
    from repro.models.api import build_model
    cfg = reduced_config(get_config("rwkv6-1.6b"))
    model = build_model(cfg, RunConfig(param_dtype="float32",
                                       compute_dtype="float32", remat=False))
    return model, model.init(jax.random.key(1))


def _profile(name, rate=20.0):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=rate,
                         prefill_tokens_per_s=1e9)


def _engine_config(backend):
    from repro.serving.engine import EngineConfig
    if backend == "paged":
        return EngineConfig(kv_blocks=48, kv_block_size=4)
    return None                                  # dense / recurrent: automatic


def _traffic(cfg, n, seed=0):
    from repro.serving.sampling import SamplingParams
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=6 + i).astype(np.int32)
               for i in range(n)]
    samplings = [SamplingParams(temperature=2.0, top_k=32, seed=300 + i)
                 if i % 2 else None for i in range(n)]
    return prompts, samplings


def _reference(model, params, prompts, samplings, max_new=8, backend=None):
    from repro.serving.engine import ServeEngine
    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=48,
                      config=_engine_config(backend))
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=max_new, sampling=sp)
    return {r.rid: r.out_tokens for r in ref.run_until_drained()}


def _chaos_fleet(model, params, trace, *, names=("a", "b"), backend=None,
                 failover=None):
    from repro.serving.fleet import ServingFleet, WorkerSpec
    workers = [WorkerSpec(n, _profile(f"dev-{n}"), max_batch=4,
                          engine_config=_engine_config(backend))
               for n in names]
    return ServingFleet(model, params, workers, max_len=48, tick_s=0.05,
                        kill_trace=trace, failover=failover)


def _drive(fleet, prompts, samplings, max_new=8):
    from repro.serving.fleet import drive_sim
    arrivals = np.linspace(0.0, 0.3, len(prompts))
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=max_new,
                                     sampling=samplings[i]))


@pytest.mark.parametrize("backend", ["dense", "paged", "recurrent"])
@pytest.mark.parametrize("seed", [3, 4])
def test_fleet_kill_is_token_identical(small_lm, small_rnn, backend, seed):
    """The tentpole claim: kill a worker mid-decode and every request
    still completes with EXACTLY the tokens an unkilled engine produces —
    for dense, paged and recurrent cache layouts."""
    model, params = small_rnn if backend == "recurrent" else small_lm
    prompts, samplings = _traffic(model.cfg, 6, seed=seed)
    trace = make_kill_trace(["b"], 1, t0_s=0.4, t1_s=0.6, seed=seed)
    fleet = _chaos_fleet(model, params, trace, backend=backend)
    _drive(fleet, prompts, samplings)

    snap = fleet.snapshot()
    assert snap.completed == len(prompts)        # zero lost requests
    assert snap.deaths == 1 and snap.dead_units == ("b",)
    assert snap.resurrections >= 1 and snap.orphaned == 0
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    want = _reference(model, params, prompts, samplings, backend=backend)
    assert got == want                           # token-identical under fire
    # no KV leak anywhere, dead engine included: forget_lane must have
    # released every block the stranded lanes held
    for name in ("a", "b"):
        eng = fleet.worker(name).engine
        if hasattr(eng.backend, "blocks"):
            assert eng.backend.blocks.in_use == 0


def test_fleet_two_deaths_still_drains(small_lm):
    model, params = small_lm
    prompts, samplings = _traffic(model.cfg, 6, seed=9)
    trace = make_kill_trace(["b", "c"], 2, t0_s=0.4, t1_s=0.9, seed=1)
    fleet = _chaos_fleet(model, params, trace, names=("a", "b", "c"))
    _drive(fleet, prompts, samplings)
    snap = fleet.snapshot()
    assert snap.completed == len(prompts)
    assert snap.deaths == 2 and set(snap.dead_units) == {"b", "c"}
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == _reference(model, params, prompts, samplings)


def test_fleet_partition_blip_is_transparent(small_lm):
    """A partition that heals inside the dead_after window is a blip:
    no death, no resurrection, and still token-identical."""
    model, params = small_lm
    prompts, samplings = _traffic(model.cfg, 4, seed=5)
    trace = KillTrace(events=(
        KillEvent(t_s=0.4, worker="b", kind="partition", down_s=0.3),))
    fleet = _chaos_fleet(model, params, trace,
                         failover=FailoverConfig(dead_after=40.0,
                                                 suspect_after=20.0))
    _drive(fleet, prompts, samplings)
    snap = fleet.snapshot()
    assert snap.completed == len(prompts)
    assert snap.deaths == 0 and snap.resurrections == 0
    assert snap.dead_units == ()
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == _reference(model, params, prompts, samplings)


def test_fleet_resurrection_rides_the_prefix_cache(small_lm):
    """Satellite: when the survivor's prefix cache already holds the dead
    lane's prompt, restart-from-scratch resurrection skips the re-prefill
    (prefill_skipped ticks up, recompute shrinks vs a cold survivor)."""
    from repro.serving.engine import EngineConfig
    from repro.serving.fleet import ServingFleet, WorkerSpec

    model, params = small_lm
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, model.cfg.vocab_size, size=12).astype(np.int32)

    def run(prefix_cache):
        cfg = EngineConfig(kv_blocks=48, kv_block_size=4,
                           prefix_cache=prefix_cache)
        workers = [WorkerSpec(n, _profile(f"dev-{n}"), max_batch=2,
                              engine_config=cfg) for n in ("a", "b")]
        trace = KillTrace(events=(
            KillEvent(t_s=0.45, worker="b", kind="crash"),))
        # checkpoints off: the stranded lane restarts from scratch, which
        # is exactly the path the prefix cache accelerates
        fleet = ServingFleet(model, params, workers, max_len=48,
                             tick_s=0.05, kill_trace=trace,
                             failover=FailoverConfig(checkpoint_every_s=1e9))
        from repro.serving.fleet import drive_sim
        # same prompt twice: rid 0 warms a's cache, rid 1 dies on b
        drive_sim(fleet, np.array([0.0, 0.05]),
                  lambda i: fleet.submit(prompt, max_new=4 if i == 0 else 16))
        return fleet

    warm = run(prefix_cache=True)
    cold = run(prefix_cache=False)
    for fleet in (warm, cold):
        snap = fleet.snapshot()
        assert snap.completed == 2 and snap.deaths == 1
    a_warm = warm.worker("a").engine
    assert a_warm.metrics.prefill_skipped >= 1   # cached prompt, no prefill
    assert warm.recompute_tokens < cold.recompute_tokens
    got = {rec.req.rid: len(rec.req.out_tokens) for rec in warm.completed}
    assert got == {0: 4, 1: 16}


def test_forget_lane_frees_blocks_without_feeding_the_cache(small_lm):
    """A dead worker's device state is unreachable: forget_lane must
    release lanes WITHOUT registering their tokens as reusable prefixes
    (unlike preempt, which snapshots live state)."""
    from repro.serving.engine import EngineConfig, ServeEngine

    model, params = small_lm
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, model.cfg.vocab_size, size=8).astype(np.int32)
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      config=EngineConfig(kv_blocks=32, kv_block_size=4,
                                          prefix_cache=True))
    eng.submit(prompt, max_new=6)
    for _ in range(3):
        eng.step()
    assert eng.backend.blocks.in_use > 0
    req = eng.forget_lane(0)
    assert req.rid == 0 and req.preemptions == 1
    assert eng.backend.blocks.in_use == 0        # no leak
    # the prompt's admission-time registration is legitimate (computed
    # while the device was alive) — but the DECODED suffix must never
    # have been registered: that state died with the device
    full_ctx = np.concatenate([prompt, np.asarray(req.out_tokens,
                                                  np.int32)])
    assert eng.backend.cached_prefix_tokens(full_ctx) <= len(prompt)
    with pytest.raises(ValueError):
        eng.forget_lane(0)                       # already idle


def test_fleet_group_member_death_kills_the_unit(small_lm):
    """A pipeline group cannot run around a missing stage: one member's
    death strands the whole unit, and its lanes finish on the replica."""
    from repro.serving.fleet import ServingFleet, StageGroup, WorkerSpec

    model, params = small_lm
    grp = StageGroup("pair", (WorkerSpec("s0", _profile("d0")),
                              WorkerSpec("s1", _profile("d1"))),
                     cuts=(1,), max_batch=2)
    trace = KillTrace(events=(
        KillEvent(t_s=0.4, worker="s1", kind="crash"),))
    fleet = ServingFleet(model, params, [WorkerSpec("solo", _profile("ds"))],
                         groups=[grp], max_len=48, tick_s=0.05,
                         kill_trace=trace)
    prompts, samplings = _traffic(model.cfg, 6, seed=17)
    _drive(fleet, prompts, samplings)
    snap = fleet.snapshot()
    assert snap.completed == len(prompts)
    assert snap.deaths == 1 and snap.dead_units == ("pair",)
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == _reference(model, params, prompts, samplings)
    # everything that survived the kill lives on the replica worker
    assert all(rec.worker == "solo" for rec in fleet.completed
               if rec.migrated)


def test_fleet_failure_log_narrates_the_episode(small_lm):
    model, params = small_lm
    prompts, samplings = _traffic(model.cfg, 4, seed=23)
    trace = KillTrace(events=(
        KillEvent(t_s=0.4, worker="b", kind="crash"),))
    fleet = _chaos_fleet(model, params, trace)
    _drive(fleet, prompts, samplings)
    kinds = [k for _, k, _ in fleet.failure_log]
    assert kinds[0] == "kill:crash"
    assert "dead" in kinds and "resurrect" in kinds
    i_dead = kinds.index("dead")
    assert "suspect" in kinds[:i_dead]           # suspicion precedes death
    assert fleet.snapshot().checkpoints > 0
