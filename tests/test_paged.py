"""Paged KV-cache serving: block manager, model hooks, engine preemption."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.block_manager import BlockManager
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.sampling import SamplingParams

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def small_lm():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# block manager
# ---------------------------------------------------------------------------

def test_block_manager_alloc_release_watermark():
    m = BlockManager(8, block_size=4, watermark_frac=0.25)
    assert m.blocks_needed(1) == 1 and m.blocks_needed(4) == 1
    assert m.blocks_needed(5) == 2 and m.blocks_needed(0) == 1
    a = m.allocate(3)
    assert len(a) == 3 and all(1 <= b <= 8 for b in a)      # 0 is the sink
    assert m.in_use == 3 and m.free == 5 and m.peak_in_use == 3
    # watermark: 2 blocks reserved for growth -> only 3 admittable
    assert m.can_admit(3) and not m.can_admit(4)
    assert m.allocate(6) is None and m.in_use == 3          # no side effects
    b = m.allocate(5)                                       # growth ignores it
    assert len(b) == 5 and m.free == 0 and m.peak_in_use == 8
    m.release(a)
    assert m.free == 3 and m.in_use == 5
    with pytest.raises(ValueError):
        m.release([0])                                      # sink is unmanaged
    with pytest.raises(ValueError):
        m.release([a[0]])                                   # double free
    with pytest.raises(ValueError):
        m.release([b[0], b[0]])                             # dup in one call
    assert m.free == 3                                      # list untouched
    with pytest.raises(ValueError):
        BlockManager(0, 4)


def test_pool_gate_excludes_nonattention_state():
    """The pool layout is offered only where decode state is a
    position-addressed K/V cache: dense + moe.  Recurrent / enc-dec
    families advertise their own state kind instead."""
    for arch in ("granite-8b", "grok-1-314b", "llama4-scout-17b-a16e"):
        m = build_model(reduced_config(get_config(arch)), RCFG)
        if m.cfg.attention == "full":
            assert m.decode_state.poolable, arch
            assert m.decode_state.kind == "attention", arch
    for arch in ("rwkv6-1.6b", "zamba2-7b"):
        m = build_model(reduced_config(get_config(arch)), RCFG)
        assert not m.decode_state.poolable, arch
        assert m.decode_state.kind == "recurrent", arch
    m = build_model(reduced_config(get_config("whisper-small")), RCFG)
    assert not m.decode_state.poolable
    assert m.decode_state.kind == "encdec"


# ---------------------------------------------------------------------------
# model-level parity
# ---------------------------------------------------------------------------

def test_dense_vs_paged_decode_logit_parity(small_lm):
    """Same prefill pasted into a block pool must decode to the same logits
    as the dense lane cache, for several steps (gather reference path)."""
    model, params = small_lm
    cfg = model.cfg
    rng = np.random.default_rng(7)
    P, bs, max_len = 11, 4, 32
    prompt = rng.integers(0, cfg.vocab_size, size=P)
    logits, dense = model.prefill(params,
                                  {"tokens": jnp.asarray(prompt[None])},
                                  max_len)
    paged = model.decode_state.pool_init(1, 10, bs)
    blocks = [4, 2, 9]                          # deliberately out of order
    flat = np.array([blocks[i // bs] * bs + i % bs for i in range(P)])
    for kk in ("k", "v"):
        pool = paged["layers"][kk]
        nl = pool.shape[0]
        fl = pool.reshape((nl, -1) + pool.shape[3:])
        paged["layers"][kk] = fl.at[:, flat].set(
            dense["layers"][kk][:, 0, :P]).reshape(pool.shape)
    paged["pos"] = jnp.asarray([P], jnp.int32)
    bt = np.zeros((1, 8), np.int32)
    # prompt blocks + growth blocks for the decoded tokens (the engine's
    # grow-on-decode guarantees a real block exists before every write —
    # only idle lanes ever write to the sink)
    bt[0, :5] = blocks + [1, 6]
    v = cfg.vocab_size
    tok = int(jnp.argmax(logits[0, :v]))
    for _ in range(6):
        t = jnp.asarray([[tok]], jnp.int32)
        ld, dense = model.decode_step(params, dense, t)
        lp, paged = model.decode_state.pool_step(params, paged, t,
                                                 jnp.asarray(bt))
        np.testing.assert_allclose(np.asarray(ld[0, :v]),
                                   np.asarray(lp[0, :v]), atol=1e-5)
        tok = int(jnp.argmax(ld[0, :v]))


def test_paged_kernel_matches_gather_reference():
    """The Pallas paged flash-decode kernel must match the pure-jnp gather
    path (interpret mode on CPU)."""
    from repro.kernels import ops as kops
    from repro.models.attention import _repeat_kv, sdpa

    rng = np.random.default_rng(0)
    b, h, g, d, nb, bs, mb = 3, 4, 2, 16, 9, 8, 4
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, g, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, g, d)), jnp.float32)
    bt = np.zeros((b, mb), np.int32)
    bt[0, :2] = [3, 5]
    bt[1, :4] = [1, 2, 7, 4]
    bt[2, :1] = [8]
    pos = jnp.asarray([9, 30, 0], jnp.int32)    # last written position
    bt = jnp.asarray(bt)
    out = kops.paged_decode_attention(q, kp, vp, bt, pos, scale=d ** -0.5)
    span = mb * bs
    ck = kp[bt].reshape(b, span, g, d)
    cv = vp[bt].reshape(b, span, g, d)
    valid = jnp.arange(span)[None, :] <= pos[:, None]
    ref = sdpa(q, _repeat_kv(ck, h // g), _repeat_kv(cv, h // g),
               valid[:, None, None, :], d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def _run(model, params, prompts, config=None, max_batch=4, max_new=6,
         sampling=None, max_len=48):
    eng = ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                      config=config)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new=max_new,
                   sampling=sampling[i] if sampling else None)
    done = eng.run_until_drained()
    return {r.rid: r.out_tokens for r in done}, eng.metrics_snapshot()


def test_paged_engine_matches_dense_tokens(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=int(n))
               for n in (5, 9, 14, 7, 21, 3)]
    dense, _ = _run(model, params, prompts)
    paged, snap = _run(model, params, prompts,
                       EngineConfig(kv_blocks=40, kv_block_size=4))
    assert dense == paged
    assert snap.preemptions == 0
    assert snap.kv_blocks_total == 40 and snap.kv_blocks_peak > 0
    assert 0.0 < snap.kv_block_utilization <= 1.0


def test_preempt_then_resume_token_identical_greedy(small_lm):
    """A pool too small for every admitted lane to grow must preempt, and
    the preempted greedy request must resume with identical output."""
    model, params = small_lm
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=int(n))
               for n in (5, 9, 14, 7, 21, 3)]
    dense, _ = _run(model, params, prompts)
    tight, snap = _run(model, params, prompts,
                       EngineConfig(kv_blocks=9, kv_block_size=4))
    assert dense == tight
    assert snap.preemptions > 0 and snap.resumes > 0
    assert snap.completed == len(prompts)


def test_preempt_then_resume_token_identical_stochastic(small_lm):
    """Preemption freezes the per-lane PRNG counter, so a STOCHASTIC
    request also resumes on the exact sample stream it left."""
    model, params = small_lm
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=int(n))
               for n in (8, 13, 6, 17)]
    sp = [SamplingParams(temperature=8.0, top_k=64, seed=100 + i)
          for i in range(len(prompts))]
    ample, _ = _run(model, params, prompts,
                    EngineConfig(kv_blocks=64, kv_block_size=4), sampling=sp)
    tight, snap = _run(model, params, prompts,
                       EngineConfig(kv_blocks=8, kv_block_size=4),
                       sampling=sp)
    assert snap.preemptions > 0
    assert ample == tight


def test_admission_with_zero_free_blocks_waits(small_lm):
    """With every block held by a running lane, new work must stay queued
    (no crash, no drop) and admit once blocks free up."""
    model, params = small_lm
    rng = np.random.default_rng(4)
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      config=EngineConfig(kv_blocks=2, kv_block_size=8))
    first = eng.submit(rng.integers(0, model.cfg.vocab_size, size=14),
                       max_new=2)           # needs both blocks
    second = eng.submit(rng.integers(0, model.cfg.vocab_size, size=8),
                        max_new=2)
    eng._admit()
    assert eng.active() == 1                # only the first fits
    assert eng.scheduler.depth == 1 and eng.backend.blocks.free == 0
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [first, second]
    assert eng.backend.blocks.free == 2     # everything released


def test_request_larger_than_pool_is_rejected(small_lm):
    """Feasibility is judged on the FINAL footprint (prompt + max_new):
    both a too-big prompt and a short prompt that must GROW past the pool
    are rejected up front, with zero wasted decode steps."""
    model, params = small_lm
    rng = np.random.default_rng(5)
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      config=EngineConfig(kv_blocks=2, kv_block_size=4))
    big = eng.submit(rng.integers(0, model.cfg.vocab_size, size=20),
                     max_new=2)             # needs 5 blocks, pool has 2
    grow = eng.submit(rng.integers(0, model.cfg.vocab_size, size=7),
                      max_new=6)            # 7+6-1 = 12 positions: 3 blocks
    ok = eng.submit(rng.integers(0, model.cfg.vocab_size, size=6), max_new=2)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [ok]
    assert sorted(r.rid for r in eng.scheduler.rejected) == [big, grow]
    assert eng.metrics_snapshot().rejected == 2
    assert eng.metrics_snapshot().preemptions == 0


def test_preempted_request_exempt_from_deadline_expiry():
    """A requeued preemption carries tokens a client is owed; the queue
    deadline (which bounds pre-admission wait) must not expire it."""
    from repro.serving.engine import Request
    from repro.serving.scheduler import AdmissionScheduler

    sched = AdmissionScheduler()
    fresh = Request(0, np.arange(4, dtype=np.int32), submitted_t=0.0,
                    deadline_s=1.0)
    resumed = Request(1, np.arange(4, dtype=np.int32), submitted_t=0.0,
                      deadline_s=1.0, admitted_t=0.5,
                      out_tokens=[7, 8])
    sched.push(fresh, 0.0)
    sched.requeue(resumed)
    popped = sched.pop(4, now=10.0)             # both deadlines long past
    assert [r.rid for r in popped] == [1]       # resumed survives
    assert [r.rid for r in sched.expired] == [0]


def test_running_lane_growth_outranks_admission(small_lm):
    """Growth of a running lane must be served before a new admission can
    take the last free blocks — otherwise the admission pays a prefill
    only to be the LIFO preemption victim in the same step."""
    model, params = small_lm
    rng = np.random.default_rng(10)
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      config=EngineConfig(kv_blocks=3, kv_block_size=4))
    a = eng.submit(rng.integers(0, model.cfg.vocab_size, size=7), max_new=6)
    eng.step()                                  # A active on 2 blocks
    b = eng.submit(rng.integers(0, model.cfg.vocab_size, size=3), max_new=2)
    eng.step()              # A grows into the last block FIRST; B must wait
    assert eng.scheduler.depth == 1
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [a, b]
    assert eng.metrics_snapshot().preemptions == 0


def test_watermark_infeasible_request_rejected_not_livelocked(small_lm):
    """A request whose prompt blocks exceed the watermark-reduced usable
    pool can NEVER pass can_admit; it must be rejected up front instead of
    requeueing forever and head-of-line-blocking later traffic."""
    model, params = small_lm
    rng = np.random.default_rng(9)
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      config=EngineConfig(kv_blocks=4, kv_block_size=4,
                                          watermark_frac=0.3))
    big = eng.submit(rng.integers(0, model.cfg.vocab_size, size=14),
                     max_new=2)     # final 15 -> 4 blocks > usable 3
    ok = eng.submit(rng.integers(0, model.cfg.vocab_size, size=6), max_new=2)
    done = eng.run_until_drained(max_steps=200)
    assert [r.rid for r in done] == [ok]
    assert [r.rid for r in eng.scheduler.rejected] == [big]


def test_pad_id_is_inert_and_configurable(small_lm):
    """Bucketed prefill right-pads with EngineConfig.pad_id; causal masking
    makes the choice inert, so any pad id must give identical tokens."""
    model, params = small_lm
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=int(n))
               for n in (5, 9, 14)]
    base, _ = _run(model, params, prompts)
    other, _ = _run(model, params, prompts,
                    EngineConfig(pad_id=model.cfg.vocab_size - 1))
    assert base == other


def test_paged_config_on_recurrent_family_gets_recurrent_backend(small_lm):
    """Requesting paged KV for a non-pageable recurrent family no longer
    silently drops to dense lanes: it gets the pooled constant-footprint
    RecurrentBackend (and still serves correctly)."""
    from repro.serving.backends import RecurrentBackend

    cfg = reduced_config(get_config("rwkv6-1.6b"))
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(1))
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      config=EngineConfig(kv_blocks=16, kv_block_size=4))
    assert isinstance(eng.backend, RecurrentBackend)
    assert eng.backend.token_footprint(6, 3) == eng.backend.state_units > 0
    rng = np.random.default_rng(8)
    eng.submit(rng.integers(0, cfg.vocab_size, size=6), max_new=3)
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
