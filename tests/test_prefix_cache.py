"""CacheBackend protocol, COW prefix caching, and backend parity."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.block_manager import BlockManager
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.scheduler import AdmissionScheduler

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def small_lm():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


def _serve(model, params, prompts, config=None, max_batch=4, max_new=5,
           max_len=64):
    eng = ServeEngine(model, params, max_batch=max_batch, max_len=max_len,
                      config=config)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    done = eng.run_until_drained()
    return {r.rid: r.out_tokens for r in done}, eng


# ---------------------------------------------------------------------------
# block manager: refcounts / COW / content cache
# ---------------------------------------------------------------------------

def test_refcount_share_and_staged_release():
    m = BlockManager(6, block_size=4)
    a = m.allocate(2)
    m.ref(a[0])                               # second holder
    assert m.ref_count(a[0]) == 2 and m.shared_now == 1
    m.release(a)                              # holder 1 drops both
    assert m.ref_count(a[0]) == 1 and m.in_use == 1 and m.shared_now == 0
    m.release([a[0]])                         # holder 2 drops the shared one
    assert m.in_use == 0 and m.free == 6


def test_double_release_of_shared_block_rejected():
    """Releasing more times than there are holders must fail loudly — a
    stray extra free would hand one physical block to two lanes."""
    m = BlockManager(4, block_size=4)
    a = m.allocate(1)
    m.ref(a[0])
    m.release(a)
    m.release(a)                              # both holders gone
    with pytest.raises(ValueError):
        m.release(a)                          # third release: over-free
    with pytest.raises(ValueError):
        m.release([a[0], a[0]])               # dup ids in one call


def test_cow_split_allocates_and_derefs():
    m = BlockManager(4, block_size=4)
    a = m.allocate(1)
    m.ref(a[0])
    fresh = m.cow_split(a[0])
    assert fresh is not None and fresh != a[0]
    assert m.ref_count(a[0]) == 1 and m.ref_count(fresh) == 1
    assert m.cow_splits == 1
    with pytest.raises(ValueError):
        m.cow_split(a[0])                     # no longer shared


def test_cow_split_under_pressure_returns_none_without_side_effects():
    m = BlockManager(2, block_size=4)
    a = m.allocate(2)                         # pool exhausted
    m.ref(a[0])
    assert m.cow_split(a[0]) is None          # caller must preempt
    assert m.ref_count(a[0]) == 2 and m.cow_splits == 0


def test_register_match_revive_and_evict():
    m = BlockManager(3, block_size=4)
    toks = np.arange(10)                      # 2 full blocks + tail of 2
    blocks = m.allocate(3)
    assert m.register(blocks, toks) == 2      # partial tail not registered
    # full-block match, then full-coverage partial-tail match
    full = m.match_prefix(np.arange(8))
    assert list(full.blocks) == blocks[:2] and full.n_tokens == 8
    part = m.match_prefix(np.arange(6))
    assert part.n_tokens == 6 and part.tail_partial
    assert list(part.blocks) == blocks[:2]
    # a diverging prefix must not match block 2's chain
    assert m.match_prefix(np.array([9, 9, 9, 9, 4, 5])).n_tokens == 0
    # rc0-cached blocks stay matchable until memory pressure evicts them
    m.release(blocks)
    assert m.match_prefix(np.arange(8)).n_tokens == 8
    m.ref(blocks[0])                          # revive the first
    assert m.ref_count(blocks[0]) == 1
    # allocation prefers the never-cached free block (the unregistered
    # tail), then LRU-evicts exactly one cached block
    got = m.allocate(2)
    assert m.evictions == 1 and set(got) == set(blocks[1:])
    assert m.match_prefix(np.arange(8)).n_tokens == 4   # only b0 survives
    m.uncache(blocks[0])                      # sole holder about to write
    assert m.match_prefix(np.arange(8)).n_tokens == 0


def test_reregistration_must_be_consistent():
    m = BlockManager(3, block_size=2)
    blocks = m.allocate(2)
    m.register(blocks, np.array([1, 2, 3, 4]))
    m.register(blocks, np.array([1, 2, 3, 4]))       # idempotent
    with pytest.raises(ValueError):
        m.register(blocks, np.array([5, 6, 7, 8]))   # content changed


# ---------------------------------------------------------------------------
# engine end-to-end: sharing, COW, preemption with shared blocks
# ---------------------------------------------------------------------------

def test_shared_prefix_admits_more_lanes_token_identically(small_lm):
    """Requests sharing a prompt prefix must (a) decode the same tokens as
    an uncached engine and (b) charge the pool only once for the prefix."""
    model, params = small_lm
    rng = np.random.default_rng(10)
    prefix = rng.integers(0, model.cfg.vocab_size, size=24)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, model.cfg.vocab_size,
                                            size=int(n))])
               for n in (5, 9, 3, 7)]
    plain, _ = _serve(model, params, prompts,
                      EngineConfig(kv_blocks=60, kv_block_size=4))
    cached, eng = _serve(model, params, prompts,
                         EngineConfig(kv_blocks=60, kv_block_size=4,
                                      prefix_cache=True))
    snap = eng.metrics_snapshot()
    assert plain == cached
    assert snap.prefix_hit_rate > 0.4
    assert snap.kv_shared_blocks_peak >= 6      # 24-token prefix, bs=4
    # shared blocks counted once: peak usage beats 4 private copies
    assert snap.kv_blocks_peak < 4 * 10


def test_full_hit_skips_prefill_and_cow_splits_on_write(small_lm):
    """Re-serving a fully-cached prompt must skip the prefill dispatch;
    two concurrent full-hit lanes write into the same shared tail block,
    so exactly one must COW-split — with token-identical output."""
    model, params = small_lm
    rng = np.random.default_rng(11)
    p = rng.integers(0, model.cfg.vocab_size, size=10)
    ref, _ = _serve(model, params, [p], max_batch=2)
    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      config=EngineConfig(kv_blocks=30, kv_block_size=4,
                                          prefix_cache=True))
    w = eng.submit(p, max_new=5)                 # warm: registers the tail
    warm = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    a = eng.submit(p, max_new=5)
    b = eng.submit(p, max_new=5)
    done = {r.rid: r.out_tokens for r in eng.run_until_drained()}
    snap = eng.metrics_snapshot()
    assert done[a] == done[b] == warm[w] == ref[0]
    assert snap.prefill_skipped == 2
    assert snap.cow_splits >= 1


def test_preempt_resume_of_lane_holding_shared_blocks(small_lm):
    """Preemption under pressure with prefix sharing live: refcounts must
    survive the release/requeue/resume cycle and outputs must match an
    unpressured engine token-for-token."""
    model, params = small_lm
    rng = np.random.default_rng(12)
    prefix = rng.integers(0, model.cfg.vocab_size, size=12)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, model.cfg.vocab_size,
                                            size=int(n))])
               for n in (6, 9, 4, 8)]
    ample, _ = _serve(model, params, prompts, max_new=7)
    tight, eng = _serve(model, params, prompts, max_new=7,
                        config=EngineConfig(kv_blocks=12, kv_block_size=4,
                                            prefix_cache=True))
    snap = eng.metrics_snapshot()
    assert snap.preemptions > 0 and snap.resumes > 0
    assert ample == tight
    assert eng.backend.blocks.in_use == 0       # every ref returned


def test_recurrent_preempt_restores_without_recompute():
    """RecurrentBackend snapshots constant-size state host-side; a
    preempted lane must resume token-identically with NO extra prefill
    dispatch (dense/paged recompute would need one)."""
    cfg = reduced_config(get_config("rwkv6-1.6b"))
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(1))
    prompt = np.random.default_rng(13).integers(0, cfg.vocab_size, size=6)
    ref = ServeEngine(model, params, max_batch=1, max_len=32)
    ref.submit(prompt, max_new=6)
    want = ref.run_until_drained()[0].out_tokens
    eng = ServeEngine(model, params, max_batch=1, max_len=32)
    eng.submit(prompt, max_new=6)
    eng.step()
    eng.step()
    eng.preempt(0)
    got = eng.run_until_drained()[0].out_tokens
    snap = eng.metrics_snapshot()
    assert got == want
    assert snap.preemptions == 1 and snap.resumes == 1
    assert snap.prefill_dispatches == 1          # restore, not recompute


# ---------------------------------------------------------------------------
# backend parity: dense vs paged vs recurrent, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,layouts", [
    ("granite-8b", ("dense", "paged", "paged+cache")),   # attention
    ("grok-1-314b", ("dense", "paged")),                 # moe
    ("rwkv6-1.6b", ("dense", "recurrent")),              # rwkv
    ("zamba2-7b", ("dense", "recurrent")),               # hybrid ssm+attn
])
def test_backend_parity_token_identical(arch, layouts):
    """Every cache layout a family supports must produce token-identical
    greedy output — the backend is a memory-management choice, never a
    model-behaviour choice."""
    cfg = dataclasses.replace(reduced_config(get_config(arch)), n_layers=2)
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n))
               for n in (5, 11, 8)]
    configs = {
        "dense": EngineConfig(backend="dense"),
        "paged": EngineConfig(kv_blocks=48, kv_block_size=4),
        "paged+cache": EngineConfig(kv_blocks=48, kv_block_size=4,
                                    prefix_cache=True),
        "recurrent": EngineConfig(backend="recurrent"),
    }
    outs = {}
    for name in layouts:
        outs[name], eng = _serve(model, params, prompts, configs[name],
                                 max_batch=3, max_new=4, max_len=32)
        want = name.split("+")[0]
        assert eng.backend.name == want
    first = outs[layouts[0]]
    for name in layouts[1:]:
        assert outs[name] == first, f"{arch}: {name} diverged from dense"


def test_forced_backend_validation(small_lm):
    model, params = small_lm
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    config=EngineConfig(backend="paged"))   # no kv_blocks
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    config=EngineConfig(backend="recurrent"))  # attention
    cfg = reduced_config(get_config("whisper-small"))
    wmodel = build_model(cfg, RCFG)
    with pytest.raises(ValueError):
        ServeEngine(wmodel, wmodel.init(jax.random.key(3)), max_batch=1,
                    max_len=32,
                    config=EngineConfig(backend="paged", kv_blocks=8))


def test_model_exposes_no_legacy_optional_hooks(small_lm):
    """API acceptance: the old per-capability Optional hooks are gone from
    the Model protocol; capabilities live in decode_state only."""
    model, _ = small_lm
    for legacy in ("prefill_ragged", "init_paged_cache", "decode_step_paged"):
        assert not hasattr(model, legacy), legacy
    assert model.decode_state.poolable


# ---------------------------------------------------------------------------
# satellites: drain warning + footprint-aware scheduler
# ---------------------------------------------------------------------------

def test_run_until_drained_warns_on_exhausted_steps(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(15)
    eng = ServeEngine(model, params, max_batch=1, max_len=64)
    eng.submit(rng.integers(0, model.cfg.vocab_size, size=5), max_new=40)
    with pytest.warns(RuntimeWarning, match="PARTIAL"):
        done = eng.run_until_drained(max_steps=3)
    assert done == [] and eng.active() == 1      # work genuinely unfinished


def test_scheduler_footprint_aware_pop_packs_and_defers():
    """pop() with a backend budget must skip (keep queued, in order) what
    cannot fit now, pack cheaper requests behind it, and still pop
    beyond-capacity requests so the backend can reject them."""
    from repro.serving.engine import Request

    sched = AdmissionScheduler()
    mk = lambda rid, n: Request(rid, np.zeros((n,), np.int32),
                                submitted_t=float(rid))
    for rid, n in [(0, 10), (1, 3), (2, 4), (3, 99)]:
        sched.push(mk(rid, n), 0.0)
    taken = sched.pop(4, 1.0, footprint=lambda r: len(r.prompt),
                      budget=8, capacity=50)
    # 0 (10 tokens) deferred; 1+2 packed; 3 (99 > capacity) popped for
    # the backend's INFEASIBLE rejection
    assert [r.rid for r in taken] == [1, 2, 3]
    assert [r.rid for r in sched.peek_order()] == [0]
    taken = sched.pop(4, 2.0, footprint=lambda r: len(r.prompt),
                      budget=20, capacity=50)
    assert [r.rid for r in taken] == [0]
