"""Sampling subsystem: parameter validation, filters, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampling import (GREEDY, LaneSampling, SamplingParams,
                                    sample_tokens)


def _lane_arrays(params_list):
    ls = LaneSampling.empty(len(params_list))
    for i, p in enumerate(params_list):
        ls.set_lane(i, p)
    return (jnp.asarray(ls.temperature), jnp.asarray(ls.top_k),
            jnp.asarray(ls.top_p), jnp.asarray(ls.key))


def _logits(b, v, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(b, v)),
                       jnp.float32)


def test_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert GREEDY.is_greedy and not SamplingParams(temperature=0.7).is_greedy


def test_greedy_is_argmax_and_key_untouched():
    logits = _logits(3, 32)
    t, k, p, kd = _lane_arrays([GREEDY] * 3)
    toks, new_kd = sample_tokens(logits, t, k, p, kd)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))
    np.testing.assert_array_equal(np.asarray(new_kd), np.asarray(kd))


def test_top_k1_and_tiny_top_p_degenerate_to_argmax():
    logits = _logits(2, 64, seed=1)
    for sp in (SamplingParams(temperature=1.5, top_k=1, seed=7),
               SamplingParams(temperature=1.5, top_p=1e-9, seed=7)):
        t, k, p, kd = _lane_arrays([sp] * 2)
        toks, _ = sample_tokens(logits, t, k, p, kd)
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.asarray(jnp.argmax(logits, axis=-1)))


def test_top_p_renormalizes_over_top_k_survivors():
    """top-p applies to the renormalized top-k distribution (HF-style):
    p=[0.4, 0.1, ...], top_k=2 renormalizes to [0.8, 0.2]; top_p=0.75 then
    keeps only the argmax (0.8 >= 0.75 covers the nucleus)."""
    probs = np.full(12, 0.05)
    probs[0], probs[1] = 0.4, 0.1
    logits = jnp.tile(jnp.log(jnp.asarray(probs))[None], (500, 1))
    params = [SamplingParams(temperature=1.0, top_k=2, top_p=0.75, seed=s)
              for s in range(500)]
    t, k, p, kd = _lane_arrays(params)
    toks, _ = sample_tokens(logits, t, k, p, kd)
    assert set(np.asarray(toks).tolist()) == {0}


def test_top_k_restricts_support():
    """1000 samples with top_k=4 never leave the 4 highest logits."""
    logits = jnp.tile(_logits(1, 32, seed=2), (1000, 1))
    params = [SamplingParams(temperature=2.0, top_k=4, seed=s)
              for s in range(1000)]
    t, k, p, kd = _lane_arrays(params)
    toks, _ = sample_tokens(logits, t, k, p, kd)
    allowed = set(np.asarray(jnp.argsort(logits[0])[-4:]).tolist())
    assert set(np.asarray(toks).tolist()) <= allowed
    assert len(set(np.asarray(toks).tolist())) > 1       # actually stochastic


def test_fixed_seed_is_reproducible_and_seed_matters():
    logits = _logits(4, 48, seed=3)
    sp = SamplingParams(temperature=1.0, top_p=0.95, seed=11)
    t, k, p, kd = _lane_arrays([sp] * 4)
    toks_a, kd_a = sample_tokens(logits, t, k, p, kd)
    toks_b, kd_b = sample_tokens(logits, t, k, p, kd)
    np.testing.assert_array_equal(np.asarray(toks_a), np.asarray(toks_b))
    np.testing.assert_array_equal(np.asarray(kd_a), np.asarray(kd_b))
    # advancing the stream changes the draw eventually
    chain = [np.asarray(toks_a)]
    nkd = kd_a
    for _ in range(4):
        tk, nkd = sample_tokens(logits, t, k, p, nkd)
        chain.append(np.asarray(tk))
    assert any(not np.array_equal(chain[0], c) for c in chain[1:])


def test_lane_streams_independent_of_batch_composition():
    """A lane's draw depends only on its own seed/stream, not on which other
    lanes happen to share the dispatch (continuous batching invariant)."""
    v = 48
    sp = SamplingParams(temperature=1.0, seed=5)
    logits_solo = _logits(1, v, seed=4)
    t, k, p, kd = _lane_arrays([sp])
    tok_solo, _ = sample_tokens(logits_solo, t, k, p, kd)

    other = SamplingParams(temperature=2.0, top_k=3, seed=99)
    logits_pair = jnp.concatenate([logits_solo, _logits(1, v, seed=6)])
    t2, k2, p2, kd2 = _lane_arrays([sp, other])
    tok_pair, _ = sample_tokens(logits_pair, t2, k2, p2, kd2)
    assert int(tok_solo[0]) == int(tok_pair[0])
