"""Speculative decoding: coupled acceptance, rollback identity, fleet pairs.

The load-bearing claim everywhere here is BIT-FOR-BIT equality with the
plain engine: the draft only decides how far a round reaches, never what
is emitted, so every test reduces to "same requests in, identical token
streams out" — for greedy and stochastic lanes, across dense / paged /
recurrent backends, through preemption, and through the fleet wire plane.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.engine_api import REQUIRED_ATTRS, DecodeEngine
from repro.serving.sampling import SamplingParams
from repro.serving.speculative import SpecEngine

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)
STOCH = SamplingParams(temperature=8.0, top_k=64, seed=11)
STOCH2 = SamplingParams(temperature=8.0, top_k=64, seed=99)


@pytest.fixture(scope="module")
def small_lm():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def misaligned_draft(small_lm):
    """A 1-layer draft with its OWN weights: proposals mostly miss, so
    every round exercises rejection + rollback."""
    model, _ = small_lm
    cfg = dataclasses.replace(model.cfg, n_layers=1)
    draft = build_model(cfg, RCFG)
    return draft, draft.init(jax.random.key(3))


@pytest.fixture(scope="module")
def aligned_lm(small_lm):
    """Target with layer 1's output projections zeroed (exact residual
    identity) + the 1-layer prefix as draft: bitwise-equal logits, so the
    draft proposes exactly what the target samples (acceptance 1.0)."""
    model, params = small_lm
    tp = {"embed": params["embed"], "final_ln": params["final_ln"],
          "blocks": dict(params["blocks"])}
    tp["blocks"] = jax.tree_util.tree_map(lambda x: x, params["blocks"])
    for mod, name in (("attn", "wo"), ("mlp", "wo")):
        w = np.asarray(tp["blocks"][mod][name]).copy()
        w[1:] = 0.0
        tp["blocks"][mod][name] = jnp.asarray(w)
    dcfg = dataclasses.replace(model.cfg, n_layers=1)
    draft = build_model(dcfg, RCFG)
    dp = {"embed": tp["embed"], "final_ln": tp["final_ln"],
          "blocks": jax.tree_util.tree_map(lambda x: x[:1], tp["blocks"])}
    return model, tp, draft, dp


def _prompts(vocab, sizes=(6, 3, 9, 1, 5), seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).tolist() for n in sizes]


def _run(engine, prompts, samplings, max_new=10):
    rids = [engine.submit(p, max_new=max_new, sampling=s)
            for p, s in zip(prompts, samplings)]
    engine.run_until_drained()
    done = {r.rid: list(r.out_tokens) for r in engine.finished}
    return [done[r] for r in rids]


# ---------------------------------------------------------------------------
# DecodeEngine conformance
# ---------------------------------------------------------------------------

def test_decode_engine_conformance(small_lm, misaligned_draft):
    from repro.serving.pipeline_decode import PipelineEngine
    model, params = small_lm
    draft, dparams = misaligned_draft
    engines = [
        ServeEngine(model, params, max_batch=2, max_len=32),
        PipelineEngine(model, params, max_batch=2, max_len=32, cuts=[1]),
        SpecEngine(model, params, draft, dparams, max_batch=2, max_len=32),
    ]
    for eng in engines:
        assert isinstance(eng, DecodeEngine), type(eng)
        for attr in REQUIRED_ATTRS:
            assert hasattr(eng, attr), (type(eng), attr)


# ---------------------------------------------------------------------------
# identity with the plain engine
# ---------------------------------------------------------------------------

def test_spec_k1_reduces_to_baseline(small_lm, misaligned_draft):
    """k=1 is the degenerate round: one proposal, one verify position."""
    model, params = small_lm
    draft, dparams = misaligned_draft
    prompts = _prompts(model.cfg.vocab_size)
    samp = [None, STOCH, None, STOCH2, None]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp)
    got = _run(SpecEngine(model, params, draft, dparams, max_batch=4,
                          max_len=48, spec_k=1), prompts, samp)
    assert got == ref


def test_spec_identity_dense_misaligned(small_lm, misaligned_draft):
    """Greedy + stochastic lanes, k=3, a draft that mostly misses: the
    emitted streams still match the plain engine bit-for-bit, and the
    acceptance metrics show real rejections happened."""
    model, params = small_lm
    draft, dparams = misaligned_draft
    prompts = _prompts(model.cfg.vocab_size)
    samp = [None, STOCH, None, STOCH2, None]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp)
    eng = SpecEngine(model, params, draft, dparams, max_batch=4, max_len=48,
                     spec_k=3)
    got = _run(eng, prompts, samp)
    assert got == ref
    snap = eng.metrics_snapshot()
    assert snap.spec_rounds > 0
    assert 0.0 <= snap.spec_acceptance_rate < 1.0
    assert len(snap.spec_accepted_series) == snap.spec_rounds


def test_spec_aligned_draft_accepts_everything(aligned_lm):
    """A bitwise-aligned draft is accepted wholesale — greedy AND
    stochastic — and rounds emit more than one token each."""
    model, params, draft, dparams = aligned_lm
    prompts = _prompts(model.cfg.vocab_size)
    samp = [None, STOCH, None, STOCH2, None]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp, max_new=12)
    eng = SpecEngine(model, params, draft, dparams, max_batch=4, max_len=48,
                     spec_k=3)
    got = _run(eng, prompts, samp, max_new=12)
    assert got == ref
    snap = eng.metrics_snapshot()
    assert snap.spec_acceptance_rate == 1.0
    # k+1 tokens per full round: far fewer rounds than tokens
    assert snap.spec_rounds * 2 <= snap.generated_tokens


def test_spec_colocated_identical_mechanics(small_lm, misaligned_draft):
    """colocated=True only skips the wire frames; tokens are unchanged."""
    model, params = small_lm
    draft, dparams = misaligned_draft
    prompts = _prompts(model.cfg.vocab_size, sizes=(5, 2, 7))
    samp = [None, STOCH, None]
    a = _run(SpecEngine(model, params, draft, dparams, max_batch=4,
                        max_len=48, spec_k=2), prompts, samp)
    b = _run(SpecEngine(model, params, draft, dparams, max_batch=4,
                        max_len=48, spec_k=2, colocated=True), prompts, samp)
    assert a == b


def test_spec_accepted_distribution_matches_target(small_lm, misaligned_draft):
    """Distribution preservation, tested exactly: for many seeds the
    stochastic stream through the speculative engine equals the plain
    engine's stream on that seed — the accepted-token distribution IS the
    target distribution, seed by seed."""
    model, params = small_lm
    draft, dparams = misaligned_draft
    prompt = _prompts(model.cfg.vocab_size, sizes=(6,))[0]
    for seed in range(8):
        sp = SamplingParams(temperature=8.0, top_k=64, seed=seed)
        ref = _run(ServeEngine(model, params, max_batch=1, max_len=32),
                   [prompt], [sp], max_new=6)
        got = _run(SpecEngine(model, params, draft, dparams, max_batch=1,
                              max_len=32, spec_k=3), [prompt], [sp],
                   max_new=6)
        assert got == ref, seed


# ---------------------------------------------------------------------------
# rollback across backends
# ---------------------------------------------------------------------------

def test_spec_paged_rollback_preempt_resume(small_lm, misaligned_draft):
    """Paged target under block pressure: preempt mid-stream + resume via
    recompute must stay token-identical; mid-window reservation failures
    evict a victim rather than corrupt a lane."""
    model, params = small_lm
    draft, dparams = misaligned_draft
    prompts = _prompts(model.cfg.vocab_size, sizes=(6, 5, 7, 4))
    samp = [None, STOCH, None, STOCH2]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp, max_new=12)
    eng = SpecEngine(model, params, draft, dparams, max_batch=4, max_len=48,
                     spec_k=3,
                     config=EngineConfig(kv_blocks=10, kv_block_size=4))
    got = _run(eng, prompts, samp, max_new=12)
    assert got == ref
    snap = eng.metrics_snapshot()
    assert snap.preemptions > 0 and snap.resumes > 0
    # every block came home: nothing leaked across rollback + release
    assert eng.backend.blocks_in_use == 0


def test_spec_recurrent_rollback_replay():
    """Recurrent (rwkv6) target + recurrent draft: rollback replays the
    kept prefix from the pre-round stash; a misaligned draft makes every
    round exercise it."""
    cfg = dataclasses.replace(reduced_config(get_config("rwkv6-1.6b")),
                              n_layers=2)
    model = build_model(cfg, RCFG)
    params = model.init(jax.random.key(0))
    dcfg = dataclasses.replace(cfg, n_layers=1)
    draft = build_model(dcfg, RCFG)
    dparams = draft.init(jax.random.key(3))
    prompts = _prompts(cfg.vocab_size, sizes=(6, 1, 4))
    samp = [None, STOCH, None]
    ref = _run(ServeEngine(model, params, max_batch=3, max_len=40),
               prompts, samp)
    got = _run(SpecEngine(model, params, draft, dparams, max_batch=3,
                          max_len=40, spec_k=3), prompts, samp)
    assert got == ref


# ---------------------------------------------------------------------------
# Sampler API shim
# ---------------------------------------------------------------------------

def test_submit_legacy_kwargs_shim(small_lm):
    """Loose temperature/top_k/seed kwargs still work (deprecated) and pin
    the same stream as the SamplingParams spelling."""
    model, params = small_lm
    prompt = _prompts(model.cfg.vocab_size, sizes=(5,))[0]
    ref = _run(ServeEngine(model, params, max_batch=1, max_len=32),
               [prompt], [SamplingParams(temperature=8.0, top_k=64, seed=5)],
               max_new=6)
    eng = ServeEngine(model, params, max_batch=1, max_len=32)
    with pytest.warns(DeprecationWarning):
        rid = eng.submit(prompt, max_new=6, temperature=8.0, top_k=64, seed=5)
    eng.run_until_drained()
    assert [list(eng.finished[0].out_tokens)] == ref and rid is not None
    # mixing both spellings is an error, not a precedence rule
    with pytest.raises(TypeError):
        eng.submit(prompt, sampling=SamplingParams(), temperature=1.0)


def test_spec_engine_rejects_extra_inputs(small_lm, misaligned_draft):
    model, params = small_lm
    draft, dparams = misaligned_draft
    eng = SpecEngine(model, params, draft, dparams, max_batch=2, max_len=32)
    with pytest.raises(TypeError):
        eng.submit([1, 2, 3], pixel_values=np.zeros((1, 4)))
    with pytest.raises(ValueError):
        SpecEngine(model, params, draft, dparams, max_batch=2, max_len=32,
                   spec_k=0)


# ---------------------------------------------------------------------------
# fleet pairing
# ---------------------------------------------------------------------------

def test_fleet_spec_pair_identity_and_frames(aligned_lm):
    from repro.hw.specs import get_profile
    from repro.serving.fleet import ServingFleet, SpecPair, WorkerSpec
    model, params, draft, dparams = aligned_lm
    prompts = _prompts(model.cfg.vocab_size, sizes=(6, 3, 9, 1))
    samp = [None, STOCH, None, STOCH2]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp)

    pair = SpecPair(name="pair",
                    draft=WorkerSpec("d0", get_profile("a18-pro")),
                    target=WorkerSpec("t0", get_profile("m2-max-cpu")),
                    draft_model=draft, draft_params=dparams, spec_k=3)
    fleet = ServingFleet(model, params, spec_pairs=[pair], max_len=48)
    rids = [fleet.submit(p, max_new=10, sampling=s)
            for p, s in zip(prompts, samp)]
    fleet.run_until_drained()
    done = {r.rid: list(r.out_tokens)
            for r in fleet.spec_pairs[0].engine.finished}
    assert [done[r] for r in rids] == ref

    ss = fleet.snapshot().per_spec["pair"]
    assert ss.engine.spec_rounds > 0
    assert ss.engine.spec_acceptance_rate == 1.0
    assert ss.frame_bytes > 0 and ss.spec_k == 3
    assert not ss.colocated and not ss.drained
    assert set(ss.members) == {"d0", "t0"}
    assert ss.goodput_tokens_per_s > 0


def test_fleet_spec_pair_colocated_fallback(aligned_lm):
    from repro.hw.specs import get_profile
    from repro.serving.fleet import ServingFleet, SpecPair, WorkerSpec
    model, params, draft, dparams = aligned_lm
    prompts = _prompts(model.cfg.vocab_size, sizes=(6, 3))
    samp = [None, STOCH]
    ref = _run(ServeEngine(model, params, max_batch=4, max_len=48),
               prompts, samp)
    pair = SpecPair(name="pair",
                    draft=WorkerSpec("d0", get_profile("a18-pro")),
                    target=WorkerSpec("t0", get_profile("m2-max-cpu")),
                    draft_model=draft, draft_params=dparams, spec_k=3)
    fleet = ServingFleet(model, params, spec_pairs=[pair], max_len=48)
    fleet.spec_pairs[0].set_colocated(True)
    rids = [fleet.submit(p, max_new=10, sampling=s)
            for p, s in zip(prompts, samp)]
    fleet.run_until_drained()
    done = {r.rid: list(r.out_tokens)
            for r in fleet.spec_pairs[0].engine.finished}
    assert [done[r] for r in rids] == ref
    ss = fleet.snapshot().per_spec["pair"]
    assert ss.colocated and ss.colocations == 1 and ss.frame_bytes == 0
