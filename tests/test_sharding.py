"""Logical-axis sharding rules: validity on the production mesh shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, RunConfig, get_config
from repro.core import sharding as sh
from repro.models.api import build_model

try:
    MESH = AbstractMesh((16, 16), ("data", "model"))        # jax >= 0.6
except TypeError:
    MESH = AbstractMesh((("data", 16), ("model", 16)))      # jax 0.4.x


def _params_shape(arch):
    cfg = get_config(arch)
    model = build_model(cfg, RunConfig())
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_divisible(arch):
    """Every emitted PartitionSpec must evenly divide its dim (our rule:
    fall back to replication rather than padding)."""
    ps = _params_shape(arch)
    shard = sh.param_shardings(ps, MESH, "gspmd_tp", fsdp=True)

    def check(leaf, s):
        spec = s.spec
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            size = np.prod([MESH.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))])
            assert dim % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, ps, shard)


def test_tp_shards_big_dims():
    ps = _params_shape("granite-8b")
    shard = sh.param_shardings(ps, MESH, "gspmd_tp")
    mlp_spec = shard["blocks"]["mlp"]["wi"].spec
    assert "model" in jax.tree.leaves(tuple(mlp_spec))
    emb_spec = shard["embed"]["tok"].spec
    assert emb_spec[0] == "model"          # vocab sharded


def test_moe_expert_parallel():
    ps = _params_shape("llama4-scout-17b-a16e")
    shard = sh.param_shardings(ps, MESH, "gspmd_tp")
    wi = shard["blocks"]["moe"]["wi"].spec      # (L, E, D, F)
    assert wi[1] == "model"                     # 16 experts over 16-way axis


def test_moe_fallback_when_not_divisible():
    ps = _params_shape("grok-1-314b")           # 8 experts on 16-way axis
    shard = sh.param_shardings(ps, MESH, "gspmd_tp")
    wi = shard["blocks"]["moe"]["wi"].spec
    assert len(wi) == 4 and wi[1] is None and wi[3] == "model"
