"""Split-tool FIFO semantics + overlap (paper §3.6/§4.3)."""
import time

import numpy as np
import pytest

from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB


def test_vectordb_topk_correct():
    db = VectorDB(n_docs=500, dim=32, seed=1)
    q = db.encode("query")
    out = db.search(q, 7)
    scores = db.embeddings @ q
    want = np.argsort(-scores)[:7]
    np.testing.assert_array_equal(out[:, 0].astype(int), want)
    assert np.all(np.diff(out[:, 1]) <= 1e-6)


def test_fifo_order():
    ex = ToolExecutor(n_workers=1)
    ex.register("t", lambda x: np.asarray([x]), simulated_seconds=0.01)
    for i in range(4):
        ex.begin("t", x=i)
    got = [int(ex.retrieve()[0]) for _ in range(4)]
    assert got == [0, 1, 2, 3]                 # oldest first (paper FIFO)
    with pytest.raises(LookupError):
        ex.retrieve()


def test_overlap_eliminates_wait():
    ex = ToolExecutor(n_workers=3)
    ex.register("slow", lambda: np.zeros(1), simulated_seconds=0.25)
    t0 = time.perf_counter()
    for _ in range(3):
        ex.begin("slow")
    time.sleep(0.3)                            # "reasoning" while tools run
    for _ in range(3):
        ex.retrieve()
    assert time.perf_counter() - t0 < 0.55     # serial would be >= 0.75


def test_wire_payload_roundtrip():
    ex = ToolExecutor(n_workers=1, wire=True)
    ex.register("echo", lambda x: np.asarray(x) * 2, simulated_seconds=0.0)
    ex.begin("echo", x=np.arange(5))
    np.testing.assert_array_equal(ex.retrieve(), np.arange(5) * 2)
