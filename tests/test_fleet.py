"""Thermal-aware serving fleet: routing, elastic actions, migration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.hw.specs import DeviceProfile
from repro.models.api import build_model
from repro.runtime.elastic import Action, ServingElasticPolicy
from repro.runtime.monitor import ThermalMonitor, ThermalState, WorkerStats
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.fleet import (ServingFleet, ThermalReservoir,
                                 ThrottleTrace, WorkerSpec, drive_sim)
from repro.serving.sampling import SamplingParams
from repro.serving.traffic import poisson_trace
from repro.serving.scheduler import SchedulerConfig

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def small_lm():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


def _profile(name, rate=20.0, **kw):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=rate,
                         prefill_tokens_per_s=1e9, **kw)


def _fleet(model, params, *, rates=(20.0, 20.0), names=("a", "b"),
           max_batch=2, **kw):
    workers = [WorkerSpec(n, _profile(f"dev-{n}", r), max_batch=max_batch)
               for n, r in zip(names, rates)]
    return ServingFleet(model, params, workers, max_len=48, tick_s=0.05,
                        **kw)


# ---------------------------------------------------------------------------
# policy unit behaviour (no engines involved)
# ---------------------------------------------------------------------------
def test_serving_elastic_policy_edges_and_hysteresis():
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    pol = ServingElasticPolicy()
    mon.observe("w", 1.0)                        # calibrates baseline
    assert pol.step(mon) == []                   # Minimal: nothing to do
    mon.observe("w", 1.10)                       # >= 1.08 -> Serious
    kinds = [a.kind for a in pol.step(mon)]
    assert kinds == ["drain", "migrate", "duty_cycle"]
    # still hot: drain/migrate are edge-triggered, duty re-asserts
    assert [a.kind for a in pol.step(mon)] == ["duty_cycle"]
    mon.observe("w", 1.05)                       # Fair: NOT yet recovered
    kinds = [a.kind for a in pol.step(mon)]
    assert "undrain" not in kinds                # hysteresis holds
    mon.observe("w", 1.0)                        # back to Minimal
    assert [a.kind for a in pol.step(mon)] == ["undrain"]
    mon.observe("w", 1.10)                       # relapse: full reaction
    assert [a.kind for a in pol.step(mon)] == ["drain", "migrate",
                                               "duty_cycle"]


def test_thermal_reservoir_heats_under_load_and_cools_idle():
    prof = _profile("hot", thermal_sustained=0.5, thermal_tau_s=10.0)
    res = ThermalReservoir({"hot": prof})
    s = 1.0
    for _ in range(100):
        s = res.advance("hot", 1.0, util=1.0)
    assert s > 1.8                               # ~2.0 at full heat
    for _ in range(100):
        s = res.advance("hot", 1.0, util=0.0)
    assert s < 1.05                              # idle time dissipates heat
    assert res.advance("unknown", 1.0, 1.0) == 1.0


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_fleet_routes_by_backlog_then_state(small_lm):
    model, params = small_lm
    fleet = _fleet(model, params)
    p = np.arange(6, dtype=np.int32)
    r0 = fleet.submit(p, max_new=2)
    assert fleet.routed[r0] == "a"               # empty fleet: name tiebreak
    r1 = fleet.submit(p, max_new=2)
    assert fleet.routed[r1] == "b"               # a now has backlog
    # mark b SERIOUS: thermal routing prefers the cooler, busier a
    fleet.monitor.workers["b"] = WorkerStats(
        "b", baseline_s=1.0, ewma_s=1.2, state=ThermalState.SERIOUS)
    r2 = fleet.submit(p, max_new=2)
    assert fleet.routed[r2] == "a"
    # thermally-naive routing ignores the state and balances backlog
    fleet.thermal_routing = False
    r3 = fleet.submit(p, max_new=2)
    assert fleet.routed[r3] == "b"


def test_fleet_drain_excludes_worker_until_undrained(small_lm):
    model, params = small_lm
    fleet = _fleet(model, params)
    p = np.arange(6, dtype=np.int32)
    fleet.drain("a")
    rids = [fleet.submit(p, max_new=2) for _ in range(3)]
    assert all(fleet.routed[r] == "b" for r in rids)
    fleet.undrain("a")
    assert fleet.routed[fleet.submit(p, max_new=2)] == "a"
    # an all-drained fleet still queues (never silently drops)
    fleet.drain("a")
    fleet.drain("b")
    rid = fleet.submit(p, max_new=2)
    assert rid is not None and fleet.routed[rid] in ("a", "b")
    assert fleet.snapshot().drains == 3


# ---------------------------------------------------------------------------
# migration / policies end to end
# ---------------------------------------------------------------------------
def test_fleet_migration_is_token_identical(small_lm):
    model, params = small_lm
    prompts = [np.asarray(
        np.random.default_rng(10 + i).integers(
            0, model.cfg.vocab_size, size=6 + i), np.int32)
        for i in range(6)]
    samplings = [SamplingParams(temperature=3.0, top_k=16, seed=50 + i)
                 if i % 2 else None for i in range(6)]

    fleet = _fleet(model, params, rates=(20.0, 20.0),
                   policy=ServingElasticPolicy(),
                   throttle=ThrottleTrace({"b": (0.2, 6.0, 0.1)}))
    arrivals = np.linspace(0.0, 0.5, len(prompts))
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=8,
                                     sampling=samplings[i]))
    snap = fleet.snapshot()
    assert snap.completed == len(prompts)
    assert snap.migrated_requests >= 1, "throttled b must shed lanes"
    assert snap.drains >= 1

    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=48)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=8, sampling=sp)
    want = {r.rid: r.out_tokens for r in ref.run_until_drained()}
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want

    # fleet bookkeeping: migrated requests finish on the cool worker, and
    # thermal-state occupancy saw the hot episode
    for rec in fleet.completed:
        if rec.migrated:
            assert rec.worker == "a"
    assert snap.per_worker["b"].state_occupancy.get("Critical", 0.0) > 0.0
    assert snap.goodput_tokens_per_s > 0.0


def test_fleet_deadline_expires_queued_behind_drained_worker(small_lm):
    model, params = small_lm
    # b is slower, so the deadline request routes to a's queue; a then
    # drains (hot) and the queued request expires before ever admitting
    fleet = _fleet(model, params, rates=(20.0, 10.0), max_batch=1)
    long_p = np.arange(8, dtype=np.int32)
    r0 = fleet.submit(long_p, max_new=12)
    r1 = fleet.submit(long_p, max_new=12)
    assert fleet.routed[r0] == "a" and fleet.routed[r1] == "b"
    r2 = fleet.submit(np.arange(5, dtype=np.int32), max_new=2,
                      deadline_s=1e-6)
    assert fleet.routed[r2] == "a"               # higher rate: less backlog
    fleet.drain("a")
    fleet.run_until_drained(max_ticks=5_000)
    a_eng = fleet.worker("a").engine
    assert [r.rid for r in a_eng.scheduler.expired] == [r2]
    snap = fleet.snapshot()
    assert snap.expired == 1
    assert snap.completed == 2
    assert {rec.req.rid for rec in fleet.completed} == {r0, r1}


def test_fleet_migration_skips_infeasible_destination(small_lm):
    """A mid-flight request must never migrate onto a worker whose
    backend can't hold its final footprint (it would be REJECTED there,
    i.e. silently dropped) — it stays and finishes on the hot worker."""
    model, params = small_lm
    tiny = EngineConfig(kv_blocks=4, kv_block_size=4)     # 16-token pool
    workers = [WorkerSpec("a", _profile("da", 20.0), max_batch=2),
               WorkerSpec("b", _profile("db", 20.0), max_batch=2,
                          engine_config=tiny)]
    fleet = ServingFleet(model, params, workers, max_len=48, tick_s=0.05)
    fleet.drain("b")                         # force both requests onto a
    p = np.arange(8, dtype=np.int32)
    rids = [fleet.submit(p, max_new=12) for _ in range(2)]   # final 19 > 16
    for _ in range(2):
        fleet.tick()                         # admit into a's lanes
    fleet.undrain("b")
    assert fleet.migrate("a") == 0           # b is the only target: unfit
    fleet.run_until_drained(max_ticks=5_000)
    snap = fleet.snapshot()
    assert snap.migrations == 0 and snap.rejected == 0
    # the lanes were never evicted: no recompute was paid to go nowhere
    assert snap.per_worker["a"].engine.preemptions == 0
    assert {rec.req.rid for rec in fleet.completed} == set(rids)
    assert all(rec.worker == "a" for rec in fleet.completed)


def test_fleet_routing_respects_backend_feasibility(small_lm):
    """submit() must not route a request onto a backend that can never
    hold its final footprint while a worker that can is standing by —
    and when NO worker fits, the backend's alloc still records the
    authoritative rejection instead of the queue hiding it."""
    model, params = small_lm
    tiny = EngineConfig(kv_blocks=4, kv_block_size=4)     # 16-token pool
    workers = [WorkerSpec("a", _profile("da", 20.0), max_batch=2,
                          engine_config=tiny),
               WorkerSpec("b", _profile("db", 20.0), max_batch=2)]
    fleet = ServingFleet(model, params, workers, max_len=48, tick_s=0.05)
    big, small = np.arange(8, dtype=np.int32), np.arange(4, dtype=np.int32)
    r_big = fleet.submit(big, max_new=12)    # final 19 > a's 16-token pool
    assert fleet.routed[r_big] == "b"
    assert fleet.routed[fleet.submit(small, max_new=2)] == "a"

    both_tiny = [WorkerSpec("a", _profile("da", 20.0), max_batch=2,
                            engine_config=tiny),
                 WorkerSpec("b", _profile("db", 20.0), max_batch=2,
                            engine_config=tiny)]
    fleet2 = ServingFleet(model, params, both_tiny, max_len=48, tick_s=0.05)
    rid = fleet2.submit(big, max_new=12)     # fits nowhere
    assert rid is not None                   # queued on the fallback...
    fleet2.run_until_drained(max_ticks=100)
    snap = fleet2.snapshot()
    assert snap.rejected == 1 and snap.completed == 0   # ...then rejected


def test_fleet_rejected_counts_once_across_probed_workers(small_lm):
    """A submit bounced by every full queue is ONE fleet rejection — not
    one per probed engine — and an admission that succeeds on the second
    worker must not leave a rejection record on the first."""
    model, params = small_lm
    fleet = _fleet(model, params,
                   scheduler=SchedulerConfig(policy="fcfs", max_queue=1))
    p = np.arange(6, dtype=np.int32)
    r0 = fleet.submit(p, max_new=2)
    r1 = fleet.submit(p, max_new=2)          # a's queue full: lands on b
    assert fleet.routed[r0] == "a" and fleet.routed[r1] == "b"
    assert fleet.submit(p, max_new=2) is None          # both queues full
    snap = fleet.snapshot()
    assert snap.rejected == 1
    assert all(w.engine.scheduler.rejected_total == 0
               and not w.engine.scheduler.rejected for w in fleet.workers)


def test_fleet_migrate_queued_respects_destination_max_queue(small_lm):
    """Never-admitted queued backlog migrates only into queue room —
    max_queue is the fleet's overload protection and must survive a
    migration (mid-flight lanes may still bypass it)."""
    model, params = small_lm
    fleet = _fleet(model, params,
                   scheduler=SchedulerConfig(policy="fcfs", max_queue=2))
    p = np.arange(6, dtype=np.int32)
    homes = [fleet.routed[fleet.submit(p, max_new=2)] for _ in range(4)]
    assert sorted(homes) == ["a", "a", "b", "b"]
    assert fleet.migrate("a") == 0           # b's queue is already full
    a_eng, b_eng = fleet.worker("a").engine, fleet.worker("b").engine
    assert a_eng.scheduler.depth == 2 and b_eng.scheduler.depth == 2
    assert fleet.snapshot().rejected == 0    # nothing dropped either


def test_fleet_migrate_queued_midflight_counts_as_migrated(small_lm):
    """A preempted-then-requeued request moved via the queue path resumes
    cross-engine — it must count in migrated_requests just like a lane
    move (and may bypass the destination's max_queue: tokens are owed)."""
    model, params = small_lm
    fleet = _fleet(model, params)
    rid = fleet.submit(np.arange(6, dtype=np.int32), max_new=4)
    req = fleet.worker("a").engine.pull_queued()[0]
    req.admitted_t = 1.0                     # simulate a past preemption
    req.out_tokens.append(3)
    fleet.worker("a").engine.inject(req, force=True)
    assert fleet.migrate("a") == 1
    snap = fleet.snapshot()
    assert snap.migrated_requests == 1 and snap.queue_moves == 1
    assert snap.migrations == 0              # no lane was occupied
    fleet.run_until_drained(max_ticks=2_000)
    recs = {rec.req.rid: rec for rec in fleet.completed}
    assert recs[rid].migrated and recs[rid].worker == "b"


def test_fleet_ignores_policy_actions_for_foreign_workers(small_lm):
    """A shared ThermalMonitor can track non-fleet workers; actions the
    policy emits for them must be skipped, not KeyError the tick."""
    model, params = small_lm
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    mon.workers["ghost"] = WorkerStats(
        "ghost", baseline_s=1.0, ewma_s=1.5, state=ThermalState.CRITICAL)
    fleet = _fleet(model, params, monitor=mon,
                   policy=ServingElasticPolicy())
    fleet.submit(np.arange(6, dtype=np.int32), max_new=2)
    for _ in range(3):
        fleet.tick()                         # must not raise
    assert all(a.worker != "ghost" for _, a in fleet.action_log)
    assert fleet.snapshot().drains == 0


def test_fleet_deadlines_run_on_the_sim_clock(small_lm):
    """Under drive_sim, Request.deadline_s is SIMULATED seconds: the
    fleet stamps submissions with sim_t and the engines' clock is the
    fleet's sim clock, so expiry follows the simulation — not host wall
    time (which bears no relation to it)."""
    model, params = small_lm
    fleet = _fleet(model, params, names=("a",), rates=(20.0,), max_batch=1)
    assert fleet.worker("a").engine._now() == fleet.sim_t
    r0 = fleet.submit(np.arange(8, dtype=np.int32), max_new=40)   # hogs lane
    # generous in sim terms (~4 ticks) but far below any wall-clock jit
    # time: wall-clock evaluation would never expire it deterministically
    r1 = fleet.submit(np.arange(5, dtype=np.int32), max_new=2,
                      deadline_s=0.2)
    r2 = fleet.submit(np.arange(5, dtype=np.int32), max_new=2,
                      deadline_s=1e9)                  # never expires
    eng = fleet.worker("a").engine
    assert all(r.submitted_t == 0.0 for r in eng.queue)   # sim-t stamped
    fleet.run_until_drained(max_ticks=5_000)
    assert [r.rid for r in eng.scheduler.expired] == [r1]
    done = {rec.req.rid for rec in fleet.completed}
    assert done == {r0, r2}
    assert fleet.snapshot().expired == 1


def test_fleet_probes_drained_workers_at_paced_cost(small_lm):
    """An idle drained worker is no longer observed for free: telemetry
    arrives only through paced probes (one per probe_every_s), each
    costing a step's compute — while a busy worker observes per tick and
    pays no probes."""
    model, params = small_lm
    fleet = _fleet(model, params, probe_every_s=0.25)
    fleet.drain("b")
    for _ in range(4):
        fleet.submit(np.arange(6, dtype=np.int32), max_new=24)
    n_ticks = 20
    for _ in range(n_ticks):
        fleet.tick()
    snap = fleet.snapshot()
    a, b = snap.per_worker["a"], snap.per_worker["b"]
    assert a.probes == 0                        # busy: steps ARE telemetry
    assert 0 < b.probes <= 1 + n_ticks * fleet.tick_s / 0.25
    # probes still calibrate the monitor: the drained worker has a state
    assert fleet.monitor.workers["b"].steps == b.probes
    assert snap.probes == b.probes


def test_fleet_wall_telemetry_never_mixes_time_scales(small_lm):
    """telemetry="wall": the monitor is calibrated on MEASURED dispatch
    times, so probes must re-observe the last measured value — never the
    synthetic sim step time — and must skip entirely before any real
    dispatch ran (an unobserved worker beats a polluted baseline)."""
    model, params = small_lm
    # b never runs: its probes have nothing real to re-measure and skip
    fleet = _fleet(model, params, telemetry="wall", probe_every_s=0.05)
    fleet.drain("b")
    fleet.submit(np.arange(6, dtype=np.int32), max_new=4)
    fleet.run_until_drained(max_ticks=2_000)
    for _ in range(6):
        fleet.tick()
    assert "b" not in fleet.monitor.workers
    assert fleet.snapshot().per_worker["b"].probes == 0
    # a ran: idle probes re-observe its last MEASURED wall latency, so
    # the EWMA converges toward that value, not toward the 50ms sim step
    a = fleet.worker("a")
    ws = fleet.monitor.workers["a"]
    assert a.last_wall_step_s is not None
    before_gap = abs(ws.ewma_s - a.last_wall_step_s)
    n_before, p_before = ws.steps, a.probes
    for _ in range(8):
        fleet.tick()
    assert a.probes > p_before and ws.steps > n_before
    assert abs(ws.ewma_s - a.last_wall_step_s) <= before_gap + 1e-12


def test_fleet_migrate_picks_cheapest_victims_first(small_lm):
    """Cost-aware victim choice: with lanes=1 the SHORTEST-context lane
    moves (least re-prefill recompute), not the whole worker."""
    model, params = small_lm
    fleet = _fleet(model, params, max_batch=2)
    fleet.drain("b")                            # both admissions land on a
    r_short = fleet.submit(np.arange(4, dtype=np.int32), max_new=24)
    r_long = fleet.submit(np.arange(20, dtype=np.int32), max_new=24)
    for _ in range(3):
        fleet.tick()                            # admit both into lanes
    assert fleet.worker("a").engine.active() == 2
    fleet.undrain("b")
    assert fleet.migrate("a", lanes=1) == 1
    snap = fleet.snapshot()
    assert snap.migrations == 1
    fleet.run_until_drained(max_ticks=5_000)
    recs = {rec.req.rid: rec for rec in fleet.completed}
    assert recs[r_short].worker == "b" and recs[r_short].migrated
    assert recs[r_long].worker == "a" and not recs[r_long].migrated


def test_fleet_duty_cycle_paces_steps(small_lm):
    model, params = small_lm

    class HalfDuty:
        def step(self, monitor):
            return [Action("duty_cycle", "a", {"duty": 0.5})]

    def steps_after(policy, n_ticks=12):
        fleet = _fleet(model, params, names=("a",), rates=(40.0,),
                       policy=policy)
        for i in range(8):
            fleet.submit(np.arange(6, dtype=np.int32), max_new=32)
        for _ in range(n_ticks):
            fleet.tick()
        return fleet.worker("a").steps_run

    full, half = steps_after(None), steps_after(HalfDuty())
    assert full > half >= 1
    assert half <= 0.7 * full                    # ~0.5 with rounding slack


def test_fleet_seeded_trace_is_deterministic(small_lm):
    """Same traffic seed -> identical FleetSnapshot, run to run: the whole
    serving path (trace, routing, scheduling, thermal policy) runs on
    seeded RNGs and the sim clock, so nothing about a run may depend on
    host timing."""
    model, params = small_lm
    trace = poisson_trace(4.0, 1.5, seed=5, prompt_tokens=(4, 10),
                          max_new_tokens=(2, 6))
    assert len(trace) > 0

    def run():
        fleet = _fleet(model, params)
        rng = np.random.default_rng(9)
        prompts = [rng.integers(0, model.cfg.vocab_size, size=int(p))
                   .astype(np.int32) for p in trace.prompt_lens]

        def sub(i):
            fleet.submit(prompts[i], max_new=int(trace.max_news[i]))

        drive_sim(fleet, trace.arrivals, sub)
        return fleet.snapshot()

    assert run() == run()
