"""Scale plane: SimFleet semantics, traffic determinism, SLO accounting.

Everything here is jax-free (the point of the scale plane), so these tests
cover production-shaped scenarios — 100+ deep queues, autoscale cycles —
in milliseconds.
"""
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.hw.specs import DeviceProfile
from repro.runtime.elastic import Action, AutoscalePolicy, FleetLoad
from repro.serving.metrics import (OUTCOME_DONE, OUTCOME_SHED, SLOClass,
                                   slo_report)
from repro.serving.scale import ScaleWorkerSpec, SimFleet, make_rows, play
from repro.serving.traffic import (SimClock, diurnal_trace, drive_open_loop,
                                   merge_traces, mmpp_trace, poisson_trace)


def _profile(decode=10.0, prefill=1e4, sustained=0.85, tau=60.0):
    return DeviceProfile(name="sim", year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=decode,
                         prefill_tokens_per_s=prefill,
                         thermal_sustained=sustained, thermal_tau_s=tau)


def _spec(**kw):
    prof_kw = {k: kw.pop(k) for k in ("decode", "prefill", "sustained", "tau")
               if k in kw}
    return ScaleWorkerSpec(profile=_profile(**prof_kw), **kw)


# ---------------------------------------------------------------------------
# traffic traces
# ---------------------------------------------------------------------------
def test_traces_are_seed_deterministic():
    for make in (lambda s: poisson_trace(5.0, 20.0, seed=s),
                 lambda s: diurnal_trace(5.0, 20.0, period_s=20.0, seed=s),
                 lambda s: mmpp_trace(1.0, 20.0, 20.0, seed=s)):
        a, b = make(3), make(3)
        np.testing.assert_array_equal(a.arrivals, b.arrivals)
        np.testing.assert_array_equal(a.prompt_lens, b.prompt_lens)
        np.testing.assert_array_equal(a.max_news, b.max_news)
        np.testing.assert_array_equal(a.classes, b.classes)
        assert not np.array_equal(a.arrivals, make(4).arrivals)


def test_merge_traces_interleaves_sorted():
    m = merge_traces(poisson_trace(3.0, 10.0, seed=0),
                     mmpp_trace(1.0, 10.0, 10.0, seed=1))
    assert np.all(np.diff(m.arrivals) >= 0)
    assert len(m) == (len(poisson_trace(3.0, 10.0, seed=0))
                      + len(mmpp_trace(1.0, 10.0, 10.0, seed=1)))


def test_sim_fleet_seeded_run_is_deterministic():
    """Same seed -> same trace -> identical snapshot, twice over (the
    scale-plane analogue of the FleetSnapshot determinism test)."""
    trace = merge_traces(
        diurnal_trace(20.0, 30.0, period_s=30.0, seed=2),
        mmpp_trace(0.0, 30.0, 30.0, calm_dwell_s=10.0, burst_dwell_s=2.0,
                   seed=3))

    def run():
        fleet = SimFleet(
            make_rows(_spec(max_batch=4, max_queue=32), 24), n_start=6,
            tick_s=0.1, slo=(SLOClass("interactive", ttft_s=2.0),),
            autoscaler=AutoscalePolicy(min_workers=6, max_workers=24,
                                       target_wait_s=0.5, cooldown_s=1.0),
            autoscale_every_s=0.5, warm_param_bytes=1e8)
        play(fleet, trace)
        return fleet.snapshot()

    assert run() == run()


# ---------------------------------------------------------------------------
# loop-vs-vector oracle
# ---------------------------------------------------------------------------
def test_loop_and_vector_ticks_are_bit_identical():
    """The vectorized tick is a refactor, not a resemantic: a mixed
    scenario (deadlines, thermal drain, autoscaling, expiry) must produce
    the exact same snapshot under both implementations."""
    def run(impl):
        fleet = SimFleet(
            make_rows(_spec(decode=4.0, sustained=0.5, tau=3.0,
                            max_batch=2, max_queue=16), 8),
            n_start=3, tick_s=0.1,
            slo=(SLOClass("interactive", ttft_s=5.0),),
            autoscaler=AutoscalePolicy(min_workers=3, max_workers=8,
                                       target_wait_s=0.3, cooldown_s=0.5,
                                       settle_reads=2),
            autoscale_every_s=0.3, warm_param_bytes=2e8, impl=impl)
        rng = np.random.default_rng(0)
        sizes = list(zip(rng.integers(4, 40, 100), rng.integers(2, 30, 100)))
        for step in range(240):
            if step < 50:
                for p, m in sizes[2 * step: 2 * step + 2]:
                    fleet.submit(int(p), int(m),
                                 deadline_s=6.0 if step % 3 else None)
            fleet.tick()
        return fleet.snapshot()

    a, b = run("vector"), run("loop")
    assert a.completed > 0          # the scenario exercises the decode path
    assert a == b


# ---------------------------------------------------------------------------
# admission shed vs capacity reject vs queued expiry
# ---------------------------------------------------------------------------
def test_capacity_reject_when_every_queue_is_full():
    fleet = SimFleet([_spec(max_queue=4)], admission=False)
    for _ in range(10):
        fleet.submit(8, 4)
    assert fleet.rejected == 6 and fleet.shed == 0
    assert fleet.offered == 10 and int(fleet.queue_len[0]) == 4


def test_admission_sheds_on_predicted_ttft_not_capacity():
    fleet = SimFleet([_spec(prefill=100.0, max_queue=64)],
                     slo=(SLOClass("interactive", ttft_s=0.5),))
    # 200 prompt tokens at 100 tok/s prefill -> 2s predicted TTFT > 0.5s
    assert fleet.submit(200, 4) is None
    assert fleet.shed == 1 and fleet.rejected == 0
    # a small prompt still fits the budget and is queued normally
    assert fleet.submit(10, 4) is not None
    snap = fleet.snapshot()
    assert snap.shed == 1 and snap.slo.shed == 1
    assert snap.slo.classes[0].shed == 1


def test_deadline_expiry_behind_drained_worker_at_depth():
    """120 queued requests behind a thermally drained worker: heads hold
    the lanes, everything behind them expires at pop time — counted as
    expired, never as shed/rejected, and the books still balance."""
    fleet = SimFleet(
        [_spec(decode=1.0, prefill=1e6, sustained=0.5, tau=float("inf"),
               max_batch=2, max_queue=128)],
        tick_s=0.05, admission=False)
    for _ in range(120):
        fleet.submit(4, 50, deadline_s=1.0)
    assert int(fleet.queue_len[0]) == 120          # 100+ deep, none admitted
    fleet.heat[0] = 0.30       # slowdown 1.3 >= CRITICAL edge; inf tau
    #                            freezes the reservoir so the drain holds
    for _ in range(60):        # 3 sim-seconds >> the 1s deadlines
        fleet.tick()
    assert fleet.drains >= 1 and bool(fleet.drained[0])
    assert fleet.expired >= 100
    assert fleet.shed == 0 and fleet.rejected == 0
    snap = fleet.snapshot()
    assert snap.offered == (snap.completed + snap.shed + snap.rejected
                            + snap.expired + snap.queued_now + snap.active_now)


def test_books_balance_once_drained():
    trace = poisson_trace(30.0, 10.0, seed=1, prompt_tokens=(4, 32),
                          max_new_tokens=(2, 12))
    fleet = SimFleet(make_rows(_spec(max_batch=4, max_queue=8), 4),
                     slo=(SLOClass("interactive", ttft_s=0.5),))
    play(fleet, trace)
    snap = fleet.snapshot()
    assert snap.queued_now == 0 and snap.active_now == 0
    assert snap.offered == len(trace)
    assert snap.offered == (snap.completed + snap.shed + snap.rejected
                            + snap.expired)


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------
def test_autoscale_policy_bounds_and_hysteresis():
    pol = AutoscalePolicy(min_workers=2, max_workers=6, target_wait_s=1.0,
                          idle_wait_s=0.2, step_frac=1.0, cooldown_s=5.0,
                          settle_reads=2)

    def load(t, *, serving, backlog, spare=10, util=0.0, depth=0):
        return FleetLoad(sim_t=t, serving=serving, warming=0, spare=spare,
                         queue_depth=depth, backlog_s=backlog,
                         backlog_max_s=backlog, hot_frac=0.0, util_mean=util)

    acts = pol.step(load(0.0, serving=2, backlog=9.0))
    assert [a.kind for a in acts] == ["scale_up"]
    assert acts[0].detail["n"] == 2                # step_frac, within max
    assert pol.step(load(1.0, serving=4, backlog=9.0)) == []   # cooldown
    acts = pol.step(load(6.0, serving=4, backlog=9.0))
    assert acts[0].detail["n"] == 2                # clipped at max_workers=6
    assert pol.step(load(12.0, serving=6, backlog=9.0)) == []  # at the cap
    # scale-down needs settle_reads consecutive idle readings
    assert pol.step(load(20.0, serving=6, backlog=0.0)) == []
    acts = pol.step(load(21.0, serving=6, backlog=0.0))
    assert [a.kind for a in acts] == ["scale_down"]
    assert acts[0].detail["n"] == 4                # down to min_workers=2
    # a burst resets the idle streak
    assert pol.step(load(30.0, serving=2, backlog=9.0, spare=0)) == []


def test_fleet_scales_up_with_warm_delay_and_retires_down_to_min():
    link_bw = _profile().link_bw
    warm_bytes = 2.0 * link_bw                     # 2 sim-seconds per row
    fleet = SimFleet(
        make_rows(_spec(decode=2.0, max_batch=2, max_queue=64), 8),
        n_start=2, tick_s=0.1, admission=False,
        autoscaler=AutoscalePolicy(min_workers=2, max_workers=6,
                                   target_wait_s=0.1, idle_wait_s=0.05,
                                   step_frac=1.0, cooldown_s=0.0,
                                   settle_reads=2),
        autoscale_every_s=0.1, warm_param_bytes=warm_bytes)
    for _ in range(40):
        fleet.submit(4, 20)
    fleet.tick()
    assert fleet.scale_ups >= 1
    assert int(fleet.alive.sum()) > 2
    # warming rows are provisioned but not serving until params land
    assert int(fleet._serving_mask().sum()) == 2
    assert fleet.warm_bytes_total == warm_bytes * (int(fleet.alive.sum()) - 2)
    for _ in range(25):                            # ~2.5s: params arrive
        fleet.tick()
    assert int(fleet._serving_mask().sum()) > 2
    for _ in range(2000):                          # drain + go idle
        fleet.tick()
        if fleet.idle() and int(fleet._serving_mask().sum()) == 2:
            break
    snap = fleet.snapshot()
    assert snap.peak_serving <= 6                  # max_workers held
    assert snap.scale_downs >= 1 and snap.retired >= 1
    assert snap.serving_now == 2                   # back at min_workers
    assert snap.completed == 40                    # nothing lost on the way


# ---------------------------------------------------------------------------
# drivers: sim clocks never sleep
# ---------------------------------------------------------------------------
class _StubEngine:
    """Minimal drive_open_loop surface: clock/active/step/scheduler."""

    def __init__(self, clock):
        self.clock = clock
        self.scheduler = SimpleNamespace(depth=0)
        self.submitted = []

    def active(self) -> bool:
        return False

    def step(self) -> bool:
        return False


def test_drive_open_loop_sim_clock_advances_instead_of_sleeping(monkeypatch):
    def boom(_):
        raise AssertionError("slept under a simulated clock")
    monkeypatch.setattr(time, "sleep", boom)
    eng = _StubEngine(SimClock())
    arrivals = [0.0, 5.0, 9.0]
    elapsed = drive_open_loop(eng, arrivals,
                              lambda i, now: eng.submitted.append((i, now)))
    assert [i for i, _ in eng.submitted] == [0, 1, 2]
    assert elapsed >= 9.0                          # jumped, not napped


def test_drive_open_loop_wall_clock_kw_is_gone():
    # deprecated in PR 7, removed with repro-lint R002: pacing is always
    # engine.clock, so the legacy escape hatch must not silently return
    eng = _StubEngine(SimClock())
    with pytest.raises(TypeError, match="wall_clock"):
        drive_open_loop(eng, [0.0], lambda i, now: None, wall_clock=False)


def test_drive_open_loop_rejects_sim_clock_without_advance():
    eng = _StubEngine(lambda: 0.0)                 # sim-paced, no advance()
    with pytest.raises(TypeError, match="advance"):
        drive_open_loop(eng, [0.0, 1.0], lambda i, now: None)


# ---------------------------------------------------------------------------
# SLO report math
# ---------------------------------------------------------------------------
def test_slo_report_folds_outcomes_per_class():
    specs = (SLOClass("a", ttft_s=1.0, tpot_s=0.1), SLOClass("b"))
    report = slo_report(
        specs,
        class_ids=[0, 0, 0, 1],
        ttft_s=[0.5, 2.0, float("nan"), 0.2],
        tpot_s=[0.05, 0.05, float("nan"), float("nan")],
        tokens=[10, 10, 0, 5],
        outcome=[OUTCOME_DONE, OUTCOME_DONE, OUTCOME_SHED, OUTCOME_DONE],
        span_s=10.0)
    a, b = report.classes
    assert (a.offered, a.completed, a.shed) == (3, 2, 1)
    assert a.met == 1                              # 2.0s TTFT blows the SLO
    assert a.attainment == pytest.approx(1 / 3)
    assert a.served_attainment == pytest.approx(1 / 2)
    assert b.met == 1                              # no limits: done == met
    assert report.offered == 4 and report.met == 2
    assert report.attainment == pytest.approx(0.5)
    assert report.goodput_tokens_per_s == pytest.approx(1.5)   # met tokens
    assert report.tokens_per_s == pytest.approx(2.5)           # all tokens
