"""int8 gradient compression + error feedback: bounds and convergence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, compress


@given(st.integers(0, 1000), st.floats(1e-6, 1e3))
@settings(max_examples=30, deadline=None)
def test_quantize_error_bound(seed, scale):
    g = scale * jax.random.normal(jax.random.key(seed), (64,))
    q, s = compress.quantize(g)
    deq = compress.dequantize(q, s)
    # absolute error bounded by half a quantisation step
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates_residual():
    g = jnp.array([1.0, 1e-4, -1e-4, 0.5])       # tiny entries underflow int8
    e = compress.init_error(g)
    q, s, e2 = compress.compress_tree(g, e)
    deq = compress.decompress_tree(q, s)
    np.testing.assert_allclose(np.asarray(deq + e2), np.asarray(g), rtol=1e-6)


def test_sgd_with_ef_converges_like_uncompressed():
    """Quadratic descent: int8+EF must track the uncompressed trajectory."""
    def run(compressed: bool, steps=300, lr=0.05):
        w = jnp.array([3.0, -2.0, 1.0, -0.5])
        e = compress.init_error(w)
        for _ in range(steps):
            g = 2 * w                               # d/dw ||w||^2
            if compressed:
                q, s, e = compress.compress_tree(g, e)
                g = compress.decompress_tree(q, s)
            w = w - lr * g
        return float(jnp.sum(w ** 2))

    assert run(True) < 1e-4
    assert abs(run(True) - run(False)) < 1e-4


def test_wire_saving():
    g = {"a": jnp.zeros((1024, 64)), "b": jnp.zeros((128,))}
    bf16, int8 = compress.wire_bytes_saved(g)
    assert bf16 / int8 > 1.9                        # ~2x vs bf16, 4x vs fp32
