"""int8 gradient compression + error feedback: bounds and convergence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, compress


@given(st.integers(0, 1000), st.floats(1e-6, 1e3))
@settings(max_examples=30, deadline=None)
def test_quantize_error_bound(seed, scale):
    g = scale * jax.random.normal(jax.random.key(seed), (64,))
    q, s = compress.quantize(g)
    deq = compress.dequantize(q, s)
    # absolute error bounded by half a quantisation step
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates_residual():
    g = jnp.array([1.0, 1e-4, -1e-4, 0.5])       # tiny entries underflow int8
    e = compress.init_error(g)
    q, s, e2 = compress.compress_tree(g, e)
    deq = compress.decompress_tree(q, s)
    np.testing.assert_allclose(np.asarray(deq + e2), np.asarray(g), rtol=1e-6)


def test_sgd_with_ef_converges_like_uncompressed():
    """Quadratic descent: int8+EF must track the uncompressed trajectory."""
    def run(compressed: bool, steps=300, lr=0.05):
        w = jnp.array([3.0, -2.0, 1.0, -0.5])
        e = compress.init_error(w)
        for _ in range(steps):
            g = 2 * w                               # d/dw ||w||^2
            if compressed:
                q, s, e = compress.compress_tree(g, e)
                g = compress.decompress_tree(q, s)
            w = w - lr * g
        return float(jnp.sum(w ** 2))

    assert run(True) < 1e-4
    assert abs(run(True) - run(False)) < 1e-4


def test_all_zero_leaf_is_exact_and_residual_free():
    """An all-zero gradient must survive the 0-safe scale floor exactly:
    deq == 0 bit-for-bit and the error-feedback residual stays zero."""
    g = jnp.zeros((16, 4))
    q, s = compress.quantize(g)
    deq = compress.dequantize(q, s)
    assert float(jnp.abs(deq).max()) == 0.0
    assert np.isfinite(float(s)) and float(s) > 0.0
    _, _, e2 = compress.compress_tree({"w": g}, compress.init_error({"w": g}))
    assert float(jnp.abs(e2["w"]).max()) == 0.0


def test_nonfinite_entries_do_not_poison_scale_or_residual():
    """NaN/±inf entries quantise as zero; the scale reflects the FINITE
    absmax and the residual stays finite (a diverged step must not wreck
    every later round through the error-feedback state)."""
    g = jnp.array([1.0, jnp.nan, jnp.inf, -jnp.inf, -0.25])
    q, s = compress.quantize(g)
    assert np.isfinite(float(s))
    # scale from the finite absmax (1.0), not inf
    np.testing.assert_allclose(float(s), 1.0 / 127.0, rtol=1e-6)
    deq = np.asarray(compress.dequantize(q, s))
    assert np.isfinite(deq).all()
    np.testing.assert_allclose(deq[[1, 2, 3]], 0.0)
    _, _, e2 = compress.compress_tree(g, compress.init_error(g))
    e2 = np.asarray(e2)
    assert np.isfinite(e2).all()
    # next round with a clean gradient stays finite end to end
    g2 = jnp.ones_like(g)
    q2, s2, e3 = compress.compress_tree(g2, jnp.asarray(e2))
    assert np.isfinite(float(s2))
    assert np.isfinite(np.asarray(e3)).all()


def test_dequantize_round_trip_bound_pinned():
    """Pinned round-trip contract: |deq - g| <= absmax/254 + eps for any
    finite input (half a quantisation step of the absmax/127 scale)."""
    rng = np.random.default_rng(7)
    for shape in [(64,), (8, 8), (3, 5, 7)]:
        g = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 10.0)
        q, s = compress.quantize(g)
        absmax = float(jnp.abs(g).max())
        bound = absmax / 254.0 + 1e-6
        assert float(jnp.abs(compress.dequantize(q, s) - g).max()) <= bound


def test_wire_saving():
    g = {"a": jnp.zeros((1024, 64)), "b": jnp.zeros((128,))}
    bf16, int8 = compress.wire_bytes_saved(g)
    assert bf16 / int8 > 1.9                        # ~2x vs bf16, 4x vs fp32
