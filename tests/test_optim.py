import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_quadratic_descent():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, schedule="const",
                            weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    opt = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-3


def test_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0, schedule="const")
    params = {"w": jnp.zeros(4)}
    opt = adamw.init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, stats = adamw.update(cfg, g, opt, params)
    assert float(stats["grad_norm"]) > 100


def test_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine")
    lrs = [float(adamw.lr_at(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0            # warmup rises
    assert lrs[99] < 0.01                    # decays to ~0
    assert max(lrs) <= 1.0


def test_moments_dtype_fp32():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    opt = adamw.init(params)
    assert opt["m"]["w"].dtype == jnp.float32
