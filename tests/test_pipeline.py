"""Multi-device pipeline tests (subprocess: needs >1 host device).

The heavyweight numerical check lives in tests/pp_check.py; here we run it
for the paper-critical cases and check the gspmd_pp stacked pipeline.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(script_args, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable] + script_args, cwd=ROOT,
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_pp_hybrid_and_gpipe_granite():
    out = _run(["tests/pp_check.py", "granite-8b", "gpipe,hybrid"])
    assert "OK" in out


@pytest.mark.slow
def test_pp_hybrid_rwkv():
    out = _run(["tests/pp_check.py", "rwkv6-1.6b", "hybrid"])
    assert "OK" in out


@pytest.mark.slow
def test_gspmd_pp_moe():
    out = _run(["tests/gpp_check.py", "grok-1-314b"])
    assert "OK" in out
