"""Heterogeneous partitioner: reproduces the paper's split decisions."""
import jax
from hypothesis import given, settings, strategies as st

from repro.configs.resnet34 import CONFIG
from repro.core.partition import (pipeline_batch_seconds, plan_pipeline,
                                  single_device_seconds, split_blocks)
from repro.hw.specs import IPHONE_11_PRO, IPHONE_16, XEON_E3_1225V3
from repro.models.resnet import block_costs, init_resnet


def _costs():
    meta, params = init_resnet(CONFIG, jax.random.key(0))
    return block_costs(CONFIG, meta, params, batch=16)   # paper microbatch 16


def test_paper_reproduction_calibrated():
    """Validate against the paper's OWN numbers (appendix A.1): rates
    calibrated on the desktop pairs must predict the HELD-OUT pairs
    (mac+iPhone16 train; desktop+iPhone11 inference) within 25%.
    (The paper's Table-1 TFLOPS ratings alone CANNOT reproduce its timings
    — the Xeon sustains 3.5x its rated flops — recorded in EXPERIMENTS.md.)"""
    from repro.core.calibrate import reproduction_table
    rows = {r["setup"]: r for r in reproduction_table()}
    for name in ("desktop_alone", "mac_alone", "desktop_iph11",
                 "desktop_iph16"):
        assert rows[name]["rel_err"] < 0.02, rows[name]      # fit quality
    for name in ("mac_iph16", "desktop_alone_infer", "desktop_iph11_infer"):
        assert rows[name]["held_out"] and rows[name]["rel_err"] < 0.25,             rows[name]
    # paper's headline ordering: iPhone16 helps more than iPhone11
    assert rows["desktop_iph16"]["predicted_s"] < rows["desktop_iph11"]["predicted_s"]


def test_paper_split_region():
    """Stronger phone -> cut no later (paper: iPhone16 took MORE layers);
    calibrated rates put both cuts strictly inside the block list."""
    from repro.core.calibrate import calibrated_profiles
    costs = _costs()
    profs = calibrated_profiles()
    c11 = split_blocks(costs, [profs["xeon"], profs["iphone11"]],
                       efficiency=1.0).cuts[0]
    c16 = split_blocks(costs, [profs["xeon"], profs["iphone16"]],
                       efficiency=1.0).cuts[0]
    assert c16 <= c11
    assert 0 < c16 <= c11 < len(costs)


@given(st.integers(2, 4), st.integers(5, 18), st.integers(1, 100))
@settings(max_examples=30, deadline=None)
def test_split_invariants(n_dev, n_blocks, seed):
    import numpy as np
    rng = np.random.default_rng(seed)
    costs = [(float(f), float(b)) for f, b in
             zip(rng.uniform(1e9, 1e11, n_blocks), rng.uniform(1e4, 1e7, n_blocks))]
    devs = [XEON_E3_1225V3, IPHONE_11_PRO, IPHONE_16, IPHONE_16][:n_dev]
    plan = split_blocks(costs, devs)
    assert len(plan.cuts) == n_dev - 1
    assert list(plan.cuts) == sorted(set(plan.cuts))
    assert all(0 < c < n_blocks for c in plan.cuts)
    # bottleneck really is the max
    assert abs(plan.bottleneck
               - max(s + (plan.comm_seconds[i] if i < n_dev - 1 else 0)
                     for i, s in enumerate(plan.stage_seconds))) < 1e-12


@given(st.integers(2, 96), st.sampled_from([4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_plan_pipeline_invariants(n_layers, model_axis):
    plan = plan_pipeline(n_layers, model_axis)
    assert plan.n_stages * plan.replicas == model_axis
    assert plan.slots >= n_layers
    assert plan.n_pad == plan.slots - n_layers
    assert plan.n_pad < plan.n_stages       # never a whole empty stage
