"""Heterogeneous partitioner: reproduces the paper's split decisions."""
import jax
from hypothesis import given, settings, strategies as st

from repro.configs.resnet34 import CONFIG
from repro.core.partition import (pipeline_batch_seconds, plan_pipeline,
                                  single_device_seconds, split_blocks,
                                  split_decode)
from repro.hw.specs import IPHONE_11_PRO, IPHONE_16, XEON_E3_1225V3
from repro.models.resnet import block_costs, init_resnet


def _costs():
    meta, params = init_resnet(CONFIG, jax.random.key(0))
    return block_costs(CONFIG, meta, params, batch=16)   # paper microbatch 16


def test_paper_reproduction_calibrated():
    """Validate against the paper's OWN numbers (appendix A.1): rates
    calibrated on the desktop pairs must predict the HELD-OUT pairs
    (mac+iPhone16 train; desktop+iPhone11 inference) within 25%.
    (The paper's Table-1 TFLOPS ratings alone CANNOT reproduce its timings
    — the Xeon sustains 3.5x its rated flops — recorded in EXPERIMENTS.md.)"""
    from repro.core.calibrate import reproduction_table
    rows = {r["setup"]: r for r in reproduction_table()}
    for name in ("desktop_alone", "mac_alone", "desktop_iph11",
                 "desktop_iph16"):
        assert rows[name]["rel_err"] < 0.02, rows[name]      # fit quality
    for name in ("mac_iph16", "desktop_alone_infer", "desktop_iph11_infer"):
        assert rows[name]["held_out"] and rows[name]["rel_err"] < 0.25,             rows[name]
    # paper's headline ordering: iPhone16 helps more than iPhone11
    assert rows["desktop_iph16"]["predicted_s"] < rows["desktop_iph11"]["predicted_s"]


def test_paper_split_region():
    """Stronger phone -> cut no later (paper: iPhone16 took MORE layers);
    calibrated rates put both cuts strictly inside the block list."""
    from repro.core.calibrate import calibrated_profiles
    costs = _costs()
    profs = calibrated_profiles()
    c11 = split_blocks(costs, [profs["xeon"], profs["iphone11"]],
                       efficiency=1.0).cuts[0]
    c16 = split_blocks(costs, [profs["xeon"], profs["iphone16"]],
                       efficiency=1.0).cuts[0]
    assert c16 <= c11
    assert 0 < c16 <= c11 < len(costs)


@given(st.integers(2, 4), st.integers(5, 18), st.integers(1, 100))
@settings(max_examples=30, deadline=None)
def test_split_invariants(n_dev, n_blocks, seed):
    import numpy as np
    rng = np.random.default_rng(seed)
    costs = [(float(f), float(b)) for f, b in
             zip(rng.uniform(1e9, 1e11, n_blocks), rng.uniform(1e4, 1e7, n_blocks))]
    devs = [XEON_E3_1225V3, IPHONE_11_PRO, IPHONE_16, IPHONE_16][:n_dev]
    plan = split_blocks(costs, devs)
    assert len(plan.cuts) == n_dev - 1
    assert list(plan.cuts) == sorted(set(plan.cuts))
    assert all(0 < c < n_blocks for c in plan.cuts)
    # bottleneck really is the max
    assert abs(plan.bottleneck
               - max(s + (plan.comm_seconds[i] if i < n_dev - 1 else 0)
                     for i, s in enumerate(plan.stage_seconds))) < 1e-12


# ---------------------------------------------------------------------------
# decode-mode split (serving)
# ---------------------------------------------------------------------------
def _decode_costs(n_blocks, mem_per_block, frame=4096.0):
    return [(1.0 / n_blocks, frame, mem_per_block)] * n_blocks


def test_split_decode_paper_pair_puts_more_layers_on_faster_phone():
    """On the paper's own device numbers (Table 1 serving rates), the
    decode search mirrors its hand-tuned asymmetry: the phone outrates
    the Xeon (30 vs 6 steps/s), so it takes MOST of the layers — and the
    stronger iPhone 16 takes at least as many as the iPhone 11 (the
    paper's 'entire layer 3' vs 'before the 4th block of layer 3'
    direction)."""
    costs = _decode_costs(12, mem_per_block=64e6)     # fits everywhere
    c11 = split_decode(costs, [XEON_E3_1225V3, IPHONE_11_PRO]).cuts[0]
    c16 = split_decode(costs, [XEON_E3_1225V3, IPHONE_16]).cuts[0]
    assert c11 < 6                     # phone (stage 1) holds the majority
    assert c16 <= c11                  # stronger phone: no fewer layers
    assert 0 < c16 <= c11 < 12


def test_split_decode_memory_wall_constrains_the_phone():
    """The §4.3 memory wall: when the model exceeds the iPhone 11's 2 GB,
    the rate-optimal cut is INFEASIBLE and the search trades step time
    for a cut whose phone stage fits — more layers stay on the host."""
    free = split_decode(_decode_costs(12, 64e6),
                        [XEON_E3_1225V3, IPHONE_11_PRO])
    tight = split_decode(_decode_costs(12, 300e6),    # 3.6 GB model > 2 GB
                         [XEON_E3_1225V3, IPHONE_11_PRO])
    assert free.feasible and tight.feasible
    assert tight.cuts[0] > free.cuts[0]
    assert tight.stage_mem_bytes[1] <= IPHONE_11_PRO.mem_bytes
    assert sum(c[2] for c in _decode_costs(12, 300e6)) \
        > IPHONE_11_PRO.mem_bytes
    # and the feasibility machinery reports honestly when NOTHING fits
    hopeless = split_decode(_decode_costs(4, 40e9),
                            [XEON_E3_1225V3, IPHONE_11_PRO])
    assert not hopeless.feasible


def test_split_decode_invariants_and_fixed_mem():
    devs = [XEON_E3_1225V3, IPHONE_11_PRO, IPHONE_16]
    costs = _decode_costs(9, 1e6)
    plan = split_decode(costs, devs, stage_fixed_mem=(5e6, 0.0, 7e6))
    assert list(plan.cuts) == sorted(set(plan.cuts))
    assert len(plan.cuts) == 2 and all(0 < c < 9 for c in plan.cuts)
    # sequential decode: per-token latency is the SUM, not the bottleneck
    assert abs(plan.step_seconds
               - (sum(plan.stage_seconds) + sum(plan.comm_seconds))) < 1e-15
    assert plan.stage_mem_bytes[0] >= 5e6
    assert plan.stage_mem_bytes[-1] >= 7e6
    # derated devices shift layers off the slowed stage
    slowed = [XEON_E3_1225V3, IPHONE_11_PRO.derate(8.0)]
    base = split_decode(_decode_costs(12, 1e6),
                        [XEON_E3_1225V3, IPHONE_11_PRO])
    hot = split_decode(_decode_costs(12, 1e6), slowed)
    assert hot.cuts[0] > base.cuts[0]


@given(st.integers(2, 96), st.sampled_from([4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_plan_pipeline_invariants(n_layers, model_axis):
    plan = plan_pipeline(n_layers, model_axis)
    assert plan.n_stages * plan.replicas == model_axis
    assert plan.slots >= n_layers
    assert plan.n_pad == plan.slots - n_layers
    assert plan.n_pad < plan.n_stages       # never a whole empty stage
