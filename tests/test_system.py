"""End-to-end behaviour tests: training descends, checkpoint/restart is
exact, failure mid-run recovers (the paper's system stitched together)."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.data.synthetic import DataConfig, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw
from repro.runtime.faults import FaultPlan
from repro.runtime.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def setup():
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    oc = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                           weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
        p2, o2, st = adamw.update(oc, g, opt, params)
        return p2, o2, dict(loss=loss, **st)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8,
                      seed=3)
    pipe = TokenPipeline(dcfg)

    def data_iter(start):
        def gen():
            s = start
            while True:
                yield {"tokens": jnp.asarray(pipe.batch(s)["tokens"])}
                s += 1
        return iter(gen())

    def init_state():
        p = model.init(jax.random.key(0))
        return p, adamw.init(p)

    return model, step_fn, init_state, data_iter


def test_train_descends_and_recovers(setup, tmp_path):
    model, step_fn, init_state, data_iter = setup
    faults = FaultPlan(fail_at={18: "worker0"})
    tr = Trainer(TrainerConfig(total_steps=40, ckpt_every=8,
                               ckpt_dir=str(tmp_path), log_every=100),
                 step_fn, init_state, data_iter, fault_plan=faults)
    out = tr.run()
    losses = out["losses"]
    assert tr.restarts == 1
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_resume_is_exact(setup, tmp_path):
    """Checkpoint/restart must be bit-identical to an uninterrupted run."""
    model, step_fn, init_state, data_iter = setup
    d1, d2 = tmp_path / "a", tmp_path / "b"
    # uninterrupted 20 steps
    tr = Trainer(TrainerConfig(total_steps=20, ckpt_every=10,
                               ckpt_dir=str(d1), log_every=100),
                 step_fn, init_state, data_iter)
    ref = tr.run()
    # interrupted at 13 (after the step-10 checkpoint), resumed
    tr2 = Trainer(TrainerConfig(total_steps=20, ckpt_every=10,
                                ckpt_dir=str(d2), log_every=100),
                  step_fn, init_state, data_iter,
                  fault_plan=FaultPlan(fail_at={13: "w"}))
    out = tr2.run()
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
