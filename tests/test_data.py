import numpy as np

from repro.data.synthetic import (DataConfig, FrontendPipeline, ImagePipeline,
                                  Prefetcher, TokenPipeline)


def test_determinism_and_seek():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    np.testing.assert_array_equal(p1.batch(7)["tokens"], p2.batch(7)["tokens"])
    assert not np.array_equal(p1.batch(7)["tokens"], p1.batch(8)["tokens"])


def test_sharding_partition():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=2)
    shards = [TokenPipeline(cfg, shard=i, n_shards=4).batch(3)["tokens"]
              for i in range(4)]
    assert all(s.shape == (2, 16) for s in shards)
    flat = [tuple(r) for s in shards for r in s]
    assert len(set(flat)) == len(flat)          # disjoint rows


def test_bigram_structure_learnable():
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=4, seed=0,
                     branching=4)
    p = TokenPipeline(cfg)
    toks = p.batch(0)["tokens"]
    ok = sum(toks[i, t + 1] in p.table[toks[i, t]]
             for i in range(4) for t in range(127))
    assert ok == 4 * 127                         # every transition from table


def test_frontend_pipeline():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    p = FrontendPipeline(cfg, frontend_seq=8, d_model=32)
    b = p.batch(0)
    assert b["frontend"].shape == (4, 8, 32)
    assert b["tokens"].shape == (4, 16)


def test_prefetcher():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    p = TokenPipeline(cfg)

    def gen():
        for s in range(5):
            yield p.batch(s)

    got = list(Prefetcher(iter(gen())))
    assert len(got) == 5


def test_images():
    p = ImagePipeline(n_classes=10, img_size=16, batch=8)
    x, y = p.batch_at(0)
    assert x.shape == (8, 16, 16, 3) and y.shape == (8,)
    x2, _ = p.batch_at(0)
    np.testing.assert_array_equal(x, x2)
