"""Blockwise attention oracle vs naive sdpa (hypothesis shapes + grads)."""
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models.attention import _repeat_kv, causal_mask, chunk_mask, sdpa
from repro.models.flash_ref import flash_attention_ref


@given(st.integers(1, 2), st.integers(8, 130), st.sampled_from([2, 4]),
       st.sampled_from([1, 2]), st.booleans(), st.sampled_from([0, 32]))
@settings(max_examples=12, deadline=None)
def test_blockwise_matches_naive(b, t, h, gdiv, causal, chunk):
    g = max(1, h // gdiv)
    ks = jax.random.split(jax.random.key(b * t + h), 3)
    q = jax.random.normal(ks[0], (b, t, h, 16), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, g, 16), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, g, 16), jnp.float32)
    if chunk and not causal:
        causal = True
    out = flash_attention_ref(q, k, v, causal=causal, chunk=chunk,
                              block_q=32, block_k=16)
    kk, vv = _repeat_kv(k, h // g), _repeat_kv(v, h // g)
    if chunk:
        mask = chunk_mask(t, t, chunk)[None, None]
    elif causal:
        mask = causal_mask(t, t)[None, None]
    else:
        mask = None
    ref = sdpa(q, kk, vv, mask, 0.25)
    assert float(jnp.abs(out - ref).max()) < 3e-5
