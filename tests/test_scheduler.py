"""Admission scheduler: policy ordering, queue limits, deadlines."""
import numpy as np
import pytest

from repro.serving.engine import Request
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig


def _req(rid, plen=8, priority=0, submitted=None, deadline=None):
    return Request(rid, np.zeros(plen, np.int32), priority=priority,
                   submitted_t=float(rid if submitted is None else submitted),
                   deadline_s=deadline)


def _pop_rids(sched, k=100, now=1000.0):
    return [r.rid for r in sched.pop(k, now)]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        SchedulerConfig(policy="lifo")


def test_fcfs_orders_by_arrival():
    s = AdmissionScheduler(SchedulerConfig(policy="fcfs"))
    for rid, plen in [(0, 9), (1, 3), (2, 7)]:
        s.push(_req(rid, plen), now=float(rid))
    assert _pop_rids(s) == [0, 1, 2]


def test_spf_orders_by_prompt_length():
    s = AdmissionScheduler(SchedulerConfig(policy="spf"))
    for rid, plen in [(0, 9), (1, 3), (2, 7), (3, 3)]:
        s.push(_req(rid, plen), now=float(rid))
    # shortest first; arrival order breaks the 3-vs-3 tie
    assert _pop_rids(s) == [1, 3, 2, 0]


def test_priority_orders_by_class_then_arrival():
    s = AdmissionScheduler(SchedulerConfig(policy="priority"))
    for rid, pr in [(0, 0), (1, 5), (2, 5), (3, 1)]:
        s.push(_req(rid, priority=pr), now=float(rid))
    assert _pop_rids(s) == [1, 2, 3, 0]


def test_pop_takes_at_most_k_and_leaves_rest():
    s = AdmissionScheduler(SchedulerConfig(policy="fcfs"))
    for rid in range(5):
        s.push(_req(rid), now=float(rid))
    assert _pop_rids(s, k=2) == [0, 1]
    assert s.depth == 3
    assert _pop_rids(s, k=0) == []
    assert _pop_rids(s) == [2, 3, 4]


def test_max_queue_rejects_at_submit():
    s = AdmissionScheduler(SchedulerConfig(max_queue=2))
    assert s.push(_req(0), 0.0) and s.push(_req(1), 0.0)
    assert not s.push(_req(2), 0.0)
    assert s.depth == 2 and [r.rid for r in s.rejected] == [2]
    assert s.stats() == {"depth": 2, "rejected": 1, "expired": 0}


def test_deadline_drops_expired_at_pop():
    s = AdmissionScheduler(SchedulerConfig())
    s.push(_req(0, submitted=0.0, deadline=5.0), now=0.0)
    s.push(_req(1, submitted=0.0, deadline=50.0), now=0.0)
    s.push(_req(2, submitted=0.0), now=0.0)            # no deadline
    assert _pop_rids(s, now=10.0) == [1, 2]
    assert [r.rid for r in s.expired] == [0]


def test_default_deadline_applied_from_config():
    s = AdmissionScheduler(SchedulerConfig(default_deadline_s=5.0))
    s.push(_req(0, submitted=0.0), now=0.0)
    assert _pop_rids(s, now=10.0) == []
    assert [r.rid for r in s.expired] == [0]


def test_peek_order_has_no_side_effects():
    s = AdmissionScheduler(SchedulerConfig(policy="spf"))
    for rid, plen in [(0, 9), (1, 3)]:
        s.push(_req(rid, plen), now=float(rid))
    assert [r.rid for r in s.peek_order()] == [1, 0]
    assert s.depth == 2
