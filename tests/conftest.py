import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (multi-device tests run in subprocesses).

# ---------------------------------------------------------------------------
# hypothesis guard: the property tests are tier-2 polish; when the plugin is
# missing (bare container, no `pip install -e .[dev]`) collection must still
# succeed and the @given tests must SKIP with a visible reason instead of
# erroring the whole module at import time.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without the dev extra
    import pytest

    _SKIP_REASON = ("hypothesis not installed - property test skipped "
                    "(run `pip install -e .[dev]`)")

    class _AnyStrategy:
        """Stands in for any hypothesis strategy expression at collect time."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

        __ror__ = __or__

    def _given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(reason=_SKIP_REASON)(fn)
        return deco

    def _settings(*a, **k):
        if a and callable(a[0]) and not isinstance(a[0], _AnyStrategy):
            return a[0]                       # bare @settings

        def deco(fn):
            return fn
        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = lambda *a, **k: True
    _stub.note = lambda *a, **k: None
    _stub.example = _given
    _stub.HealthCheck = _AnyStrategy()

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _AnyStrategy()

    _stub.strategies = _st
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st
