"""repro-lint: paired good/bad fixtures per rule, a whole-repo clean run,
and the runtime guards (TraceGuard, seeded_replay_check).

The static-rule tests are jax-free (they exercise the stdlib-only linter
on source strings); only the TraceGuard tests import jax.
"""

from pathlib import Path
from textwrap import dedent

import pytest

from repro.analysis.lint import (BACKEND_REQUIRED_ATTRS,
                                 ENGINE_REQUIRED_ATTRS, lint_paths,
                                 lint_source)
from repro.analysis.lint.cli import run as lint_cli_run
from repro.runtime.guard import (DeterminismError, RetraceError, TraceGuard,
                                 diff_snapshots, seeded_replay_check)

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"

#: a fixture module path inside R002's sim-clock scope
SIM_MOD = "repro/serving/fixture.py"


def rules_of(violations):
    return sorted({v.rule for v in violations})


# ---------------------------------------------------------------------------
# R001 — shared jit wrappers
# ---------------------------------------------------------------------------


def test_r001_flags_jit_in_init():
    bad = dedent("""
        import jax
        class Worker:
            def __init__(self, model):
                self._step = jax.jit(model.apply)
    """)
    vs = lint_source(bad, rules=["R001"])
    assert rules_of(vs) == ["R001"]
    assert "class scope" in vs[0].message


def test_r001_flags_jit_in_plain_function_and_partial():
    bad = dedent("""
        import functools
        import jax
        def build(fn):
            return jax.jit(fn)
        def build2(fn):
            return functools.partial(jax.jit, static_argnums=0)(fn)
    """)
    vs = lint_source(bad, rules=["R001"])
    assert len(vs) == 2


def test_r001_flags_decorator_in_nested_scope():
    bad = dedent("""
        import jax
        def main(model):
            @jax.jit
            def step(params, batch):
                return params
            return step
    """)
    vs = lint_source(bad, rules=["R001"])
    assert len(vs) == 1


def test_r001_allows_module_level_and_lru_cache_factory():
    good = dedent("""
        import functools
        import jax

        @jax.jit
        def decode_one(params, tok):
            return tok

        shared = jax.jit(lambda x: x)

        @functools.lru_cache(maxsize=32)
        def jit_for(model, bucket):
            return jax.jit(lambda p, x: model.apply(p, x))
    """)
    assert lint_source(good, rules=["R001"]) == []


# ---------------------------------------------------------------------------
# R002 — never-sleep / no wall clock in sim modules
# ---------------------------------------------------------------------------


def test_r002_flags_wall_clock_in_sim_scope():
    bad = dedent("""
        import time
        import random
        from datetime import datetime
        def pace(engine):
            time.sleep(0.1)
            t = time.time()
            r = random.random()
            d = datetime.now()
    """)
    vs = lint_source(bad, module=SIM_MOD, rules=["R002"])
    assert len(vs) == 4


def test_r002_ignores_out_of_scope_and_perf_counter():
    code = dedent("""
        import time
        def pace():
            time.sleep(0.1)
    """)
    assert lint_source(code, module="repro/launch/fixture.py",
                       rules=["R002"]) == []
    good = dedent("""
        import time
        def stamp():
            return time.perf_counter()
    """)
    assert lint_source(good, module=SIM_MOD, rules=["R002"]) == []


def test_r002_pragma_needs_a_reason():
    with_reason = dedent("""
        import time
        def pace():
            time.sleep(0.1)  # repro-lint: allow[R002] wall engines nap for real
    """)
    assert lint_source(with_reason, module=SIM_MOD, rules=["R002"]) == []
    without_reason = with_reason.replace(" wall engines nap for real", "")
    vs = lint_source(without_reason, module=SIM_MOD, rules=["R002"])
    assert len(vs) == 1 and "missing a reason" in vs[0].message


def test_r002_tool_loop_async_path_is_allowlisted():
    code = dedent("""
        import time
        def tool_call():
            time.sleep(0.05)
    """)
    assert lint_source(code, module="repro/offload/tools.py",
                       rules=["R002"]) == []


# ---------------------------------------------------------------------------
# R003 — PRNG key discipline
# ---------------------------------------------------------------------------


def test_r003_flags_key_reused_without_rebind():
    bad = dedent("""
        import jax
        def sample(key, logits):
            a = jax.random.categorical(key, logits)
            b = jax.random.categorical(key, logits)
            return a, b
    """)
    vs = lint_source(bad, rules=["R003"])
    assert len(vs) == 1 and "rebind" in vs[0].message


def test_r003_allows_split_rebind_idiom():
    good = dedent("""
        import jax
        def sample(key, logits):
            key, sub = jax.random.split(key)
            a = jax.random.categorical(sub, logits)
            key, sub = jax.random.split(key)
            b = jax.random.categorical(sub, logits)
            return a, b
    """)
    assert lint_source(good, rules=["R003"]) == []


def test_r003_branches_do_not_cross_contaminate():
    good = dedent("""
        import jax
        def sample(key, logits, greedy):
            if greedy:
                return jax.random.categorical(key, logits)
            else:
                return jax.random.categorical(key, logits / 2.0)
    """)
    assert lint_source(good, rules=["R003"]) == []


# ---------------------------------------------------------------------------
# R004 — no implicit host sync in *step* hot paths
# ---------------------------------------------------------------------------


def test_r004_flags_item_cast_and_asarray_in_step():
    bad = dedent("""
        import jax
        import numpy as np
        def decode_step(logits, nxt):
            x = logits.item()
            tok = int(nxt[0])
            host = np.asarray(logits)
            return x, tok, host
    """)
    vs = lint_source(bad, rules=["R004"])
    assert len(vs) == 3


def test_r004_ignores_non_step_functions_and_jax_free_modules():
    code = dedent("""
        import jax
        def finalize(logits):
            return logits.item()
    """)
    assert lint_source(code, rules=["R004"]) == []
    jax_free = dedent("""
        def on_step(x):
            return int(x)
    """)
    assert lint_source(jax_free, rules=["R004"]) == []


def test_r004_allows_host_literal_asarray():
    good = dedent("""
        import jax
        import numpy as np
        def step(slots):
            active = np.asarray([s is not None for s in slots])
            return active
    """)
    assert lint_source(good, rules=["R004"]) == []


# ---------------------------------------------------------------------------
# R005 — Engine/Backend protocol attrs
# ---------------------------------------------------------------------------


def test_r005_flags_engine_missing_required_attrs():
    bad = dedent("""
        class BrokenEngine:
            def __init__(self):
                self.slots = []
    """)
    vs = lint_source(bad, rules=["R005"])
    assert len(vs) == 1
    for attr in ("scheduler", "finished", "max_batch", "metrics"):
        assert attr in vs[0].message


def test_r005_passes_complete_engine_and_inherited_backend():
    good = dedent("""
        class GoodEngine:
            def __init__(self):
                self.scheduler = None
                self.slots = []
                self.finished = []
                self.max_batch = 4
                self.metrics = None

        class BaseBackend:
            name = "base"
            n_blocks = 0
            state_version = 0
            snapshot_free = False

        class ChildBackend(BaseBackend):
            name = "child"
    """)
    assert lint_source(good, rules=["R005"]) == []


def test_r005_mirrors_runtime_required_attrs():
    """The linter's hardcoded mirrors must track the runtime protocol."""
    from repro.serving.backends import CacheBackend
    from repro.serving.engine_api import REQUIRED_ATTRS
    assert tuple(ENGINE_REQUIRED_ATTRS) == tuple(REQUIRED_ATTRS)
    assert tuple(BACKEND_REQUIRED_ATTRS) == tuple(CacheBackend.REQUIRED_ATTRS)


# ---------------------------------------------------------------------------
# R006 — frozen snapshots are immutable outside their defining module
# ---------------------------------------------------------------------------


def test_r006_flags_snapshot_mutation():
    bad = dedent("""
        def tamper(engine):
            snap = engine.metrics_snapshot()
            snap.completed = 0
            return snap
    """)
    vs = lint_source(bad, module="repro/launch/fixture.py", rules=["R006"])
    assert len(vs) == 1 and "replace" in vs[0].message


def test_r006_allows_replace_and_defining_module():
    good = dedent("""
        import dataclasses
        def redact(engine):
            snap = engine.metrics_snapshot()
            return dataclasses.replace(snap, completed=0)
    """)
    assert lint_source(good, module="repro/launch/fixture.py",
                       rules=["R006"]) == []
    mutate = dedent("""
        def fixup(engine):
            snap = engine.metrics_snapshot()
            snap.completed = 0
    """)
    assert lint_source(mutate, module="repro/serving/metrics.py",
                       rules=["R006"]) == []


def test_r006_flags_object_setattr_on_snapshot():
    bad = dedent("""
        def tamper(f):
            snap = FleetSnapshot(sim_t=0.0)
            object.__setattr__(snap, "completed", 9)
    """)
    vs = lint_source(bad, module="repro/launch/fixture.py", rules=["R006"])
    assert len(vs) == 1


# ---------------------------------------------------------------------------
# The repo itself is clean
# ---------------------------------------------------------------------------


def test_whole_repo_lints_clean():
    violations = lint_paths([SRC])
    assert violations == [], "\n".join(v.format() for v in violations)


def test_cli_strict_run_is_clean(capsys):
    assert lint_cli_run(["--strict", str(SRC)]) == 0
    out = capsys.readouterr().out
    assert "0 violation(s)" in out


# ---------------------------------------------------------------------------
# TraceGuard
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def jitted_double():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones((4,)))  # warm the (4,) program
    return f, jnp


def test_trace_guard_warm_path_counts_zero(jitted_double):
    f, jnp = jitted_double
    with TraceGuard(max_retraces=0) as tg:
        f(jnp.ones((4,)))
    assert tg.total == 0 and tg.events == []


def test_trace_guard_catches_deliberate_retrace(jitted_double):
    f, jnp = jitted_double
    with pytest.raises(RetraceError, match="recompile"):
        with TraceGuard(max_retraces=0, name="deliberate") as tg:
            f(jnp.ones((5,)))  # unseen shape: must retrace
    assert tg.total >= 1


def test_trace_guard_observe_mode_and_flag_restore(jitted_double):
    import jax
    f, jnp = jitted_double
    before = jax.config.jax_log_compiles
    with TraceGuard(max_retraces=None) as tg:
        f(jnp.ones((6,)))  # retraces, but observe-only never raises
    assert tg.total >= 1
    assert jax.config.jax_log_compiles == before


# ---------------------------------------------------------------------------
# seeded_replay_check
# ---------------------------------------------------------------------------


def test_seeded_replay_passes_for_pure_sim():
    import numpy as np

    def sim(seed):
        rng = np.random.default_rng(seed)
        return {"served": rng.integers(0, 100, size=8),
                "p99": float(rng.random()), "empty_stat": float("nan")}

    ok, diffs = seeded_replay_check(sim, seed=7)
    assert ok and diffs == []


def test_seeded_replay_catches_hidden_state():
    calls = []

    def impure(seed):
        calls.append(seed)
        return {"n": len(calls)}

    with pytest.raises(DeterminismError, match="seed=3"):
        seeded_replay_check(impure, seed=3)
    ok, diffs = seeded_replay_check(impure, seed=3, strict=False)
    assert not ok and any("n" in d for d in diffs)


def test_seeded_replay_on_sim_fleet_snapshot():
    """End-to-end: the jax-free scale plane really is seed-deterministic."""
    from repro.hw.specs import DeviceProfile
    from repro.serving.scale import ScaleWorkerSpec, SimFleet, play
    from repro.serving.traffic import poisson_trace

    prof = DeviceProfile(name="sim", year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=20.0,
                         prefill_tokens_per_s=1e4)

    def sim(seed):
        trace = poisson_trace(rate_rps=30.0, duration_s=1.0, seed=seed)
        fleet = SimFleet([ScaleWorkerSpec(profile=prof, max_batch=4)
                          for _ in range(2)], tick_s=0.05)
        play(fleet, trace)
        return fleet.snapshot()

    ok, diffs = seeded_replay_check(sim, seed=11)
    assert ok, diffs


def test_diff_snapshots_reports_paths():
    diffs = diff_snapshots({"a": [1, 2], "b": 3}, {"a": [1, 5], "b": 3})
    assert diffs and "a" in diffs[0]
