"""Continuous batching correctness + tool-loop timeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB
from repro.serving.engine import ServeEngine
from repro.serving.tool_loop import run_scenario

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced_config(get_config("granite-8b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


def _naive_greedy(model, params, prompt, n, max_len=48):
    l, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(l[0]))]
    step = jax.jit(model.decode_step)
    for _ in range(n - 1):
        l, cache = step(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(l[0])))
    return toks


def test_continuous_batching_matches_naive(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=5 + i)
               for i in range(4)]
    eng = ServeEngine(model, params, max_batch=2, max_len=48)
    for p in prompts:
        eng.submit(p, max_new=4)
    done = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(done) == 4
    for r, p in zip(done, prompts):
        assert r.out_tokens == _naive_greedy(model, params, p, 4)


def test_tool_loop_async_removes_idle(small_lm):
    model, params = small_lm
    db = VectorDB(n_docs=300, dim=16)
    queries = ["a", "b", "c"]

    def fresh():
        eng = ServeEngine(model, params, max_batch=1, max_len=48)
        ex = ToolExecutor(n_workers=3)
        ex.register("vector_db_begin_search",
                    lambda query, k: db.search_text(query, int(k)),
                    simulated_seconds=0.25)
        return eng, ex

    tr_async = run_scenario(*fresh(), queries, async_tools=True,
                            reason_tokens=6, summary_tokens=8)
    tr_sync = run_scenario(*fresh(), queries, async_tools=False,
                           reason_tokens=6, summary_tokens=8)
    assert tr_sync.time_in("tool_wait") > 0.6
    assert tr_async.time_in("tool_wait") < 0.3 * tr_sync.time_in("tool_wait")
