"""Continuous batching correctness + tool-loop timeline."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB
from repro.serving.engine import ServeEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import SchedulerConfig
from repro.serving.tool_loop import run_scenario

RCFG = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced_config(get_config("granite-8b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=2)
    model = build_model(cfg, RCFG)
    return model, model.init(jax.random.key(0))


def _naive_greedy(model, params, prompt, n, max_len=48):
    v = model.cfg.vocab_size        # logits are pad_vocab-wide; the engine
    #                                 (correctly) never emits pad-column ids
    l, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(l[0, :v]))]
    step = jax.jit(model.decode_step)
    for _ in range(n - 1):
        l, cache = step(params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(l[0, :v])))
    return toks


def test_continuous_batching_matches_naive(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=5 + i)
               for i in range(4)]
    eng = ServeEngine(model, params, max_batch=2, max_len=48)
    for p in prompts:
        eng.submit(p, max_new=4)
    done = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(done) == 4
    for r, p in zip(done, prompts):
        assert r.out_tokens == _naive_greedy(model, params, p, 4)


def test_batched_prefill_gate_excludes_unsafe_families():
    """Right-padded batched prefill must only be offered where padding is
    provably inert: dense full-attention.  MoE pad tokens perturb expert
    routing/capacity; recurrent families fold pads into their state."""
    assert build_model(reduced_config(get_config("granite-8b")),
                       RCFG).decode_state.batched_prefill is not None
    for arch in ("grok-1-314b", "llama4-scout-17b-a16e", "rwkv6-1.6b",
                 "zamba2-7b", "whisper-small", "internvl2-1b"):
        assert build_model(reduced_config(get_config(arch)),
                           RCFG).decode_state.batched_prefill is None, arch


def test_bucketed_prefill_matches_per_request(small_lm):
    """Batched padded prefill must be token-for-token identical to the
    seed's one-dispatch-per-request path, in strictly fewer dispatches."""
    model, params = small_lm
    assert model.decode_state.batched_prefill is not None
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=4 + (3 * i) % 11)
               for i in range(16)]

    def run(m):
        eng = ServeEngine(m, params, max_batch=16, max_len=48)
        for p in prompts:
            eng.submit(p, max_new=4)
        done = eng.run_until_drained()
        return {r.rid: r.out_tokens for r in done}, eng.metrics_snapshot()

    toks_bucketed, snap_b = run(model)
    toks_fallback, snap_f = run(dataclasses.replace(
        model, decode_state=dataclasses.replace(
            model.decode_state, batched_prefill=None)))
    assert toks_bucketed == toks_fallback
    assert snap_f.prefill_dispatches == 16
    assert snap_b.prefill_dispatches < 16
    assert snap_b.prefill_requests == 16
    assert snap_b.prefill_batch_mean > 1.0


def test_engine_sampling_deterministic_and_distinct(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=6) for _ in range(3)]
    # high temperature: the tiny random-weight model is extremely confident,
    # so mild temperatures would still reproduce greedy argmax everywhere
    sp = SamplingParams(temperature=8.0, top_k=64, seed=123)

    def run(seed_offset=0):
        eng = ServeEngine(model, params, max_batch=2, max_len=48)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=5, sampling=dataclasses.replace(
                sp, seed=sp.seed + seed_offset * (i + 1)))
        return {r.rid: r.out_tokens for r in eng.run_until_drained()}

    assert run() == run()                       # fixed seeds -> identical
    greedy = ServeEngine(model, params, max_batch=2, max_len=48)
    for p in prompts:
        greedy.submit(p, max_new=5)
    greedy_toks = {r.rid: r.out_tokens for r in greedy.run_until_drained()}
    assert run() != greedy_toks                 # and actually stochastic


def test_engine_policy_orders_admission(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(3)
    mk = lambda n: rng.integers(0, model.cfg.vocab_size, size=n)

    eng = ServeEngine(model, params, max_batch=1, max_len=48,
                      scheduler=SchedulerConfig(policy="priority"))
    rid_lo = eng.submit(mk(5), max_new=2, priority=0)
    rid_hi = eng.submit(mk(5), max_new=2, priority=9)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [rid_hi, rid_lo]

    eng = ServeEngine(model, params, max_batch=1, max_len=48,
                      scheduler=SchedulerConfig(policy="spf"))
    rid_long = eng.submit(mk(12), max_new=2)
    rid_short = eng.submit(mk(4), max_new=2)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [rid_short, rid_long]


def test_engine_queue_limit_and_metrics_snapshot(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=5) for _ in range(4)]

    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      scheduler=SchedulerConfig(max_queue=3))
    rids = [eng.submit(p, max_new=3) for p in prompts]
    assert rids[3] is None and all(r is not None for r in rids[:3])
    done = eng.run_until_drained()
    snap = eng.metrics_snapshot()
    assert snap.completed == 3
    assert snap.rejected == 1 and snap.expired == 0
    assert snap.generated_tokens == sum(len(r.out_tokens) for r in done) == 9
    assert snap.queue_depth_now == 0
    assert snap.steps == eng.steps > 0
    assert 0.0 < snap.slot_utilization <= 1.0
    assert snap.ttft.count == 3 and snap.ttft.mean > 0.0
    assert snap.tpot.count == 3 and snap.tpot.mean > 0.0
    assert snap.tokens_per_s > 0.0
    assert snap.wall_s > 0.0
    d = snap.as_dict()
    assert d["completed"] == 3 and d["ttft"]["count"] == 3


def test_engine_max_new_one_and_eos_on_first_token(small_lm):
    """max_new=1 must emit exactly one token; a first token equal to eos_id
    must finish the request at admission without a decode step."""
    model, params = small_lm
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, model.cfg.vocab_size, size=7)

    eng = ServeEngine(model, params, max_batch=2, max_len=48)
    eng.submit(prompt, max_new=1)
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out_tokens) == 1
    first_tok = done[0].out_tokens[0]

    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      eos_id=first_tok)
    eng.submit(prompt, max_new=10)
    done = eng.run_until_drained()
    assert done[0].out_tokens == [first_tok]
    assert eng.steps == 0                       # never reached decode

    # an instant finish must refill its lane in the SAME admission round
    eng = ServeEngine(model, params, max_batch=1, max_len=48)
    eng.submit(prompt, max_new=1)
    eng.submit(prompt, max_new=3)
    eng._admit()
    assert len(eng.finished) == 1 and eng.active() == 1


def test_run_until_drained_warns_on_max_steps_exhaustion(small_lm):
    """Exhausting max_steps with work outstanding must raise the PARTIAL
    RuntimeWarning (with live counts) and still return what finished."""
    model, params = small_lm
    rng = np.random.default_rng(7)
    eng = ServeEngine(model, params, max_batch=1, max_len=48)
    eng.submit(rng.integers(0, model.cfg.vocab_size, size=5), max_new=2)
    eng.submit(rng.integers(0, model.cfg.vocab_size, size=5), max_new=20)
    with pytest.warns(RuntimeWarning, match=r"max_steps=3.*1 active.*0 queued"):
        done = eng.run_until_drained(max_steps=3)
    assert len(done) == 1                       # the short request finished
    assert eng.active() == 1                    # the long one is still live
    # a clean drain from here must NOT warn again
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        done = eng.run_until_drained()
    assert len(done) == 2


def test_engine_rejects_buckets_beyond_max_len(small_lm):
    model, params = small_lm
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    prefill_buckets=(16, 64))


def test_engine_deadline_expires_queued_request(small_lm):
    model, params = small_lm
    rng = np.random.default_rng(5)
    eng = ServeEngine(model, params, max_batch=1, max_len=48)
    ok = eng.submit(rng.integers(0, model.cfg.vocab_size, size=5), max_new=2)
    dead = eng.submit(rng.integers(0, model.cfg.vocab_size, size=5),
                      max_new=2, deadline_s=-1.0)   # already expired
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [ok]
    assert [r.rid for r in eng.scheduler.expired] == [dead]
    assert eng.metrics_snapshot().expired == 1


def test_tool_loop_async_removes_idle(small_lm):
    model, params = small_lm
    db = VectorDB(n_docs=300, dim=16)
    queries = ["a", "b", "c"]

    def fresh():
        eng = ServeEngine(model, params, max_batch=1, max_len=48)
        ex = ToolExecutor(n_workers=3)
        ex.register("vector_db_begin_search",
                    lambda query, k: db.search_text(query, int(k)),
                    simulated_seconds=0.25)
        return eng, ex

    tr_async = run_scenario(*fresh(), queries, async_tools=True,
                            reason_tokens=6, summary_tokens=8)
    tr_sync = run_scenario(*fresh(), queries, async_tools=False,
                           reason_tokens=6, summary_tokens=8)
    # sync waits out 3 x 0.25s sequentially; async overlaps them on 3
    # executor workers, so its floor is ~1/3 of sync (one 0.25s window)
    # minus whatever decode it hides — 0.5 asserts the overlap without
    # racing that floor on a noisy shared CPU
    assert tr_sync.time_in("tool_wait") > 0.6
    assert tr_async.time_in("tool_wait") < 0.5 * tr_sync.time_in("tool_wait")
