import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_roundtrip_exact(tmp_path):
    t = _tree()
    store.save(tmp_path, 5, t, extra={"next_step": 5})
    out, extra = store.restore(tmp_path, like=t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["next_step"] == 5


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        store.save(tmp_path, s, t, keep=3)
    assert store.latest_step(tmp_path) == 5
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 3


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(tmp_path)
    ck.save_async(10, _tree(), extra={"next_step": 10})
    ck.wait()
    assert store.latest_step(tmp_path) == 10
    out, _ = store.restore(tmp_path, like=_tree())
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(_tree()["a"]))


def test_corruption_detected(tmp_path):
    store.save(tmp_path, 1, _tree())
    shard = next(tmp_path.glob("step_*/shard_0.bin"))
    data = bytearray(shard.read_bytes())
    data[40] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(Exception):
        store.restore(tmp_path, like=_tree())


def test_reshard_dtype_cast(tmp_path):
    t = _tree()
    store.save(tmp_path, 1, t)
    like = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32)
                        if a.dtype == jnp.bfloat16 else a, t)
    out, _ = store.restore(tmp_path, like=like)
    assert out["b"]["c"].dtype == np.float32
