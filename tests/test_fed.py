"""Training plane: fed wire frames, fed-avg reduction, serve-while-train.

The jax-free half exercises the SimFleet capacity mirror (select it with
``-k sim or not jax`` in lint-tier CI); the jax half runs real local-SGD
rounds through the FedRoundCoordinator and holds the plane's contracts:
bit-deterministic aggregation under replay, serving token-identity with
training on, and failure-plane composition (dead participants excluded,
healed partitions contributing).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.hw.specs import DeviceProfile
from repro.runtime.faults import KillEvent, KillTrace
from repro.serving.metrics import SLOClass
from repro.serving.scale import (FedSimConfig, ScaleWorkerSpec, SimFleet,
                                 make_rows)


# ---------------------------------------------------------------------------
# SimFleet capacity mirror (jax-free)
# ---------------------------------------------------------------------------
def _sim_profile(prefill=2000.0):
    return DeviceProfile(name="sim", year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=20.0,
                         prefill_tokens_per_s=prefill,
                         thermal_sustained=0.85, thermal_tau_s=60.0)


def _sim_fleet(fed, impl="vector", n=4, kill_trace=None):
    spec = ScaleWorkerSpec(profile=_sim_profile(), max_batch=4, max_queue=16)
    return SimFleet(make_rows(spec, n), tick_s=0.05,
                    slo=(SLOClass("default"),), admission=False,
                    fed=fed, impl=impl, kill_trace=kill_trace,
                    detect_s=0.5, ckpt_every_s=0.5)


def _run_rounds(fleet, rounds, max_ticks=50_000):
    while fleet.fed_rounds < rounds and fleet.ticks < max_ticks:
        fleet.tick()
    return fleet.snapshot()


def test_sim_fed_rounds_complete_and_account():
    fed = FedSimConfig(rounds=3, participants=2, local_steps=2,
                       step_tokens=200, frame_bytes=1 << 16)
    snap = _run_rounds(_sim_fleet(fed), 3)
    assert snap.fed_rounds == 3
    assert snap.fed_deliveries == 6 and snap.fed_excluded == 0
    assert snap.fed_wire_bytes == 6 * (1 << 16)
    assert snap.fed_samples == 6 * 2 * 200
    # compute really was charged: at least the cold seconds of the work
    cold = 2 * fed.flops_mult * 200 / 2000.0
    assert snap.fed_train_s >= 6 * cold * 0.99


def test_sim_fed_loop_vector_bit_identical():
    fed = FedSimConfig(rounds=3, participants=2, local_steps=2,
                       step_tokens=500, frame_bytes=1 << 18)
    a = _run_rounds(_sim_fleet(fed, "loop"), 3)
    b = _run_rounds(_sim_fleet(fed, "vector"), 3)
    assert a == b


def test_sim_fed_off_is_inert():
    """fed=None leaves the snapshot's training fields at zero and the
    tick stream exactly as before the training plane existed."""
    fleet = _sim_fleet(None)
    for _ in range(50):
        fleet.tick()
    snap = fleet.snapshot()
    assert snap.fed_rounds == snap.fed_deliveries == snap.fed_excluded == 0
    assert snap.fed_train_s == 0.0 and snap.fed_wire_bytes == 0
    assert snap.fed_preempt_ticks == 0


def test_sim_fed_training_heats_the_row():
    """Training spend must feed the thermal reservoir: a row grinding fed
    compute gets hotter than an idle one."""
    fed = FedSimConfig(rounds=4, participants=1, local_steps=4,
                       step_tokens=4000, frame_bytes=1 << 16)
    hot = _sim_fleet(fed)
    cold = _sim_fleet(None)
    for _ in range(400):
        hot.tick()
        cold.tick()
    assert hot.fed_train_s > 0
    assert hot.snapshot().heat_max > cold.snapshot().heat_max


def test_sim_fed_detected_kill_excludes_participant():
    # selection ties break to the lowest rows, so 0 and 1 train; a crash
    # on row 0 mid-round (long compute) must fail only its leg
    fed = FedSimConfig(rounds=2, participants=2, local_steps=2,
                       step_tokens=5_000, frame_bytes=1 << 16,
                       round_timeout_s=120.0)
    trace = KillTrace((KillEvent(t_s=2.0, worker=0, kind="crash",
                                 down_s=math.inf),))
    snap = _run_rounds(_sim_fleet(fed, kill_trace=trace), 2)
    assert snap.fed_rounds == 2, "kill lost a round"
    assert snap.fed_excluded >= 1
    assert snap.fed_deliveries >= 2      # survivor + the next clean round
    assert snap.deaths == 1


# ---------------------------------------------------------------------------
# fed wire frames + aggregation (jax, no fleet)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_lm():
    jax = pytest.importorskip("jax")
    from repro.configs import RunConfig, get_config, reduced_config
    from repro.models.api import build_model
    cfg = dataclasses.replace(
        reduced_config(get_config("granite-8b")), n_layers=2)
    model = build_model(cfg, RunConfig(param_dtype="float32",
                                       compute_dtype="float32", remat=False))
    return model, model.init(jax.random.key(0))


def _delta_tree():
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.default_rng(3)
    return {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}


def test_fed_frame_roundtrip_int8_and_bf16():
    pytest.importorskip("jax")
    import jax
    from repro.optim import fed
    delta = _delta_tree()
    for topk in (None, 0.5):
        frame, err = fed.encode_update(delta, mode="int8_ef",
                                       topk_frac=topk)
        assert frame[:4] == fed.FED_MAGIC
        out = fed.decode_update(frame)
        for a, b, e in zip(jax.tree.leaves(delta), jax.tree.leaves(out),
                           jax.tree.leaves(err)):
            # delta = decoded + residual, by error-feedback construction
            np.testing.assert_allclose(np.asarray(a),
                                       np.asarray(b) + np.asarray(e),
                                       atol=1e-6)
    frame, _ = fed.encode_update(delta, mode="bf16")
    out = fed.decode_update(frame)
    for a, b in zip(jax.tree.leaves(delta), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=1e-2)


def test_fed_frame_rejects_garbage():
    pytest.importorskip("jax")
    from repro.optim import fed
    frame, _ = fed.encode_update(_delta_tree(), mode="int8_ef")
    with pytest.raises(fed.FedWireError):
        fed.decode_update(b"NOPE" + frame[4:])
    with pytest.raises(fed.FedWireError):
        fed.decode_update(frame[:4] + bytes([99]) + frame[5:])
    with pytest.raises(fed.FedWireError):
        fed.decode_update(frame[:6])
    with pytest.raises(ValueError):
        fed.encode_update(_delta_tree(), mode="float8")


def test_fed_avg_is_sample_weighted_and_order_free():
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.optim import fed
    # bf16-exact values so the weighted average is checkable in closed form
    d1 = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
    d2 = {"w": jnp.asarray([3.0, 4.0], jnp.float32)}
    u1 = fed.ClientUpdate("a", 1, fed.encode_update(d1, mode="bf16")[0])
    u2 = fed.ClientUpdate("b", 3, fed.encode_update(d2, mode="bf16")[0])
    avg = fed.fed_avg([u1, u2])
    np.testing.assert_allclose(np.asarray(avg["w"]), [2.5, 3.5], atol=1e-6)
    rev = fed.fed_avg([u2, u1])          # delivery order must not matter
    assert np.array_equal(np.asarray(avg["w"]), np.asarray(rev["w"]))
    assert fed.fed_avg([]) is None
    with pytest.raises(ValueError):
        fed.fed_avg([u1, fed.ClientUpdate("a", 2, u1.frame)])
    with pytest.raises(ValueError):
        fed.fed_avg([fed.ClientUpdate("a", 0, u1.frame)])


def test_topk_error_feedback_carries_dropped_mass():
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.optim import compress
    g = {"w": jnp.asarray([10.0, -8.0, 0.2, -0.1], jnp.float32)}
    e = compress.init_error(g)
    q, s, e2 = compress.compress_tree(g, e, topk_frac=0.5)
    qw = np.asarray(q["w"])
    assert np.count_nonzero(qw) == 2             # only the top half survives
    deq = np.asarray(compress.decompress_tree(q, s)["w"])
    # the dropped entries live on, in full, inside the residual
    np.testing.assert_allclose(np.asarray(e2["w"])[2:], [0.2, -0.1],
                               atol=1e-5)
    # and the kept ones round-trip up to one quantisation step
    np.testing.assert_allclose(deq[:2], [10.0, -8.0], atol=10.0 / 127)


# ---------------------------------------------------------------------------
# FedRoundCoordinator on a real fleet (jax)
# ---------------------------------------------------------------------------
def _profile(name):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=20.0,
                         prefill_tokens_per_s=2000.0)


def _coord(model, params, rounds=2, kill_trace=None, **cfg_kw):
    from repro.serving.failover import FailoverConfig
    from repro.serving.fleet import ServingFleet, WorkerSpec
    from repro.serving.train_plane import FedConfig, FedRoundCoordinator
    workers = [WorkerSpec(n, _profile(f"dev-{n}"), max_batch=4)
               for n in ("a", "b", "c")]
    fleet = ServingFleet(model, params, workers, max_len=48, tick_s=0.05,
                         kill_trace=kill_trace,
                         failover=FailoverConfig(checkpoint_every_s=0.5)
                         if kill_trace is not None else None)
    fc = FedConfig(rounds=rounds, local_steps=2, participants=2, batch=2,
                   seq_len=16, lr=0.3, seed=0, **cfg_kw)
    return FedRoundCoordinator(fleet, model, fc)


def test_coordinator_runs_rounds_and_loss_descends(small_lm):
    model, params = small_lm
    coord = _coord(model, params, rounds=3)
    rounds = coord.run_rounds()
    assert len(rounds) == 3 and coord.rounds_done == 3
    assert all(len(r.delivered) == 2 for r in rounds)
    assert rounds[-1].loss_last < rounds[0].loss_first
    assert coord.train_s_total > 0 and coord.wire_bytes_total > 0
    # the trained params are the coordinator's own: serving params on the
    # fleet workers are untouched by design
    assert rounds[0].t_end <= rounds[1].t_begin


def test_coordinator_replay_is_bit_deterministic(small_lm):
    import jax
    model, params = small_lm
    a = _coord(model, params, rounds=2)
    b = _coord(model, params, rounds=2)
    a.run_rounds()
    b.run_rounds()
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert [r.delivered for r in a.rounds] == [r.delivered for r in b.rounds]


def test_serving_tokens_identical_with_training_on(small_lm):
    """The headline serve-while-train contract: interleaved training may
    shift timing, never tokens."""
    from repro.serving.fleet import drive_sim
    model, params = small_lm
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, model.cfg.vocab_size, size=5 + i)
               .astype(np.int32) for i in range(6)]
    arrivals = np.linspace(0.0, 0.4, len(prompts))

    def serve(target):
        drive_sim(target, arrivals,
                  lambda i: target.submit(prompts[i], max_new=6))
        return {rec.req.rid: list(rec.req.out_tokens)
                for rec in target.completed}

    coord = _coord(model, params, rounds=2)
    with_training = serve(coord)
    baseline = serve(_coord(model, params, rounds=2).fleet)
    assert with_training == baseline
    assert coord.rounds_done >= 1            # training really interleaved


def test_mid_round_kill_loses_zero_rounds(small_lm):
    model, params = small_lm
    trace = KillTrace((KillEvent(t_s=0.15, worker="b", kind="crash",
                                 down_s=math.inf),))
    coord = _coord(model, params, rounds=2, kill_trace=trace)
    rounds = coord.run_rounds()
    assert coord.rounds_done == 2, "mid-round kill lost a round"
    hit = [r for r in rounds if "b" in r.excluded]
    assert hit and all("b" not in r.delivered for r in hit)
    # the aggregation weight covers only delivered samples
    for r in hit:
        assert r.samples == len(r.delivered) * coord.cfg.local_steps \
            * coord.cfg.batch


def test_partition_heal_before_deadline_contributes(small_lm):
    model, params = small_lm
    # down for 0.3 s, back well before the heartbeat declares it dead
    # (dead_after 4 * probe 0.25 = 1 s) and before the round deadline
    trace = KillTrace((KillEvent(t_s=0.15, worker="b", kind="partition",
                                 down_s=0.3),))
    coord = _coord(model, params, rounds=2, kill_trace=trace)
    rounds = coord.run_rounds()
    assert coord.rounds_done == 2
    assert coord.exclusions == 0, "healed partition was excluded"
    assert all(len(r.delivered) == 2 for r in rounds)


def test_trainer_clock_is_injectable():
    pytest.importorskip("jax")
    from repro.runtime.trainer import Trainer, TrainerConfig
    ticks = iter([10.0, 10.5, 11.0, 11.25])

    def step_fn(params, opt, batch):
        return params, opt, {"loss": 1.5}

    tr = Trainer(TrainerConfig(worker_name="w0"), step_fn,
                 clock=lambda: next(ticks))
    _, _, rec = tr.train_step({}, {}, None, step=0)
    assert rec["step_s"] == pytest.approx(0.5)
    _, _, rec = tr.train_step({}, {}, None, step=1)
    assert rec["step_s"] == pytest.approx(0.25)
    assert [r["loss"] for r in tr.history] == [1.5, 1.5]
