"""Pallas kernels: interpret-mode correctness timing + analytic TPU roofline
per block shape (no TPU in the container — the roofline columns are the
kernel's design budget: VMEM working set and FLOP:byte ratio)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.hw.specs import TPU_V5E
from repro.kernels.flash_attention import flash_attention_fwd


def main():
    rows = []
    for (t, h, g, d, bq, bk) in [(1024, 8, 2, 128, 128, 512),
                                 (4096, 8, 2, 128, 128, 512)]:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (1, t, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, t, g, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, t, g, d), jnp.bfloat16)
        flops = 4 * t * t * h * d * 0.5              # causal
        hbm = (q.size + 2 * k.size + q.size) * 2
        vmem = (bq * d + 2 * bk * d + bq * bk + bq * d) * 4
        ai = flops / hbm
        tpu_us = max(flops / TPU_V5E.flops, hbm / TPU_V5E.mem_bw) * 1e6
        rows.append([f"flash_t{t}", round(tpu_us, 1),
                     f"AI={ai:.0f}flop/B",
                     f"vmem_tile={vmem/1e3:.0f}KB",
                     f"bound={'compute' if flops/TPU_V5E.flops > hbm/TPU_V5E.mem_bw else 'memory'}"])
    # rwkv/ssd chunk kernels: arithmetic intensity per chunk
    for name, c, k_, v_ in [("rwkv6_c64", 64, 64, 64),
                            ("mamba2_c64", 64, 64, 64)]:
        flops = 2 * (c * c * k_ + c * c * v_ + c * k_ * v_)
        hbm = (4 * c * k_) * 4
        rows.append([name, 0, f"AI={flops/hbm:.1f}flop/B",
                     f"state={k_*v_*4/1e3:.0f}KB", ""])
    emit("kernels", rows, ["name", "us_per_call", "d1", "d2", "d3"])


if __name__ == "__main__":
    main()
