"""Bench regression gate: compare bench JSON outputs against committed
baselines with per-metric tolerance bands.

    PYTHONPATH=src python -m benchmarks.check_regression [names...]

Each committed baseline ``benchmarks/baselines/<name>.json`` declares the
bench JSON it gates (``source``, a file under ``experiments/bench/``) and a
``metrics`` map from dotted paths into that JSON to a band:

* ``{"min": x}`` / ``{"max": x}`` — absolute one-sided bound (for gates
  that mirror the bench's own asserts, and for wall-clock-dependent
  numbers where only a floor is meaningful);
* ``{"equals": v}`` — exact match (token-identity flags, counts);
* ``{"baseline": v, "rel_tol": r}`` — committed expectation with a
  relative band: value must land within ``v * (1 ± r)``.  Add
  ``"direction": "min"`` (or ``"max"``) to only gate the harmful side —
  e.g. goodput may exceed the baseline freely but not undershoot it.

Prints a markdown delta table (also appended to ``$GITHUB_STEP_SUMMARY``
when set, so the CI job summary shows exactly which metric moved and by
how much) and exits non-zero if any metric regressed or went missing.
"""
import argparse
import json
import os
from pathlib import Path

from benchmarks.common import OUT_DIR

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"


def _lookup(doc, path: str):
    cur = doc
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict) and part in cur:
            cur = cur[part]
        else:
            raise KeyError(path)
    return cur


def _fmt(v) -> str:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return str(v)
    if isinstance(v, int):
        return str(v)
    return f"{v:.4g}"


def _check(value, band: dict):
    """Returns (ok, expectation_text, delta_text)."""
    if "equals" in band:
        want = band["equals"]
        return value == want, f"== {_fmt(want)}", ""
    if "min" in band or "max" in band:
        lo, hi = band.get("min"), band.get("max")
        ok = ((lo is None or value >= lo) and (hi is None or value <= hi))
        parts = ([f">= {_fmt(lo)}"] if lo is not None else []) \
            + ([f"<= {_fmt(hi)}"] if hi is not None else [])
        return ok, " and ".join(parts), ""
    base = band["baseline"]
    rel = band.get("rel_tol", 0.0)
    direction = band.get("direction", "both")
    lo = base * (1 - rel) if direction in ("both", "min") else None
    hi = base * (1 + rel) if direction in ("both", "max") else None
    ok = ((lo is None or value >= lo) and (hi is None or value <= hi))
    delta = (value - base) / base if base else float("inf")
    return ok, f"{_fmt(base)} ±{rel:.0%} ({direction})", f"{delta:+.1%}"


def check_one(name: str, bench_dir: Path, rows: list) -> int:
    """Append table rows for one baseline; returns the failure count."""
    spec = json.loads((BASELINE_DIR / f"{name}.json").read_text())
    src = bench_dir / spec["source"]
    if not src.exists():
        rows.append((f"{name}: {spec['source']}", "MISSING", "bench JSON "
                     "not produced", "", "FAIL"))
        return 1
    doc = json.loads(src.read_text())
    failures = 0
    for path, band in spec["metrics"].items():
        try:
            value = _lookup(doc, path)
        except KeyError:
            rows.append((f"{name}: {path}", "MISSING", "metric absent",
                         "", "FAIL"))
            failures += 1
            continue
        ok, want, delta = _check(value, band)
        rows.append((f"{name}: {path}", _fmt(value), want, delta,
                     "ok" if ok else "FAIL"))
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*",
                    help="baseline names (default: every committed baseline)")
    ap.add_argument("--bench-dir", type=Path, default=OUT_DIR)
    args = ap.parse_args(argv)
    names = args.names or sorted(
        p.stem for p in BASELINE_DIR.glob("*.json"))
    if not names:
        raise SystemExit("no baselines found")

    rows = [("metric", "value", "expected", "Δ", "status"),
            ("---", "---", "---", "---", "---")]
    failures = 0
    for name in names:
        failures += check_one(name, args.bench_dir, rows)

    widths = [max(len(str(r[i])) for r in rows) for i in range(5)]
    table = "\n".join(
        "| " + " | ".join(str(c).ljust(w) for c, w in zip(r, widths)) + " |"
        for r in rows)
    verdict = (f"{failures} metric(s) regressed" if failures
               else f"all {len(rows) - 2} metrics within tolerance")
    out = f"### Bench regression check\n\n{table}\n\n**{verdict}**\n"
    print(out)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(out)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
