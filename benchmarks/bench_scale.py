"""Production-scale fleet simulation bench: the CI gate for the scale plane.

Two sections, both on :class:`repro.serving.scale.SimFleet` (no jax — this
bench must run in CI seconds at hundreds of workers):

* **tick_micro** — the vectorization claim.  A 200-worker fleet saturated
  with queued work ticks under both implementations; the numpy
  structure-of-arrays tick must beat the pre-refactor per-worker/per-lane
  Python loop by >= 10x tick-throughput *while producing a bit-identical
  snapshot* (the refactor is a speedup, not a semantics change).
* **autoscale** — the serving story at production shape.  A diurnal load
  curve with MMPP bursts offers >= 10k requests; a fleet starting at 24
  phone workers must autoscale past 100 (params charged over the link as
  warm-up before a new row serves) and hold >= 95% TTFT SLO attainment
  measured against *offered* traffic (admission sheds count as misses).
  The same trace against the same 24 workers without the autoscaler must
  fail that SLO — otherwise the gate proves nothing.

JSON summary lands in ``experiments/bench/scale.json`` and is regression-
gated by ``benchmarks/check_regression.py`` against
``benchmarks/baselines/scale.json``.
"""
import argparse
import json
import time

import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.hw.specs import DeviceProfile
from repro.runtime.elastic import AutoscalePolicy
from repro.serving.metrics import SLOClass
from repro.serving.scale import ScaleWorkerSpec, SimFleet, make_rows, play
from repro.serving.traffic import diurnal_trace, merge_traces, mmpp_trace

# a mid-tier phone, rated at the sustained serving rates the scale story
# needs (the sim is capacity-level: only rates/thermals/link matter)
PHONE = DeviceProfile(
    name="phone-sim", year=2024, flops=1.9e12, mem_bytes=8e9,
    mem_bw=60e9, link_bw=1.25e9, thermal_sustained=0.85, thermal_tau_s=60.0,
    decode_steps_per_s=8.0, prefill_tokens_per_s=4000.0)
PARAM_BYTES = 8e8        # ~800 MB of params streamed to every scaled-up row


def bench_tick_micro(smoke: bool):
    n_workers = 200
    n_requests = 20_000 if not smoke else 12_000
    settle, timed = 5, 30
    spec = ScaleWorkerSpec(
        profile=DeviceProfile(
            name="phone-sim-fast", year=2024, flops=1.9e12, mem_bytes=8e9,
            mem_bw=60e9, link_bw=1.25e9, thermal_sustained=0.85,
            thermal_tau_s=60.0, decode_steps_per_s=30.0,
            prefill_tokens_per_s=8000.0),
        max_batch=8, max_queue=128)

    def build(impl):
        f = SimFleet(make_rows(spec, n_workers), tick_s=0.5, impl=impl,
                     slo=(SLOClass("default"),), admission=False)
        rng = np.random.default_rng(0)
        for p, m in zip(rng.integers(16, 64, n_requests),
                        rng.integers(64, 256, n_requests)):
            f.submit(int(p), int(m))
        return f

    per_tick = {}
    for impl in ("vector", "loop"):
        f = build(impl)
        for _ in range(settle):
            f.tick()
        t0 = time.perf_counter()
        for _ in range(timed):
            f.tick()
        per_tick[impl] = (time.perf_counter() - t0) / timed
    speedup = per_tick["loop"] / per_tick["vector"]

    a, b = build("vector"), build("loop")
    for _ in range(settle + timed):
        a.tick()
        b.tick()
    identical = a.snapshot() == b.snapshot()
    assert identical, "vectorized tick diverged from the loop baseline"
    assert speedup >= 10.0, (
        f"vectorized tick must be >= 10x the loop baseline at "
        f"{n_workers} workers, got {speedup:.1f}x")

    rows = [["scale_tick_micro", round(per_tick["vector"] * 1e6, 1),
             f"workers={n_workers}", f"queued={n_requests}",
             f"loop_us={per_tick['loop'] * 1e6:.0f}",
             f"speedup={speedup:.1f}", f"identical={identical}"]]
    summary = {
        "workers": n_workers,
        "queued_requests": n_requests,
        "us_per_tick_vector": per_tick["vector"] * 1e6,
        "us_per_tick_loop": per_tick["loop"] * 1e6,
        "speedup": speedup,
        "identical": identical,
    }
    return rows, summary


def _scale_trace(smoke: bool):
    dur = 420.0 if smoke else 840.0
    sizes = dict(prompt_tokens=(16, 96), max_new_tokens=(24, 72))
    base = diurnal_trace(30.0, dur, period_s=dur, depth=0.85, seed=7,
                         **sizes)
    burst = mmpp_trace(0.0, 60.0, dur, calm_dwell_s=90.0, burst_dwell_s=8.0,
                       seed=11, **sizes)
    return merge_traces(base, burst)


def _run_scale(trace, *, autoscale: bool, n_rows=160, n_start=24):
    policy = None
    if autoscale:
        policy = AutoscalePolicy(
            min_workers=n_start, max_workers=n_rows,
            target_wait_s=1.0, idle_wait_s=0.25,
            step_frac=0.35, cooldown_s=2.0, settle_reads=4)
    fleet = SimFleet(
        make_rows(ScaleWorkerSpec(profile=PHONE, max_batch=4, max_queue=64),
                  n_rows),
        n_start=n_start, tick_s=0.1,
        slo=(SLOClass("interactive", ttft_s=4.0, tpot_s=0.5),),
        autoscaler=policy, autoscale_every_s=0.5,
        warm_param_bytes=PARAM_BYTES, impl="vector")
    t0 = time.perf_counter()
    play(fleet, trace)
    wall = time.perf_counter() - t0
    return fleet.snapshot(), wall


def _summarize(snap, wall: float) -> dict:
    cls = snap.slo.classes[0]
    return {
        "wall_s": wall,
        "sim_t": snap.sim_t,
        "offered": snap.offered,
        "completed": snap.completed,
        "shed": snap.shed,
        "rejected": snap.rejected,
        "expired": snap.expired,
        "peak_serving": snap.peak_serving,
        "scale_ups": snap.scale_ups,
        "scale_downs": snap.scale_downs,
        "retired": snap.retired,
        "warm_bytes_total": snap.warm_bytes_total,
        "warm_link_s_total": snap.warm_link_s_total,
        "attainment": snap.slo.attainment,
        "served_attainment": snap.slo.served_attainment,
        "ttft_p50": cls.ttft_p50,
        "ttft_p99": cls.ttft_p99,
        "tpot_p99": cls.tpot_p99,
        "goodput_tokens_per_s": snap.slo.goodput_tokens_per_s,
        "drains": snap.drains,
        "undrains": snap.undrains,
        "heat_max": snap.heat_max,
    }


def bench_autoscale(smoke: bool):
    trace = _scale_trace(smoke)
    on, wall_on = _run_scale(trace, autoscale=True)
    off, wall_off = _run_scale(trace, autoscale=False)

    assert on.offered >= 10_000, f"need >= 10k offered, got {on.offered}"
    assert on.peak_serving >= 100, (
        f"autoscaler must push past 100 workers, got {on.peak_serving}")
    assert on.slo.attainment >= 0.95, (
        f"autoscaled fleet must hold >= 95% SLO attainment, got "
        f"{on.slo.attainment:.3f}")
    assert off.slo.attainment < 0.95, (
        f"the fixed-size baseline must FAIL the SLO (else the gate is "
        f"vacuous), got {off.slo.attainment:.3f}")
    assert on.scale_ups > 0 and on.scale_downs > 0, "autoscaler never acted"
    assert on.warm_bytes_total > 0, "scale-up must charge params on the link"
    ratio = on.slo.goodput_tokens_per_s / max(
        off.slo.goodput_tokens_per_s, 1e-9)
    assert ratio >= 2.0, f"autoscale goodput win too small: {ratio:.2f}x"

    rows = [
        ["scale_autoscale_on", round(wall_on * 1e6, 0),
         f"offered={on.offered}", f"peak={on.peak_serving}",
         f"attainment={on.slo.attainment:.3f}",
         f"shed={on.shed}", f"goodput={on.slo.goodput_tokens_per_s:.0f}"],
        ["scale_autoscale_off", round(wall_off * 1e6, 0),
         f"offered={off.offered}", f"peak={off.peak_serving}",
         f"attainment={off.slo.attainment:.3f}",
         f"shed={off.shed}", f"goodput={off.slo.goodput_tokens_per_s:.0f}"],
    ]
    summary = {
        "trace": {
            "n": len(trace), "duration_s": trace.duration_s,
            "offered_rps": trace.offered_rps,
            "offered_tokens": trace.offered_tokens, "kind": trace.kind,
        },
        "autoscale": _summarize(on, wall_on),
        "baseline": _summarize(off, wall_off),
        "goodput_ratio": ratio,
    }
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized config (still >= 100 workers / >= 10k "
                         "requests — that IS the acceptance bar)")
    args = ap.parse_args(argv)
    micro_rows, micro = bench_tick_micro(args.smoke)
    auto_rows, auto = bench_autoscale(args.smoke)
    rows = micro_rows + auto_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("scale", rows,
         ["name", "us"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "scale.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "tick_micro": micro,
        **auto,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
