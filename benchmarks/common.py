"""Shared benchmark utilities: timing + CSV emission."""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def emit(name: str, rows, header=None):
    """Print ``name,us_per_call,derived`` CSV rows + save to experiments/."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    lines = []
    for row in rows:
        lines.append(",".join(str(x) for x in row))
    text = "\n".join(lines)
    (OUT_DIR / f"{name}.csv").write_text(
        (",".join(header) + "\n" if header else "") + text + "\n")
    for line in lines:
        print(f"{name},{line}")


def timeit(fn, n=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6        # us
