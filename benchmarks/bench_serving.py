"""Serving engine under load: throughput/latency across admission policies.

Measurements:

1. **Backlog admission** — a cold 16-request backlog, bucketed batched
   prefill vs the seed's one-dispatch-per-request behaviour.  The batched
   path must admit the same work in strictly fewer prefill dispatches.
2. **Paged vs dense KV at equal memory** — a mixed-length (16-512 token)
   backlog served twice with the SAME total KV budget: dense lanes
   (max_batch x max_len) vs the block-pooled paged layout.  Paged must
   sustain >= 1.5x the mean concurrent lanes, because short requests no
   longer hold a worst-case-length lane.  Also drives a deliberately tiny
   pool to force preemption and checks the preempted greedy requests
   resume token-identically.
3. **Open-loop load sweep** (skipped with ``--smoke``) — Poisson arrivals
   at several offered loads per scheduler policy; TTFT / TPOT / tokens/s /
   queue depth.

``--smoke`` shrinks everything to a CI-runnable size and is the
configuration the ``bench-smoke`` CI job runs (its JSON lands in
``experiments/bench/serving.json`` and is uploaded as an artifact); any
assertion failure or engine crash fails the job.
"""
import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.scheduler import POLICIES, SchedulerConfig
from repro.serving.traffic import drive_open_loop


def _build():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    return cfg, model, model.init(jax.random.key(0))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24)))
            for _ in range(n)]


def _mixed_prompts(cfg, n, lo=16, hi=512, seed=0):
    """Log-uniform lengths in [lo, hi]: mostly short, a heavy tail — the
    distribution where dense per-lane allocation wastes the most."""
    rng = np.random.default_rng(seed)
    lens = np.exp(rng.uniform(np.log(lo), np.log(hi), size=n)).astype(int)
    return [rng.integers(0, cfg.vocab_size, size=int(n_)) for n_ in lens]


def bench_backlog(cfg, model, params, n_requests=16):
    """Cold backlog: dispatches needed to admit everything."""
    rows = []
    no_batch = dataclasses.replace(
        model, decode_state=dataclasses.replace(model.decode_state,
                                                batched_prefill=None))
    for name, m in [("bucketed", model), ("per_request", no_batch)]:
        eng = ServeEngine(m, params, max_batch=n_requests, max_len=64)
        for p in _prompts(cfg, n_requests):
            eng.submit(p, max_new=4)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        rows.append([f"backlog_{name}", round(dt * 1e6, 0),
                     f"dispatches={snap.prefill_dispatches}",
                     f"requests={snap.prefill_requests}",
                     f"ttft_mean={snap.ttft.mean:.4f}s"])
    assert int(rows[0][2].split("=")[1]) < int(rows[1][2].split("=")[1]), \
        "bucketed prefill must use fewer dispatches than per-request"
    return rows


def bench_paged_vs_dense(cfg, model, params, *, smoke: bool):
    """Equal-KV-memory shootout on mixed-length traffic.

    Dense budget = max_batch * max_len cache positions per layer; the paged
    engine gets exactly that many positions as a block pool but 4x the
    lanes, so admission is bound by live tokens instead of lane count.
    """
    dense_lanes = 4 if smoke else 8
    max_new = 4 if smoke else 8
    max_len = 544                              # 512-token prompts + headroom
    n_req = 16 if smoke else 48
    block = 16
    budget = dense_lanes * max_len             # KV positions per layer
    prompts = _mixed_prompts(cfg, n_req, seed=1)

    def drain(eng):
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        eng.run_until_drained(max_steps=100_000)
        dt = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        assert snap.completed == n_req, \
            f"engine dropped work: {snap.completed}/{n_req}"
        return dt, snap

    dt_d, snap_d = drain(ServeEngine(model, params, max_batch=dense_lanes,
                                     max_len=max_len))
    dt_p, snap_p = drain(ServeEngine(
        model, params, max_batch=4 * dense_lanes, max_len=max_len,
        config=EngineConfig(kv_blocks=budget // block, kv_block_size=block)))

    ratio = snap_p.busy_lanes_mean / snap_d.busy_lanes_mean
    rows = [
        ["paged_dense_lanes", round(dt_d * 1e6, 0),
         f"busy_lanes_mean={snap_d.busy_lanes_mean:.2f}",
         f"kv_positions={budget}", f"steps={snap_d.steps}",
         f"completed={snap_d.completed}"],
        ["paged_block_pool", round(dt_p * 1e6, 0),
         f"busy_lanes_mean={snap_p.busy_lanes_mean:.2f}",
         f"kv_positions={budget // block * block}", f"steps={snap_p.steps}",
         f"completed={snap_p.completed}",
         f"preemptions={snap_p.preemptions}",
         f"block_util={snap_p.kv_block_utilization:.2f}"],
        ["paged_concurrency_ratio", round(ratio, 2)],
    ]
    assert ratio >= 1.5, (
        f"paged layout must sustain >= 1.5x concurrent lanes at equal KV "
        f"memory, got {ratio:.2f}x")

    # preemption drill: a pool too small for every lane to grow must evict,
    # requeue and resume with token-identical greedy output
    small = _prompts(cfg, 6, seed=2)
    ref = ServeEngine(model, params, max_batch=4, max_len=64)
    for p in small:
        ref.submit(p, max_new=8)
    want = {r.rid: r.out_tokens for r in ref.run_until_drained()}
    tight = ServeEngine(model, params, max_batch=4, max_len=64,
                        config=EngineConfig(kv_blocks=12, kv_block_size=4))
    for p in small:
        tight.submit(p, max_new=8)
    got = {r.rid: r.out_tokens for r in tight.run_until_drained()}
    snap_t = tight.metrics_snapshot()
    assert snap_t.preemptions > 0, "tiny pool should have forced preemption"
    assert got == want, "preempted requests must resume token-identically"
    rows.append(["paged_preempt_resume", snap_t.preemptions,
                 f"resumes={snap_t.resumes}", "token_identical=True"])
    summary = {
        "busy_lanes_mean_dense": snap_d.busy_lanes_mean,
        "busy_lanes_mean_paged": snap_p.busy_lanes_mean,
        "concurrency_ratio": ratio,
        "kv_positions_budget": budget,
        "paged_preemptions": snap_p.preemptions,
        "drill_preemptions": snap_t.preemptions,
        "drill_resumes": snap_t.resumes,
        "preempt_resume_token_identical": got == want,
    }
    return rows, summary


def bench_prefix_caching(cfg, model, params, *, smoke: bool):
    """Prefix-heavy mix (shared scenario prefix + short unique suffixes —
    system-prompt / agentic traffic) served twice on the SAME paged pool:
    caching off vs on.  With caching the prefix is admitted once and
    shared copy-on-write, so admission charges only each request's unique
    suffix blocks — the engine must sustain >= 1.3x the concurrent lanes
    (or, failing that, >= 1.3x better mean TTFT via skipped prefills)."""
    n_req = 18 if smoke else 36
    max_new = 4
    prefix_len, block = 96, 16
    max_len = 160
    kv_blocks = 24                 # without sharing: ~3 lanes of 8 blocks
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=int(rng.integers(8, 25)))])
               for _ in range(n_req)]

    def drain(prefix_cache):
        eng = ServeEngine(model, params, max_batch=12, max_len=max_len,
                          config=EngineConfig(kv_blocks=kv_blocks,
                                              kv_block_size=block,
                                              prefix_cache=prefix_cache))
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        eng.run_until_drained(max_steps=100_000)
        dt = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        assert snap.completed == n_req, \
            f"engine dropped work: {snap.completed}/{n_req}"
        return dt, snap

    dt_off, s_off = drain(False)
    dt_on, s_on = drain(True)
    lanes_ratio = s_on.busy_lanes_mean / s_off.busy_lanes_mean
    ttft_ratio = s_off.ttft.mean / s_on.ttft.mean
    rows = [
        ["prefix_cache_off", round(dt_off * 1e6, 0),
         f"busy_lanes_mean={s_off.busy_lanes_mean:.2f}",
         f"ttft_mean={s_off.ttft.mean:.4f}s",
         f"preemptions={s_off.preemptions}",
         f"prefill_dispatches={s_off.prefill_dispatches}"],
        ["prefix_cache_on", round(dt_on * 1e6, 0),
         f"busy_lanes_mean={s_on.busy_lanes_mean:.2f}",
         f"ttft_mean={s_on.ttft.mean:.4f}s",
         f"hit_rate={s_on.prefix_hit_rate:.2f}",
         f"prefill_skipped={s_on.prefill_skipped}",
         f"cow_splits={s_on.cow_splits}",
         f"shared_peak={s_on.kv_shared_blocks_peak}"],
        ["prefix_cache_win", round(max(lanes_ratio, ttft_ratio), 2),
         f"lanes_ratio={lanes_ratio:.2f}", f"ttft_ratio={ttft_ratio:.2f}"],
    ]
    assert s_on.prefix_hit_rate > 0.3, (
        f"shared-prefix traffic must hit the cache, got "
        f"{s_on.prefix_hit_rate:.2f}")
    assert max(lanes_ratio, ttft_ratio) >= 1.3, (
        f"prefix caching must win >= 1.3x on admitted lanes or TTFT for "
        f"prefix-heavy traffic, got lanes {lanes_ratio:.2f}x / "
        f"ttft {ttft_ratio:.2f}x")
    summary = {
        "busy_lanes_mean_off": s_off.busy_lanes_mean,
        "busy_lanes_mean_on": s_on.busy_lanes_mean,
        "lanes_ratio": lanes_ratio,
        "ttft_mean_off": s_off.ttft.mean,
        "ttft_mean_on": s_on.ttft.mean,
        "ttft_ratio": ttft_ratio,
        "hit_rate": s_on.prefix_hit_rate,
        "hit_rate_series": list(s_on.prefix_hit_series),
        "prefill_skipped": s_on.prefill_skipped,
        "cow_splits": s_on.cow_splits,
        "shared_blocks_peak": s_on.kv_shared_blocks_peak,
        "cache_evictions": s_on.cache_evictions,
    }
    return rows, summary


def bench_load_sweep(cfg, model, params, *, loads=(4.0, 16.0),
                     n_requests=24, max_new=8, seed=0):
    """Open-loop Poisson arrivals at `loads` requests/s, per policy."""
    rows = []
    for rate in loads:
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
        prompts = _prompts(cfg, n_requests, seed=seed)
        priorities = rng.integers(0, 3, size=n_requests)
        for policy in POLICIES:
            eng = ServeEngine(model, params, max_batch=8, max_len=64,
                              scheduler=SchedulerConfig(policy=policy))
            # warm THIS engine's jit caches (they are per-instance) so
            # compile time doesn't masquerade as TTFT, then reset counters
            eng.submit(prompts[0], max_new=2)
            eng.run_until_drained()
            eng.reset_stats()
            drive_open_loop(
                eng, arrivals,
                lambda i, now: eng.submit(prompts[i], max_new=max_new,
                                          priority=int(priorities[i])))
            snap = eng.metrics_snapshot()
            rows.append([
                f"load{rate:g}_{policy}", round(snap.wall_s * 1e6, 0),
                f"ttft_mean={snap.ttft.mean:.4f}s",
                f"ttft_p95={snap.ttft.p95:.4f}s",
                f"tpot_mean={snap.tpot.mean:.5f}s",
                f"tokens_per_s={snap.tokens_per_s:.1f}",
                f"queue_depth_mean={snap.queue_depth_mean:.2f}",
                f"slot_util={snap.slot_utilization:.2f}",
            ])
    return rows


def bench_trace_guard(cfg, model, params):
    """Steady-state retrace gate (runtime face of repro-lint R001).

    A FRESH engine on an already-warm model must admit, prefill, and
    decode with ZERO new traces: all jit wrappers are module-level or
    lru_cache-shared per (model, shape), never per instance — the
    invariant PR 4's fleet recompile bug violated.  The warmup run
    compiles every (bucket, chunk) program once; the guarded run then
    replays the identical workload on a new ServeEngine and TraceGuard
    raises on any compile-log event.
    """
    from repro.runtime.guard import TraceGuard

    prompts = _prompts(cfg, 8, seed=5)

    def run():
        eng = ServeEngine(model, params, max_batch=4, max_len=64)
        for p in prompts:
            eng.submit(p, max_new=4)
        eng.run_until_drained()
        return eng.metrics_snapshot()

    run()                                   # warmup: compile everything once
    with TraceGuard(max_retraces=0, name="bench_serving") as tg:
        snap = run()                        # fresh engine: wrappers must hit
    rows = [["trace_guard", 0, f"retraces={tg.total}",
             f"steps={snap.steps}", f"completed={snap.completed}"]]
    summary = {"retraces": tg.total, "traces": tg.traces,
               "compiles": tg.compiles, "completed": snap.completed}
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized config; skips the load sweep")
    args = ap.parse_args(argv)
    cfg, model, params = _build()
    rows = list(bench_backlog(cfg, model, params))
    paged_rows, paged_summary = bench_paged_vs_dense(cfg, model, params,
                                                     smoke=args.smoke)
    rows += paged_rows
    prefix_rows, prefix_summary = bench_prefix_caching(cfg, model, params,
                                                       smoke=args.smoke)
    rows += prefix_rows
    guard_rows, guard_summary = bench_trace_guard(cfg, model, params)
    rows += guard_rows
    if not args.smoke:
        rows += bench_load_sweep(cfg, model, params)
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("serving", rows,
         ["name", "us_total"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "serving.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "paged_vs_dense": paged_summary,
        "prefix_caching": prefix_summary,
        "trace_guard": guard_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
