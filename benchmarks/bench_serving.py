"""Serving engine under load: throughput/latency across admission policies.

Two measurements:

1. **Backlog admission** — a cold 16-request backlog, bucketed batched
   prefill vs the seed's one-dispatch-per-request behaviour.  The batched
   path must admit the same work in strictly fewer prefill dispatches.
2. **Open-loop load sweep** — Poisson arrivals at several offered loads,
   driven step-by-step (arrivals are submitted when their time comes due,
   the engine never waits for the queue to fill).  Reports TTFT / TPOT /
   tokens-per-second / mean queue depth per scheduler policy.
"""
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import POLICIES, SchedulerConfig
from repro.serving.traffic import drive_open_loop


def _build():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    return cfg, model, model.init(jax.random.key(0))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24)))
            for _ in range(n)]


def bench_backlog(cfg, model, params, n_requests=16):
    """Cold backlog: dispatches needed to admit everything."""
    rows = []
    for name, m in [("bucketed", model),
                    ("per_request", dataclasses.replace(model,
                                                        prefill_ragged=None))]:
        eng = ServeEngine(m, params, max_batch=n_requests, max_len=64)
        for p in _prompts(cfg, n_requests):
            eng.submit(p, max_new=4)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        snap = eng.metrics_snapshot()
        rows.append([f"backlog_{name}", round(dt * 1e6, 0),
                     f"dispatches={snap.prefill_dispatches}",
                     f"requests={snap.prefill_requests}",
                     f"ttft_mean={snap.ttft.mean:.4f}s"])
    assert int(rows[0][2].split("=")[1]) < int(rows[1][2].split("=")[1]), \
        "bucketed prefill must use fewer dispatches than per-request"
    return rows


def bench_load_sweep(cfg, model, params, *, loads=(4.0, 16.0),
                     n_requests=24, max_new=8, seed=0):
    """Open-loop Poisson arrivals at `loads` requests/s, per policy."""
    rows = []
    for rate in loads:
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
        prompts = _prompts(cfg, n_requests, seed=seed)
        priorities = rng.integers(0, 3, size=n_requests)
        for policy in POLICIES:
            eng = ServeEngine(model, params, max_batch=8, max_len=64,
                              scheduler=SchedulerConfig(policy=policy))
            # warm THIS engine's jit caches (they are per-instance) so
            # compile time doesn't masquerade as TTFT, then reset counters
            eng.submit(prompts[0], max_new=2)
            eng.run_until_drained()
            eng.reset_stats()
            drive_open_loop(
                eng, arrivals,
                lambda i, now: eng.submit(prompts[i], max_new=max_new,
                                          priority=int(priorities[i])))
            snap = eng.metrics_snapshot()
            rows.append([
                f"load{rate:g}_{policy}", round(snap.wall_s * 1e6, 0),
                f"ttft_mean={snap.ttft.mean:.4f}s",
                f"ttft_p95={snap.ttft.p95:.4f}s",
                f"tpot_mean={snap.tpot.mean:.5f}s",
                f"tokens_per_s={snap.tokens_per_s:.1f}",
                f"queue_depth_mean={snap.queue_depth_mean:.2f}",
                f"slot_util={snap.slot_utilization:.2f}",
            ])
    return rows


def main():
    cfg, model, params = _build()
    rows = [r + [""] * (8 - len(r)) for r in bench_backlog(cfg, model, params)]
    rows += bench_load_sweep(cfg, model, params)
    emit("serving", rows,
         ["name", "us_total", "d1", "d2", "d3", "d4", "d5", "d6"])


if __name__ == "__main__":
    main()
