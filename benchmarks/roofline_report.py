"""§Roofline: the three-term table over every dry-run cell + §Perf hints.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), computes
compute/memory/collective seconds per (arch × shape × mesh), marks the
dominant term, the 6·N·D useful-work ratio, and emits both CSV and the
markdown table embedded in EXPERIMENTS.md.
"""
from pathlib import Path

from benchmarks.common import OUT_DIR, emit
from repro.analysis.roofline import (best_rows, improvement_hint, load_cells)

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def markdown_table(rows):
    md = ["| arch | shape | mesh | strategy | compute s | memory s | "
          "collective s | dominant | peak GB/dev | 6ND/HLO | note |",
          "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.status == "ok":
            md.append(
                f"| {r.arch} | {r.shape} | {r.mesh} | {r.strategy} "
                f"| {r.compute_s:.4f} | {r.memory_s:.4f} "
                f"| {r.collective_s:.4f} | **{r.dominant}** "
                f"| {r.peak_gb:.1f} | {r.useful_ratio:.2f} "
                f"| {improvement_hint(r)[:60]} |")
        else:
            md.append(f"| {r.arch} | {r.shape} | {r.mesh} | - | - | - | - "
                      f"| {r.status.upper()} | - | - | {r.note[:60]} |")
    return "\n".join(md)


def main():
    cells = load_cells(ART)
    if not cells:
        print("roofline,no_dryrun_artifacts,0,run repro.launch.dryrun first")
        return
    rows = sorted(best_rows(cells).values(),
                  key=lambda r: (r.arch, r.shape, r.mesh))
    csv = []
    for r in rows:
        csv.append([f"{r.arch}__{r.shape}__{r.mesh}",
                    round(r.step_s * 1e6, 1),
                    r.status, r.strategy, round(r.compute_s, 5),
                    round(r.memory_s, 5), round(r.collective_s, 5),
                    r.dominant, round(r.peak_gb, 2),
                    round(r.useful_ratio, 3)])
    emit("roofline", csv,
         ["cell", "us_step", "status", "strategy", "compute_s", "memory_s",
          "collective_s", "dominant", "peak_gb_dev", "useful_ratio"])
    md = markdown_table(rows)
    (OUT_DIR / "roofline.md").write_text(md + "\n")
    ok = [r for r in rows if r.status == "ok"]
    doms = {}
    for r in ok:
        doms[r.dominant] = doms.get(r.dominant, 0) + 1
    print(f"roofline,summary,0,cells_ok={len(ok)},dominant_split={doms}")


if __name__ == "__main__":
    main()
