"""Paper Fig. 3: GPipe vs hybrid schedule accounting (+ rendered tables)."""
from benchmarks.common import emit
from repro.core import schedules as S


def main():
    rows = []
    for s, m in [(2, 8), (4, 8), (4, 16), (8, 16), (16, 16)]:
        g = S.schedule_stats(S.gpipe_table(s, m), s, m)
        h = S.schedule_stats(S.hybrid_table(s, m), s, m)
        rows.append([f"S{s}_M{m}", 0,
                     f"gpipe_ticks={g['ticks']}",
                     f"hybrid_ticks={h['ticks']}",
                     f"gpipe_bubble={g['bubble_fraction']:.3f}",
                     f"hybrid_bubble={h['bubble_fraction']:.3f}"])
    emit("schedules", rows,
         ["name", "us_per_call", "d1", "d2", "d3", "d4"])
    print("\n[paper Fig.3, S=2 M=4] hybrid (last stage fused F+B):")
    print(S.render(S.hybrid_table(2, 4)))
    print("[gpipe]:")
    print(S.render(S.gpipe_table(2, 4)))


if __name__ == "__main__":
    main()
