"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]
    PYTHONPATH=src python -m benchmarks.run --list

Emits ``name,us_per_call,derived...`` CSV lines (+ files under
experiments/bench/).  ``--list`` imports every bench module and prints the
registry without running anything — CI's cheap import-breakage smoke.
"""
import sys
import traceback

from benchmarks import (bench_devices, bench_faults, bench_fed,
                        bench_kernels, bench_pipeline, bench_scale,
                        bench_schedules, bench_serving, bench_spec,
                        bench_thermal, bench_tool_parallel, bench_wire,
                        roofline_report)
from repro.analysis.lint import cli as lint_cli


def _lint_entry() -> None:
    # a broken analysis module fails CI like any other entry point; a
    # dirty tree fails the run outright
    n = lint_cli.run(["--strict"])
    if n:
        raise RuntimeError(f"repro-lint: {n} invariant violation(s)")


ALL = {
    "devices": bench_devices.main,          # paper Table 1
    "pipeline": bench_pipeline.main,        # paper §4.1 / Fig. 5 / A.1
    "schedules": bench_schedules.main,      # paper Fig. 3
    "thermal": bench_thermal.main,          # paper §4.2 / Fig. 6
    "tool_parallel": bench_tool_parallel.main,  # paper §4.3 / Fig. 7-8
    "wire": bench_wire.main,                # paper Fig. 2 protocol
    "kernels": bench_kernels.main,          # Pallas kernel budgets
    "roofline": roofline_report.main,       # §Roofline table from dry-run
    # engine under load (ROADMAP); explicit empty argv — its CLI would
    # otherwise swallow the orchestrator's own bench-name arguments
    "serving": lambda: bench_serving.main([]),
    # speculative pairs on the fleet (ROADMAP); same explicit-argv guard
    "spec": lambda: bench_spec.main([]),
    # production-scale fleet simulation (ROADMAP); same guard
    "scale": lambda: bench_scale.main([]),
    # chaos harness: kill traces, heartbeats, lane resurrection; same guard
    "faults": lambda: bench_faults.main([]),
    # federated serve-while-train plane (ROADMAP training item); same guard
    "fed": lambda: bench_fed.main([]),
    # repro-lint invariants (R001-R006) over src/; see docs/INVARIANTS.md
    "lint": _lint_entry,
}


def main() -> None:
    if "--list" in sys.argv[1:]:
        # reaching this line proves every bench module imported cleanly
        for name in ALL:
            print(name)
        return
    names = sys.argv[1:] or list(ALL)
    failed = []
    for name in names:
        print(f"# === bench:{name} ===")
        try:
            ALL[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
