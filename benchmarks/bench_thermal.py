"""Paper §4.2 / Fig. 6: thermal throttling + §5.2 mitigations, simulated.

Reproduces the paper's observation (state creep Minimal->Fair->Serious with
per-batch time rising ~10%), then runs the three mitigation policies and
reports recovered throughput.  Simulation timestep = one batch; worker time
follows the paper's Fig. 6 ramp shape via FaultPlan.slowdown.
"""
import numpy as np

from benchmarks.common import emit
from repro.core.calibrate import calibrated_profiles, resnet_costs
from repro.core.partition import pipeline_batch_seconds, split_blocks
from repro.runtime.elastic import DutyCyclePolicy, RebalancePolicy, SwapPolicy
from repro.runtime.faults import FaultPlan
from repro.runtime.monitor import ThermalMonitor, ThermalState


def simulate(policy_name: str, n_batches: int = 30):
    costs = resnet_costs()
    profs = calibrated_profiles()
    host, phone = profs["xeon"], profs["iphone11"]
    base_plan = split_blocks(costs, [host, phone], efficiency=1.0)
    base_t = pipeline_batch_seconds(base_plan, 8)
    fp = FaultPlan(throttle={"phone": (10, 1.12, 5.0)})   # Fig.6-like ramp
    mon = ThermalMonitor(alpha=0.4, calibration_steps=3, warmup_skip=0)
    swap = SwapPolicy(spares=["phone_spare"])
    duty = DutyCyclePolicy()
    reb = RebalancePolicy(costs, [host, phone], efficiency=1.0)
    times, states = [], []
    plan = base_plan
    duty_mult = 1.0
    swapped_at = None
    for b in range(n_batches):
        slow = fp.slowdown("phone", b)
        if swapped_at is not None:            # fresh spare: no throttle
            slow = 1.0
        t = pipeline_batch_seconds(plan, 8) * (1 + (slow - 1) * duty_mult)
        # mitigations consume telemetry
        ws = mon.observe("phone", t)
        if policy_name == "swap" and swapped_at is None:
            acts = swap.step(mon)
            if acts:
                swapped_at = b
        elif policy_name == "duty":
            acts = duty.step(mon)
            duty_mult = acts[0].detail["duty"] if acts else 1.0
        elif policy_name == "rebalance":
            derate = ws.slowdown
            import dataclasses
            acts = reb.step(mon, ["host", "phone"])
            if acts:
                plan = reb.current
        times.append(t)
        states.append(ws.state.value)
    return base_t, times, states


def main():
    rows = []
    for pol in ["none", "swap", "duty", "rebalance"]:
        base_t, times, states = simulate(pol)
        tail = float(np.mean(times[-8:]))
        rows.append([f"policy_{pol}", round(tail * 1e6, 0),
                     f"baseline={base_t:.3f}s",
                     f"tail_batch={tail:.3f}s",
                     f"degradation={tail/base_t-1:.1%}",
                     f"states={'->'.join(dict.fromkeys(states))}"])
    emit("thermal", rows, ["name", "us_per_call", "d1", "d2", "d3", "d4"])


if __name__ == "__main__":
    main()
