"""Thermal-aware serving fleet under throttle: §5.2 policies on vs off.

A two-worker heterogeneous fleet (host: ``m2-max-cpu``, phone:
``iphone-11-pro``) serves the same open-loop traffic twice under the SAME
synthetic throttle trace (the phone ramps from Minimal to Serious/Critical
mid-run, paper Fig. 6 shape):

1. **policies off** — thermally-naive capacity routing, no elastic
   actions: the phone keeps receiving work it can only crawl through.
2. **policies on** — thermal-aware routing +
   :class:`repro.runtime.elastic.ServingElasticPolicy`: the hot phone is
   duty-cycled, drained, and its decode lanes are MIGRATED to the host
   (token-identically, via the engine's preempt/resume contract).

Asserted (CI-gated via the ``bench-smoke`` job):

* policies recover >= 1.3x goodput (completed tokens per simulated
  second) vs policies-off under the same trace;
* at least one request actually migrates, and EVERY request's output —
  migrated ones included — is token-identical to an unmigrated
  single-engine run with the same sampling seeds.

A second section re-runs the policies-on fleet with paged +
content-addressed prefix-cache engines on shared-scaffold traffic: the
migration re-prefill prefix-matches the scaffold blocks the target worker
already served, tying the PR 3 cache to fleet mobility.

``--smoke`` is the CI configuration; JSON lands in
``experiments/bench/fleet.json`` and is uploaded as an artifact.
"""
import argparse
import dataclasses
import json

import jax
import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.configs import RunConfig, get_config, reduced_config
from repro.hw.specs import get_profile
from repro.models.api import build_model
from repro.runtime.elastic import ServingElasticPolicy
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.fleet import (ServingFleet, ThrottleTrace, WorkerSpec,
                                 drive_sim)
from repro.serving.sampling import SamplingParams

MAX_LEN = 96
TICK_S = 0.05


def _build():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    return cfg, model, model.init(jax.random.key(0))


def _traffic(cfg, n, *, span_s, seed=0, prefix_len=0):
    """n prompts (optionally sharing a scenario prefix), evenly-spaced
    arrivals over ``span_s`` sim seconds, and a greedy/stochastic sampling
    mix with per-request seeds (so any engine reproduces the streams)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len)
    prompts = [np.concatenate([
        prefix, rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 16)))
    ]) for _ in range(n)]
    arrivals = np.linspace(0.0, span_s, n)
    samplings = [SamplingParams(temperature=2.0, top_k=32, seed=1000 + i)
                 if i % 3 == 0 else None for i in range(n)]
    return prompts, arrivals, samplings


def _run_fleet(model, params, prompts, arrivals, samplings, max_new, *,
               policy, thermal_routing, engine_config=None,
               throttle_start=0.5, max_batch=3):
    workers = [
        WorkerSpec("host", get_profile("m2-max-cpu"), max_batch=max_batch),
        WorkerSpec("phone", get_profile("iphone-11-pro"),
                   max_batch=max_batch),
    ]
    trace = ThrottleTrace({"phone": (throttle_start, 6.0, 0.15)})
    fleet = ServingFleet(model, params, workers, max_len=MAX_LEN,
                         tick_s=TICK_S, policy=policy, throttle=trace,
                         thermal_routing=thermal_routing,
                         engine_config=engine_config)
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=max_new,
                                     sampling=samplings[i]))
    return fleet, fleet.snapshot()


def _reference_tokens(model, params, prompts, samplings, max_new):
    """Unmigrated single-engine run: the token-identity oracle."""
    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=MAX_LEN)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=max_new, sampling=sp)
    return {r.rid: r.out_tokens for r in ref.run_until_drained()}


def bench_policies(cfg, model, params, *, smoke: bool):
    n = 12 if smoke else 28
    max_new = 16 if smoke else 24
    span = 1.4 if smoke else 3.0
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=span)

    f_on, on = _run_fleet(model, params, prompts, arrivals, samplings,
                          max_new, policy=ServingElasticPolicy(),
                          thermal_routing=True)
    f_off, off = _run_fleet(model, params, prompts, arrivals, samplings,
                            max_new, policy=None, thermal_routing=False)
    assert on.completed == off.completed == n, \
        f"fleet dropped work: on={on.completed} off={off.completed} of {n}"
    ratio = on.goodput_tokens_per_s / off.goodput_tokens_per_s
    assert on.migrated_requests >= 1, "throttle must force a migration"
    assert ratio >= 1.3, (
        f"elastic policies must recover >= 1.3x goodput under throttle, "
        f"got {ratio:.2f}x ({on.goodput_tokens_per_s:.1f} vs "
        f"{off.goodput_tokens_per_s:.1f} tok/s)")

    want = _reference_tokens(model, params, prompts, samplings, max_new)
    got = {rec.req.rid: rec.req.out_tokens for rec in f_on.completed}
    assert got == want, \
        "migrated fleet outputs must be token-identical to the unmigrated run"

    phone_on, phone_off = on.per_worker["phone"], off.per_worker["phone"]
    rows = [
        ["fleet_policies_on", round(on.sim_t * 1e6, 0),
         f"goodput={on.goodput_tokens_per_s:.1f}tok/s",
         f"migrations={on.migrations}", f"drains={on.drains}",
         f"phone_goodput={phone_on.goodput_tokens_per_s:.1f}",
         f"phone_occ={phone_on.state_occupancy}"],
        ["fleet_policies_off", round(off.sim_t * 1e6, 0),
         f"goodput={off.goodput_tokens_per_s:.1f}tok/s",
         "migrations=0", "drains=0",
         f"phone_goodput={phone_off.goodput_tokens_per_s:.1f}",
         f"phone_occ={phone_off.state_occupancy}"],
        ["fleet_goodput_ratio", round(ratio, 2),
         f"migrated_requests={on.migrated_requests}",
         "token_identical=True"],
    ]
    summary = {
        "goodput_on": on.goodput_tokens_per_s,
        "goodput_off": off.goodput_tokens_per_s,
        "goodput_ratio": ratio,
        "sim_t_on": on.sim_t,
        "sim_t_off": off.sim_t,
        "migrations": on.migrations,
        "migrated_requests": on.migrated_requests,
        "drains": on.drains,
        "undrains": on.undrains,
        "token_identical": got == want,
        "policies_on": on.as_dict(),
        "policies_off": off.as_dict(),
    }
    return rows, summary


def bench_migration_prefix_cache(cfg, model, params, *, smoke: bool):
    """Policies-on fleet on PAGED + prefix-cached engines with a shared
    scenario scaffold: the hot phone's migrated lanes re-prefill on the
    host against scaffold blocks the host already served, so migration
    cost is a near-full cache hit instead of a cold re-prefill."""
    n = 12 if smoke else 24
    max_new = 8
    prompts, arrivals, samplings = _traffic(
        cfg, n, span_s=1.4 if smoke else 2.5, seed=3, prefix_len=64)
    econf = EngineConfig(kv_blocks=30, kv_block_size=16, prefix_cache=True)
    f, snap = _run_fleet(model, params, prompts, arrivals, samplings,
                         max_new, policy=ServingElasticPolicy(),
                         thermal_routing=True, engine_config=econf)
    assert snap.completed == n, f"dropped work: {snap.completed}/{n}"
    want = _reference_tokens(model, params, prompts, samplings, max_new)
    got = {rec.req.rid: rec.req.out_tokens for rec in f.completed}
    assert got == want, "paged+cached fleet must stay token-identical"
    hit = sum(w.engine.prefix_hit_tokens for w in snap.per_worker.values())
    query = sum(w.engine.prefix_query_tokens
                for w in snap.per_worker.values())
    hit_rate = hit / query if query else 0.0
    assert hit_rate > 0.3, (
        f"shared-scaffold fleet traffic must hit the prefix cache, got "
        f"{hit_rate:.2f}")
    rows = [["fleet_migration_prefix_cache", round(snap.sim_t * 1e6, 0),
             f"hit_rate={hit_rate:.2f}",
             f"migrations={snap.migrations}",
             f"prefill_skipped="
             f"{sum(w.engine.prefill_skipped for w in snap.per_worker.values())}",
             "token_identical=True"]]
    summary = {
        "hit_rate": hit_rate,
        "migrations": snap.migrations,
        "migrated_requests": snap.migrated_requests,
        "completed": snap.completed,
        "token_identical": got == want,
    }
    return rows, summary


def bench_trace_guard(cfg, model, params, *, smoke: bool):
    """Steady-state retrace gate across the WHOLE fleet (PR 4's bug).

    Two workers share one model; jit wrappers are lru_cache-shared per
    (model, shape), so a second identical fleet run after warmup must
    trigger zero traces — a retrace here means some worker rebuilt a
    wrapper per instance.  The warmup run takes the compiles; the guarded
    run replays the same seeded traffic on a brand-new fleet.
    """
    from repro.runtime.guard import TraceGuard

    n = 6 if smoke else 10
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=0.8, seed=9)
    max_new = 6 if smoke else 10

    def run():
        _, snap = _run_fleet(model, params, prompts, arrivals, samplings,
                             max_new, policy=None, thermal_routing=False)
        return snap

    run()                                   # warmup: compile once, fleet-wide
    with TraceGuard(max_retraces=0, name="bench_fleet") as tg:
        snap = run()                        # new fleet, same model: all hits
    rows = [["trace_guard", 0, f"retraces={tg.total}",
             f"completed={snap.completed}"]]
    summary = {"retraces": tg.total, "traces": tg.traces,
               "compiles": tg.compiles, "completed": snap.completed}
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized config")
    args = ap.parse_args(argv)
    cfg, model, params = _build()
    rows, summary = bench_policies(cfg, model, params, smoke=args.smoke)
    cache_rows, cache_summary = bench_migration_prefix_cache(
        cfg, model, params, smoke=args.smoke)
    rows += cache_rows
    guard_rows, guard_summary = bench_trace_guard(cfg, model, params,
                                                  smoke=args.smoke)
    rows += guard_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("fleet", rows,
         ["name", "us_sim"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "fleet.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "policies": summary,
        "migration_prefix_cache": cache_summary,
        "trace_guard": guard_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
