"""Paper Table 1: device compute comparison + calibration findings.

Emits the rated vs calibrated-effective rates (the reproduction-critical
discovery that Table 1 ratings don't predict the paper's own timings), plus
this host's measured matmul throughput as a sanity row.
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.calibrate import calibrated_profiles
from repro.hw.specs import PROFILES, TPU_V5E


def main():
    profs = calibrated_profiles()
    rated = {"xeon": 0.061e12, "mac": 0.9e12, "iphone11": 0.63e12,
             "iphone16": 1.907e12}
    rows = []
    for name, p in profs.items():
        rows.append([name, 0, f"rated={rated[name]/1e9:.0f}GF/s",
                     f"calibrated={p.flops/1e9:.0f}GF/s",
                     f"efficiency={p.flops/rated[name]:.2f}"])
    rows.append(["tpu-v5e-target", 0, f"rated={TPU_V5E.flops/1e12:.0f}TF/s",
                 f"hbm={TPU_V5E.mem_bw/1e9:.0f}GB/s",
                 f"ici={TPU_V5E.link_bw/1e9:.0f}GB/s/link"])

    # measured local matmul throughput (this container's CPU)
    import jax
    import jax.numpy as jnp
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    us = timeit(lambda: jax.block_until_ready(f(a)), n=5)
    gflops = 2 * n ** 3 / (us / 1e6) / 1e9
    rows.append(["this-host-cpu", round(us, 1), f"matmul={gflops:.1f}GF/s", "", ""])
    emit("devices", rows, ["name", "us_per_call", "d1", "d2", "d3"])


if __name__ == "__main__":
    main()
