"""Pipeline benchmarks: paper §4.1 training reproduction + split DECODE.

Three parts:

1. REPRODUCTION (calibrated cost model): predicted vs the paper's measured
   training batch times for all five setups + the two held-out validations,
   and a real timed ResNet-34-mini 2-stage run on this host.

2. SPLIT SERVING (CI-gated via ``--smoke`` in the ``bench-smoke`` job) —
   the pipeline-split decode subsystem's claims:

   * **memory wall**: a 2-worker :class:`~repro.serving.fleet.StageGroup`
     serves a model whose params EXCEED either worker's ``mem_bytes``
     alone (each stage's slice fits its worker; asserted from real byte
     counts), with the cut chosen by
     :func:`repro.core.partition.split_decode`;
   * **token identity**: every output — across prefill/decode boundary
     frames round-tripped through :mod:`repro.wire.codec` — is identical
     to a single-engine :class:`~repro.serving.engine.ServeEngine`
     reference;
   * **transfers are charged**: boundary activations cost simulated link
     seconds (``transfer_s > 0``), and starving the link strictly lowers
     goodput with frames crossing fleet ticks;
   * **rebalance**: when one stage throttles, the elastic policy re-cuts
     the split (layers move OFF the hot stage, moved weights charged over
     the link) and outputs stay token-identical.

3. REAL TELEMETRY: the same fleet run with ``telemetry="wall"`` — the
   ThermalMonitor is fed the MEASURED wall-clock per-step latency of the
   real jitted dispatches instead of the synthetic simulated value.

JSON lands in ``experiments/bench/pipeline.json`` (uploaded as a CI
artifact alongside ``fleet.json``).
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, emit, timeit
from repro.configs import RunConfig, get_config, reduced_config
from repro.core.calibrate import PAPER_MS, reproduction_table
from repro.hw.specs import DeviceProfile
from repro.models.api import build_model, param_bytes
from repro.runtime.elastic import ServingElasticPolicy
from repro.runtime.monitor import ThermalMonitor
from repro.serving.engine import ServeEngine
from repro.serving.fleet import (ServingFleet, StageGroup, ThrottleTrace,
                                 WorkerSpec, drive_sim)
from repro.serving.pipeline_decode import plan_decode_split
from repro.serving.sampling import SamplingParams

MAX_LEN = 64
TICK_S = 0.05


# ---------------------------------------------------------------------------
# part 1: training reproduction (unchanged claims)
# ---------------------------------------------------------------------------
def bench_training_reproduction():
    rows = []
    for r in reproduction_table():
        rows.append([r["setup"], 0, f"pred={r['predicted_s']}s",
                     f"paper={r['paper_s']}s", f"rel_err={r['rel_err']}",
                     "HELD-OUT" if r["held_out"] else "fit"])
    # paper headline: % decrease vs desktop alone
    tbl = {r["setup"]: r for r in reproduction_table()}
    for pair, base in [("desktop_iph11", "desktop_alone"),
                       ("desktop_iph16", "desktop_alone"),
                       ("mac_iph16", "mac_alone")]:
        pred = 1 - tbl[pair]["predicted_s"] / tbl[base]["predicted_s"]
        meas = 1 - PAPER_MS[pair] / PAPER_MS[base]
        rows.append([f"decrease_{pair}", 0, f"pred={pred:.0%}",
                     f"paper={meas:.0%}", "", ""])

    # real timed mini 2-stage pipeline on this host
    from repro.configs.resnet34 import MINI
    from repro.models import resnet as R
    meta, params = R.init_resnet(MINI, jax.random.key(0))
    x = jnp.ones((8, 32, 32, 3))
    cut = len(params) // 2
    s1 = jax.jit(lambda p, x: R.forward(meta[:cut], p, x))
    s2 = jax.jit(lambda p, h: R.forward(meta[cut:], p, h))
    p1, p2 = params[:cut], params[cut:]
    h = s1(p1, x)
    full = jax.jit(lambda p, x: R.forward(meta, p, x))
    us_s1 = timeit(lambda: jax.block_until_ready(s1(p1, x)))
    us_s2 = timeit(lambda: jax.block_until_ready(s2(p2, h)))
    us_full = timeit(lambda: jax.block_until_ready(full(params, x)))
    m = 8
    pipe_us = max(us_s1, us_s2) * m + min(us_s1, us_s2)
    rows.append(["mini_2stage_real", round(pipe_us / m, 1),
                 f"single={us_full:.0f}us",
                 f"2dev_pipe={pipe_us/m:.0f}us/mb",
                 f"speedup={us_full/(pipe_us/m):.2f}x", ""])
    return rows, {"reproduction": reproduction_table()}


# ---------------------------------------------------------------------------
# part 2: pipeline-split decode
# ---------------------------------------------------------------------------
def _build():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=4)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    return cfg, model, model.init(jax.random.key(0))


def _profile(name, rate, link, mem, **kw):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=mem,
                         mem_bw=1e9, link_bw=link, decode_steps_per_s=rate,
                         prefill_tokens_per_s=2e5, **kw)


def _traffic(cfg, n, *, span_s, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(6, 18))) for _ in range(n)]
    arrivals = np.linspace(0.0, span_s, n)
    samplings = [SamplingParams(temperature=2.0, top_k=32, seed=1000 + i)
                 if i % 3 == 0 else None for i in range(n)]
    return prompts, arrivals, samplings


def _reference_tokens(model, params, prompts, samplings, max_new):
    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=MAX_LEN)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=max_new, sampling=sp)
    return {r.rid: r.out_tokens for r in ref.run_until_drained()}


def _run_group(model, params, prompts, arrivals, samplings, max_new, *,
               workers, cuts=None, policy=None, throttle=None,
               max_batch=3):
    grp = StageGroup("pair", tuple(workers), cuts=cuts, max_batch=max_batch)
    fleet = ServingFleet(model, params, groups=[grp], max_len=MAX_LEN,
                         tick_s=TICK_S, policy=policy, throttle=throttle)
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=max_new,
                                     sampling=samplings[i]))
    return fleet, fleet.snapshot()


def bench_split_serving(cfg, model, params, *, smoke: bool):
    n = 10 if smoke else 24
    max_new = 12 if smoke else 20
    prompts, arrivals, samplings = _traffic(cfg, n,
                                            span_s=1.0 if smoke else 2.5)
    want = _reference_tokens(model, params, prompts, samplings, max_new)

    # -- memory wall: neither worker holds the full params ---------------
    total = param_bytes(params)
    mem = 0.75 * total
    host = WorkerSpec("host", _profile("split-host", 40.0, 1e9, mem))
    phone = WorkerSpec("phone", _profile("split-phone", 60.0, 1e9, mem))
    plan = plan_decode_split(model, params,
                             [host.profile, phone.profile],
                             max_batch=3, max_len=MAX_LEN)
    assert plan.feasible, "the cut search must find a fitting split"
    assert total > host.profile.mem_bytes \
        and total > phone.profile.mem_bytes, \
        "the bench model must NOT fit either worker alone"

    fleet, snap = _run_group(model, params, prompts, arrivals, samplings,
                             max_new, workers=(host, phone))
    g = snap.per_group["pair"]
    eng = fleet.group("pair").engine
    assert snap.completed == n, f"dropped work: {snap.completed}/{n}"
    for sb, w in zip(eng.stage_param_bytes, (host, phone)):
        assert sb <= w.profile.mem_bytes, \
            f"stage slice {sb} exceeds {w.name}'s memory"
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want, \
        "split-pair outputs must be token-identical to the single engine"
    assert g.frames_sent > 0 and g.frame_bytes > 0 and g.transfer_s > 0, \
        "boundary activations must be charged through the codec + link"

    # -- the link model bites: a USB2-class-starved link, same work ------
    _, nsnap = _run_group(model, params, prompts, arrivals, samplings,
                          max_new,
                          workers=(WorkerSpec("host", _profile(
                              "narrow-host", 40.0, 2e4, mem)),
                              WorkerSpec("phone", _profile(
                                  "narrow-phone", 60.0, 2e4, mem))),
                          cuts=eng.cuts)
    ng = nsnap.per_group["pair"]
    assert nsnap.completed == n
    assert nsnap.goodput_tokens_per_s < snap.goodput_tokens_per_s, \
        "a starved link must lower goodput"
    assert ng.transfer_s > g.transfer_s

    rows = [
        ["split_memory_wall", round(snap.sim_t * 1e6, 0),
         f"params={total/1e6:.1f}MB>mem={mem/1e6:.1f}MB",
         f"cuts={list(eng.cuts)}",
         f"stage_MB={[round(b/1e6, 2) for b in eng.stage_param_bytes]}",
         "token_identical=True"],
        ["split_transfers", round(g.transfer_s * 1e6, 0),
         f"frames={g.frames_sent}", f"bytes={g.frame_bytes}",
         f"goodput={snap.goodput_tokens_per_s:.1f}tok/s",
         f"narrow_goodput={nsnap.goodput_tokens_per_s:.1f}tok/s"],
    ]
    summary = {
        "total_param_bytes": total,
        "worker_mem_bytes": mem,
        "cuts": list(eng.cuts),
        "stage_param_bytes": list(eng.stage_param_bytes),
        "plan_step_seconds": plan.step_seconds,
        "goodput": snap.goodput_tokens_per_s,
        "narrow_link_goodput": nsnap.goodput_tokens_per_s,
        "frames_sent": g.frames_sent,
        "frame_bytes": g.frame_bytes,
        "transfer_s": g.transfer_s,
        "narrow_transfer_s": ng.transfer_s,
        "narrow_link_stall_ticks": ng.link_stall_ticks,
        "token_identical": got == want,
    }
    return rows, summary


def bench_rebalance(cfg, model, params, *, smoke: bool):
    """Stage 1 throttles 6x mid-run: the elastic policy's migrate action
    becomes REBALANCE for the group — the cut moves layers off the hot
    stage, charged over the link, token-identically."""
    n = 10 if smoke else 20
    max_new = 10 if smoke else 16
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=1.2, seed=4)
    want = _reference_tokens(model, params, prompts, samplings, max_new)
    workers = (WorkerSpec("rb-host", _profile("rb-host", 40.0, 1e9, 1e12)),
               WorkerSpec("rb-phone", _profile("rb-phone", 60.0, 1e9, 1e12)))
    fleet, snap = _run_group(
        model, params, prompts, arrivals, samplings, max_new,
        workers=workers, cuts=(2,), policy=ServingElasticPolicy(),
        throttle=ThrottleTrace({"rb-phone": (0.3, 6.0, 0.1)}))
    g = snap.per_group["pair"]
    assert snap.completed == n
    assert snap.recuts >= 1, "the throttled stage must force a re-cut"
    assert g.cuts[0] > 2, "layers must move OFF the hot stage"
    assert g.recut_bytes > 0, "moved layer weights must be charged"
    got = {rec.req.rid: rec.req.out_tokens for rec in fleet.completed}
    assert got == want, "re-cut outputs must stay token-identical"
    rows = [["split_rebalance", round(snap.sim_t * 1e6, 0),
             f"recuts={snap.recuts}", f"cuts={list(g.cuts)}",
             f"moved={g.recut_bytes}B", "token_identical=True"]]
    summary = {
        "recuts": snap.recuts,
        "final_cuts": list(g.cuts),
        "recut_bytes": g.recut_bytes,
        "token_identical": got == want,
    }
    return rows, summary


# ---------------------------------------------------------------------------
# part 3: real (wall-clock) telemetry into the ThermalMonitor
# ---------------------------------------------------------------------------
def bench_real_telemetry(cfg, model, params, *, smoke: bool):
    """telemetry="wall": the monitor's EWMA state machine runs on the
    MEASURED per-step wall latency of the real jitted dispatches — the
    harness-side feed the ROADMAP asked for, replacing simulated traces.
    Warmup skip absorbs the compile-step outliers, exactly as it would on
    a real device feed."""
    n = 8 if smoke else 16
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=0.8, seed=8)
    monitor = ThermalMonitor(alpha=0.25, calibration_steps=3, warmup_skip=1)
    fleet = ServingFleet(
        model, params,
        [WorkerSpec("real", _profile("real-host", 40.0, 1e9, 1e12),
                    max_batch=4)],
        max_len=MAX_LEN, tick_s=TICK_S, monitor=monitor, telemetry="wall")
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=8,
                                     sampling=samplings[i]))
    ws = monitor.workers["real"]
    assert ws.steps > monitor.calibration_steps, \
        "real telemetry must have flowed into the monitor"
    assert ws.baseline_s is not None and ws.baseline_s > 0, \
        "the monitor must calibrate a real wall-clock baseline"
    rows = [["real_telemetry", round(ws.baseline_s * 1e6, 1),
             f"observations={ws.steps}", f"state={ws.state.value}",
             f"ewma_us={ws.ewma_s*1e6:.1f}",
             f"slowdown={ws.slowdown:.3f}"]]
    summary = {
        "observations": ws.steps,
        "baseline_s": ws.baseline_s,
        "ewma_s": ws.ewma_s,
        "state": ws.state.value,
        "slowdown": ws.slowdown,
    }
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized config")
    args = ap.parse_args(argv)
    rows, repro_summary = bench_training_reproduction()
    cfg, model, params = _build()
    split_rows, split_summary = bench_split_serving(cfg, model, params,
                                                    smoke=args.smoke)
    rb_rows, rb_summary = bench_rebalance(cfg, model, params,
                                          smoke=args.smoke)
    tel_rows, tel_summary = bench_real_telemetry(cfg, model, params,
                                                 smoke=args.smoke)
    rows += split_rows + rb_rows + tel_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("pipeline", rows,
         ["name", "us_per_call"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "pipeline.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "training_reproduction": repro_summary,
        "split_serving": split_summary,
        "rebalance": rb_summary,
        "real_telemetry": tel_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
