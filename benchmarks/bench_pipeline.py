"""Paper §4.1 / Fig. 5 / appendix A.1: pipeline-parallel training speedups.

Two parts:
1. REPRODUCTION (calibrated cost model): predicted vs the paper's measured
   batch times for all five setups + the two held-out validations.
2. REAL TIMED RUN (this host): ResNet-34-mini 2-stage simulated-time
   pipeline vs single device using the schedule simulator with real jitted
   per-stage compute — demonstrates the hybrid schedule executes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.calibrate import PAPER_MS, reproduction_table
from repro.core.partition import pipeline_batch_seconds, split_blocks


def main():
    rows = []
    for r in reproduction_table():
        rows.append([r["setup"], 0, f"pred={r['predicted_s']}s",
                     f"paper={r['paper_s']}s", f"rel_err={r['rel_err']}",
                     "HELD-OUT" if r["held_out"] else "fit"])
    # paper headline: % decrease vs desktop alone
    tbl = {r["setup"]: r for r in reproduction_table()}
    for pair, base in [("desktop_iph11", "desktop_alone"),
                       ("desktop_iph16", "desktop_alone"),
                       ("mac_iph16", "mac_alone")]:
        pred = 1 - tbl[pair]["predicted_s"] / tbl[base]["predicted_s"]
        meas = 1 - PAPER_MS[pair] / PAPER_MS[base]
        rows.append([f"decrease_{pair}", 0, f"pred={pred:.0%}",
                     f"paper={meas:.0%}", "", ""])

    # real timed mini 2-stage pipeline on this host
    from repro.configs.resnet34 import MINI
    from repro.models import resnet as R
    meta, params = R.init_resnet(MINI, jax.random.key(0))
    x = jnp.ones((8, 32, 32, 3))
    cut = len(params) // 2
    s1 = jax.jit(lambda p, x: R.forward(meta[:cut], p, x))
    s2 = jax.jit(lambda p, h: R.forward(meta[cut:], p, h))
    p1, p2 = params[:cut], params[cut:]
    h = s1(p1, x)
    full = jax.jit(lambda p, x: R.forward(meta, p, x))
    us_s1 = timeit(lambda: jax.block_until_ready(s1(p1, x)))
    us_s2 = timeit(lambda: jax.block_until_ready(s2(p2, h)))
    us_full = timeit(lambda: jax.block_until_ready(full(params, x)))
    m = 8
    pipe_us = max(us_s1, us_s2) * m + min(us_s1, us_s2)
    rows.append(["mini_2stage_real", round(pipe_us / m, 1),
                 f"single={us_full:.0f}us",
                 f"2dev_pipe={pipe_us/m:.0f}us/mb",
                 f"speedup={us_full/(pipe_us/m):.2f}x", ""])
    emit("pipeline", rows,
         ["name", "us_per_call", "d1", "d2", "d3", "d4"])


if __name__ == "__main__":
    main()
