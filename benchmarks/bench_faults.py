"""Chaos bench: the CI gate for the failure plane.

Two sections:

1. **fleet** (real engines) — a 3-worker ServingFleet serving mixed
   greedy/sampled traffic eats a seeded kill trace with >= 2 mid-decode
   deaths.  Asserted (regression-banded in ``baselines/faults.json``):
   ZERO lost requests, every output token-identical to an unkilled
   single-engine reference, recompute bounded by the checkpoint cadence
   (tokens-since-checkpoint + context re-prefill per stranded lane), and
   no parked orphans at drain.
2. **scale** (jax-free SimFleet) — 60 simulated workers, ~600 requests,
   a 12-kill trace mixing crash / partition / zombie.  Asserted: zero
   lost, loop and vector tick implementations bit-identical under kills,
   and bounded recompute at fleet scale.

JSON lands in ``experiments/bench/faults.json`` and is gated by
``benchmarks/check_regression.py`` against ``baselines/faults.json``.
"""
import argparse
import dataclasses
import json
import time

import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.hw.specs import DeviceProfile
from repro.runtime.faults import make_kill_trace
from repro.serving.metrics import SLOClass
from repro.serving.scale import ScaleWorkerSpec, SimFleet, make_rows

MAX_LEN = 64
MAX_NEW = 10
N_REQUESTS = 8


def _profile(name, rate=20.0):
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=rate,
                         prefill_tokens_per_s=1e9)


def bench_fleet(smoke: bool):
    import jax
    from repro.configs import RunConfig, get_config, reduced_config
    from repro.models.api import build_model
    from repro.serving.engine import ServeEngine
    from repro.serving.failover import FailoverConfig
    from repro.serving.fleet import ServingFleet, WorkerSpec, drive_sim
    from repro.serving.sampling import SamplingParams

    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RunConfig(param_dtype="float32",
                                      compute_dtype="float32", remat=False))
    params = model.init(jax.random.key(0))

    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, size=6 + i).astype(np.int32)
               for i in range(N_REQUESTS)]
    samplings = [SamplingParams(temperature=2.0, top_k=32, seed=100 + i)
                 if i % 2 else None for i in range(N_REQUESTS)]

    # >= 2 deaths mid-decode, leaving "a" the sole survivor
    trace = make_kill_trace(["b", "c"], 2, t0_s=0.4, t1_s=0.9, seed=1)
    failover = FailoverConfig(checkpoint_every_s=0.5)
    workers = [WorkerSpec(n, _profile(f"dev-{n}"), max_batch=4)
               for n in ("a", "b", "c")]
    fleet = ServingFleet(model, params, workers, max_len=MAX_LEN,
                         tick_s=0.05, kill_trace=trace, failover=failover)
    t0 = time.perf_counter()
    arrivals = np.linspace(0.0, 0.3, N_REQUESTS)
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=MAX_NEW,
                                     sampling=samplings[i]))
    wall = time.perf_counter() - t0
    snap = fleet.snapshot()

    ref = ServeEngine(model, params, max_batch=N_REQUESTS, max_len=MAX_LEN)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=MAX_NEW, sampling=sp)
    want = {r.rid: list(r.out_tokens) for r in ref.run_until_drained()}
    got = {rec.req.rid: list(rec.req.out_tokens) for rec in fleet.completed}
    identical = got == want
    lost = N_REQUESTS - snap.completed

    # recompute bound: per stranded lane, at most one checkpoint window of
    # decode (worst case the whole output) plus the context re-prefill
    max_ctx = max(len(p) for p in prompts) + MAX_NEW
    bound = snap.deaths * 4 * (MAX_NEW + max_ctx)
    assert lost == 0, f"lost {lost} requests to the kill trace"
    assert identical, "kill trace changed output tokens"
    assert snap.deaths >= 2, f"need >= 2 deaths, got {snap.deaths}"
    assert snap.resurrections >= 1, "no lane was resurrected"
    assert snap.orphaned == 0, f"{snap.orphaned} requests still parked"
    assert 0 < snap.recompute_tokens <= bound, (
        f"recompute {snap.recompute_tokens} outside (0, {bound}]")

    rows = [["faults_fleet", round(wall * 1e6, 0),
             f"completed={snap.completed}", f"deaths={snap.deaths}",
             f"resurrections={snap.resurrections}",
             f"recompute={snap.recompute_tokens}",
             f"identical={identical}"]]
    summary = {
        "completed": snap.completed,
        "lost": lost,
        "identical": identical,
        "deaths": snap.deaths,
        "resurrections": snap.resurrections,
        "recompute_tokens": snap.recompute_tokens,
        "recompute_bound": bound,
        "orphaned": snap.orphaned,
        "checkpoints": snap.checkpoints,
        "dead_units": list(snap.dead_units),
        "wall_s": wall,
    }
    return rows, summary


def bench_scale(smoke: bool):
    n_workers = 60
    n_requests = 600 if not smoke else 300
    n_kills = 12
    spec = ScaleWorkerSpec(profile=_profile("phone-sim", rate=10.0),
                           max_batch=4, max_queue=64)
    trace = make_kill_trace(list(range(n_workers)), n_kills,
                            t0_s=1.0, t1_s=20.0, seed=9,
                            kinds=("crash", "partition", "zombie"),
                            down_s=(0.5, 4.0))

    def run(impl):
        fleet = SimFleet(make_rows(spec, n_workers), tick_s=0.05,
                         slo=(SLOClass("default"),), admission=False,
                         kill_trace=trace, detect_s=0.5, ckpt_every_s=0.5,
                         impl=impl)
        rng = np.random.default_rng(5)
        for p, m in zip(rng.integers(8, 48, n_requests),
                        rng.integers(8, 48, n_requests)):
            fleet.submit(int(p), int(m))
        t0 = time.perf_counter()
        while not fleet.idle() and fleet.ticks < 200_000:
            fleet.tick()
        return fleet, time.perf_counter() - t0

    fleet, wall = run("vector")
    loop_fleet, _ = run("loop")
    snap, loop_snap = fleet.snapshot(), loop_fleet.snapshot()
    identical = snap == loop_snap
    lost = sum(1 for st in fleet.q_status if st < 0)

    # every stranded lane redoes at most one checkpoint window of decode
    # plus a prompt re-prefill (2x slack for tick granularity)
    bound = snap.deaths * 4 * int(2 * 0.5 * 10.0 + 48 + 2)
    assert lost == 0, f"{lost} requests never reached a terminal state"
    assert identical, "loop and vector diverged under the kill trace"
    assert snap.completed == snap.offered == n_requests
    assert snap.deaths >= 2, f"need >= 2 deaths, got {snap.deaths}"
    assert snap.orphaned == 0
    assert 0 < snap.recompute_tokens <= bound, (
        f"recompute {snap.recompute_tokens} outside (0, {bound}]")

    rows = [["faults_scale", round(wall * 1e6, 0),
             f"workers={n_workers}", f"offered={snap.offered}",
             f"deaths={snap.deaths}",
             f"resurrections={snap.resurrections}",
             f"recompute={snap.recompute_tokens}",
             f"identical={identical}"]]
    summary = {
        "workers": n_workers,
        "offered": snap.offered,
        "completed": snap.completed,
        "lost": lost,
        "identical": identical,
        "deaths": snap.deaths,
        "resurrections": snap.resurrections,
        "recompute_tokens": snap.recompute_tokens,
        "recompute_bound": bound,
        "orphaned": snap.orphaned,
        "wall_s": wall,
    }
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized config (the asserts ARE the gate: zero "
                         "lost, token-identical, bounded recompute)")
    args = ap.parse_args(argv)
    fleet_rows, fleet_summary = bench_fleet(args.smoke)
    scale_rows, scale_summary = bench_scale(args.smoke)
    rows = fleet_rows + scale_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("faults", rows,
         ["name", "us"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "faults.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "fleet": fleet_summary,
        "scale": scale_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
