"""Speculative decoding on the heterogeneous fleet: draft fast, verify slow.

The fleet asymmetry the paper builds on — a phone whose short bursts are
FAST next to the host's steady grind — is exactly what speculative
decoding converts into decode throughput: an ``a18-pro`` draft proposes
``k`` tokens per round, the ``m2-max-cpu`` target verifies them in one
scanned window, and every proposal/commit exchange crosses the link as a
real wire-codec frame charged against the pair's link budget.

Two sections:

1. **aligned draft** — the target's layers past the first are zeroed into
   exact residual identities, so a 1-layer prefix draft (an honest 1/4
   compute share) proposes exactly what the target samples.  Asserted
   (CI-gated via ``bench-smoke``): acceptance rate 1.0, the SpecPair
   fleet clears >= 1.5x the decode goodput of the same target serving
   alone, EVERY output token-identical to a plain single-engine run, and
   drafted-token frames actually crossed the charged link (bytes > 0).
2. **misaligned draft** — an independently-initialised draft whose
   proposals mostly miss: goodput degrades (rollback is not free) but the
   outputs stay bit-for-bit the baseline streams — the correctness story
   is independent of draft quality.

JSON (speedup, acceptance series, per-direction frame bytes) lands in
``experiments/bench/spec.json`` and is uploaded as a CI artifact.
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.configs import RunConfig, get_config, reduced_config
from repro.hw.specs import get_profile
from repro.models.api import build_model
from repro.serving.engine import ServeEngine
from repro.serving.fleet import (ServingFleet, SpecPair, WorkerSpec,
                                 drive_sim)
from repro.serving.sampling import SamplingParams

MAX_LEN = 96
TICK_S = 0.02
SPEC_K = 3


def _build():
    """4-layer target with layers 1..3's output projections zeroed (exact
    residual identities) + the 1-layer prefix as an ALIGNED draft, and an
    independently-initialised 1-layer MISALIGNED draft."""
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=4)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    for mod, name in (("attn", "wo"), ("mlp", "wo")):
        w = np.asarray(params["blocks"][mod][name]).copy()
        w[1:] = 0.0
        params["blocks"][mod][name] = jnp.asarray(w)
    dcfg = dataclasses.replace(cfg, n_layers=1)
    draft = build_model(dcfg, rcfg)
    aligned = {"embed": params["embed"], "final_ln": params["final_ln"],
               "blocks": jax.tree_util.tree_map(lambda x: x[:1],
                                                params["blocks"])}
    misaligned = draft.init(jax.random.key(3))
    return cfg, model, params, draft, aligned, misaligned


def _traffic(cfg, n, *, span_s, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(6, 16))) for _ in range(n)]
    arrivals = np.linspace(0.0, span_s, n)
    samplings = [SamplingParams(temperature=2.0, top_k=32, seed=1000 + i)
                 if i % 3 == 0 else None for i in range(n)]
    return prompts, arrivals, samplings


def _reference_tokens(model, params, prompts, samplings, max_new):
    """Plain single-engine run: the token-identity oracle."""
    ref = ServeEngine(model, params, max_batch=len(prompts), max_len=MAX_LEN)
    for p, sp in zip(prompts, samplings):
        ref.submit(p, max_new=max_new, sampling=sp)
    return {r.rid: r.out_tokens for r in ref.run_until_drained()}


def _run_target_alone(model, params, prompts, arrivals, samplings, max_new):
    """The comparison floor: the same target device serving solo."""
    fleet = ServingFleet(
        model, params,
        [WorkerSpec("host", get_profile("m2-max-cpu"), max_batch=4)],
        max_len=MAX_LEN, tick_s=TICK_S)
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=max_new,
                                     sampling=samplings[i]))
    return fleet, fleet.snapshot()


def _run_spec_pair(model, params, draft, dparams, prompts, arrivals,
                   samplings, max_new):
    pair = SpecPair(name="pair",
                    draft=WorkerSpec("phone", get_profile("a18-pro")),
                    target=WorkerSpec("host", get_profile("m2-max-cpu")),
                    draft_model=draft, draft_params=dparams,
                    spec_k=SPEC_K, max_batch=4)
    fleet = ServingFleet(model, params, spec_pairs=[pair], max_len=MAX_LEN,
                         tick_s=TICK_S)
    drive_sim(fleet, arrivals,
              lambda i: fleet.submit(prompts[i], max_new=max_new,
                                     sampling=samplings[i]))
    return fleet, fleet.snapshot()


def bench_aligned(cfg, model, params, draft, dparams, *, smoke: bool):
    n = 8 if smoke else 20
    max_new = 24 if smoke else 32
    span = 0.3 if smoke else 0.8
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=span)

    f_ref, ref = _run_target_alone(model, params, prompts, arrivals,
                                   samplings, max_new)
    f_spec, spec = _run_spec_pair(model, params, draft, dparams, prompts,
                                  arrivals, samplings, max_new)
    assert ref.completed == spec.completed == n, \
        f"dropped work: ref={ref.completed} spec={spec.completed} of {n}"

    want = _reference_tokens(model, params, prompts, samplings, max_new)
    got = {rec.req.rid: rec.req.out_tokens for rec in f_spec.completed}
    assert got == want, \
        "speculative fleet outputs must be token-identical to the plain run"

    ss = spec.per_spec["pair"]
    speedup = spec.goodput_tokens_per_s / ref.goodput_tokens_per_s
    assert ss.engine.spec_acceptance_rate == 1.0, (
        f"aligned draft must be accepted wholesale, got "
        f"{ss.engine.spec_acceptance_rate:.3f}")
    assert ss.frame_bytes > 0, "draft/verify frames must cross the link"
    assert speedup >= 1.5, (
        f"spec pair must clear >= 1.5x the solo target's decode goodput, "
        f"got {speedup:.2f}x ({spec.goodput_tokens_per_s:.1f} vs "
        f"{ref.goodput_tokens_per_s:.1f} tok/s)")

    rows = [
        ["spec_target_alone", round(ref.sim_t * 1e6, 0),
         f"goodput={ref.goodput_tokens_per_s:.1f}tok/s"],
        ["spec_pair_aligned", round(spec.sim_t * 1e6, 0),
         f"goodput={spec.goodput_tokens_per_s:.1f}tok/s",
         f"acceptance={ss.engine.spec_acceptance_rate:.2f}",
         f"rounds={ss.engine.spec_rounds}",
         f"frame_bytes={ss.frame_bytes}",
         f"transfer_s={ss.transfer_s:.4f}"],
        ["spec_speedup", round(speedup, 2), "token_identical=True",
         f"k={SPEC_K}"],
    ]
    summary = {
        "speedup": speedup,
        "goodput_spec": spec.goodput_tokens_per_s,
        "goodput_ref": ref.goodput_tokens_per_s,
        "acceptance_rate": ss.engine.spec_acceptance_rate,
        "accepted_series": list(ss.engine.spec_accepted_series),
        "rounds": ss.engine.spec_rounds,
        "frame_bytes": ss.frame_bytes,
        "transfer_s": ss.transfer_s,
        "token_identical": got == want,
        "spec": ss.engine.as_dict(),
    }
    return rows, summary


def bench_misaligned(cfg, model, params, draft, dparams, *, smoke: bool):
    n = 6 if smoke else 16
    max_new = 16 if smoke else 24
    prompts, arrivals, samplings = _traffic(cfg, n, span_s=0.3, seed=5)
    f_spec, spec = _run_spec_pair(model, params, draft, dparams, prompts,
                                  arrivals, samplings, max_new)
    assert spec.completed == n, f"dropped work: {spec.completed}/{n}"
    want = _reference_tokens(model, params, prompts, samplings, max_new)
    got = {rec.req.rid: rec.req.out_tokens for rec in f_spec.completed}
    assert got == want, \
        "a bad draft may slow decode down but NEVER changes the stream"
    ss = spec.per_spec["pair"]
    assert ss.engine.spec_acceptance_rate < 1.0
    rows = [["spec_pair_misaligned", round(spec.sim_t * 1e6, 0),
             f"goodput={spec.goodput_tokens_per_s:.1f}tok/s",
             f"acceptance={ss.engine.spec_acceptance_rate:.2f}",
             f"rounds={ss.engine.spec_rounds}",
             "token_identical=True"]]
    summary = {
        "goodput": spec.goodput_tokens_per_s,
        "acceptance_rate": ss.engine.spec_acceptance_rate,
        "accepted_series": list(ss.engine.spec_accepted_series),
        "rounds": ss.engine.spec_rounds,
        "frame_bytes": ss.frame_bytes,
        "token_identical": got == want,
    }
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized config")
    args = ap.parse_args(argv)
    cfg, model, params, draft, aligned, misaligned = _build()
    rows, summary = bench_aligned(cfg, model, params, draft, aligned,
                                  smoke=args.smoke)
    mis_rows, mis_summary = bench_misaligned(cfg, model, params, draft,
                                             misaligned, smoke=args.smoke)
    rows += mis_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("spec", rows,
         ["name", "us_sim"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "spec.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "aligned": summary,
        "misaligned": mis_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
