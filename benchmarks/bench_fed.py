"""Training-plane bench: the CI gate for federated serve-while-train.

Three sections:

1. **train** (real jax steps) — a 3-worker ServingFleet runs N federated
   rounds through the :class:`~repro.serving.train_plane.FedRoundCoordinator`
   twice per frame mode.  Asserted (regression-banded in
   ``baselines/fed.json``): two seeded replays produce BIT-IDENTICAL
   aggregated params; int8+error-feedback frames cut gradient wire bytes
   >= 3x vs the bf16 baseline at equal-or-better held-out loss after the
   same rounds; and training actually trains (loss well below init).
2. **kill** (failure-plane composition) — a crash lands mid-round on a
   participant.  Asserted: ZERO rounds lost (all configured rounds
   complete), the dead worker is excluded from its round's aggregation,
   and the exclusion is visible in the round snapshots.
3. **scale** (jax-free SimFleet mirror) — the same Poisson trace runs
   serve-only and serve-while-train.  Asserted: loop and vector tick
   implementations stay bit-identical with the training plane on, every
   mirrored round completes, and serving SLO attainment holds within a
   committed band of the serve-only baseline.

JSON lands in ``experiments/bench/fed.json`` and is gated by
``benchmarks/check_regression.py`` against ``baselines/fed.json``.
"""
import argparse
import dataclasses
import json
import time

import numpy as np

from benchmarks.common import OUT_DIR, emit
from repro.hw.specs import DeviceProfile
from repro.runtime.faults import make_kill_trace
from repro.serving.metrics import SLOClass
from repro.serving.scale import (FedSimConfig, ScaleWorkerSpec, SimFleet,
                                 make_rows, play)
from repro.serving.traffic import poisson_trace

N_ROUNDS = 6
# loss slack for the equal-or-better gate: the int8+EF run measurably
# beats bf16 at N_ROUNDS on the committed seeds; the epsilon only absorbs
# cross-platform float reduction differences
LOSS_EPS = 5e-3


def _profile(name):
    # prefill rate low enough that local training costs real sim seconds
    # (the charge queue, not the wall clock, paces rounds)
    return DeviceProfile(name=name, year=2024, flops=1e12, mem_bytes=8e9,
                         mem_bw=60e9, link_bw=1e9, decode_steps_per_s=20.0,
                         prefill_tokens_per_s=2000.0)


def _build():
    import jax
    from repro.configs import RunConfig, get_config, reduced_config
    from repro.models.api import build_model

    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    model = build_model(cfg, RunConfig(param_dtype="float32",
                                       compute_dtype="float32", remat=False))
    return model, model.init(jax.random.key(0))


def _run_coord(model, params, mode, rounds, kill_trace=None):
    from repro.serving.failover import FailoverConfig
    from repro.serving.fleet import ServingFleet, WorkerSpec
    from repro.serving.train_plane import FedConfig, FedRoundCoordinator

    workers = [WorkerSpec(n, _profile(f"dev-{n}"), max_batch=4)
               for n in ("a", "b", "c")]
    fleet = ServingFleet(model, params, workers, max_len=64, tick_s=0.05,
                         kill_trace=kill_trace,
                         failover=FailoverConfig(checkpoint_every_s=0.5)
                         if kill_trace is not None else None)
    fc = FedConfig(rounds=rounds, local_steps=2, participants=2, batch=4,
                   seq_len=32, lr=0.3, seed=0, mode=mode)
    coord = FedRoundCoordinator(fleet, model, fc)
    coord.run_rounds()
    return coord


def _eval_loss(model, params):
    from repro.data.synthetic import DataConfig, TokenPipeline

    dcfg = DataConfig(vocab_size=model.cfg.vocab_size, seq_len=32,
                      global_batch=8, seed=7)
    batch = TokenPipeline(dcfg, shard=0, n_shards=1).batch(999)
    return float(model.loss(params, batch)[0])


def bench_train(smoke):
    import jax

    model, params = _build()
    t0 = time.perf_counter()
    c_a = _run_coord(model, params, "int8_ef", N_ROUNDS)
    c_b = _run_coord(model, params, "int8_ef", N_ROUNDS)
    c_bf = _run_coord(model, params, "bf16", N_ROUNDS)
    wall = time.perf_counter() - t0

    identical = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(c_a.params),
                        jax.tree.leaves(c_b.params)))
    loss_init = _eval_loss(model, params)
    loss_i8 = _eval_loss(model, c_a.params)
    loss_bf = _eval_loss(model, c_bf.params)
    ratio = c_bf.wire_bytes_total / c_a.wire_bytes_total

    assert c_a.rounds_done == N_ROUNDS, (
        f"only {c_a.rounds_done}/{N_ROUNDS} rounds completed")
    assert c_a.deliveries == N_ROUNDS * 2, (
        f"expected {N_ROUNDS * 2} deliveries, got {c_a.deliveries}")
    assert identical, "two seeded replays disagree on aggregated params"
    assert ratio >= 3.0, (
        f"int8+EF frames only cut wire {ratio:.2f}x vs bf16 (need >= 3x)")
    assert loss_i8 <= loss_bf + LOSS_EPS, (
        f"int8+EF loss {loss_i8:.4f} worse than bf16 {loss_bf:.4f}")
    assert loss_i8 < loss_init - 0.5, (
        f"training barely moved loss: {loss_init:.4f} -> {loss_i8:.4f}")
    assert c_a.train_s_total > 0.0, "no training compute was charged"

    rows = [["fed_train", round(wall * 1e6, 0),
             f"rounds={c_a.rounds_done}", f"identical={identical}",
             f"ratio={ratio:.2f}", f"loss_i8={loss_i8:.4f}",
             f"loss_bf16={loss_bf:.4f}"]]
    summary = {
        "rounds": c_a.rounds_done,
        "deliveries": c_a.deliveries,
        "identical": identical,
        "wire_bytes_int8": c_a.wire_bytes_total,
        "wire_bytes_bf16": c_bf.wire_bytes_total,
        "wire_ratio": ratio,
        "loss_init": loss_init,
        "loss_int8": loss_i8,
        "loss_bf16": loss_bf,
        "train_s": c_a.train_s_total,
        "wall_s": wall,
    }
    return rows, summary


def bench_kill(smoke):
    model, params = _build()
    # one crash landing mid-round on worker "b" (a round spans ~0.4 sim s)
    trace = make_kill_trace(["b"], 1, t0_s=0.3, t1_s=0.31, seed=3)
    t0 = time.perf_counter()
    coord = _run_coord(model, params, "int8_ef", 3, kill_trace=trace)
    wall = time.perf_counter() - t0

    excluded = [r for r in coord.rounds if "b" in r.excluded]
    clean = [r for r in coord.rounds if r.excluded == ()]
    assert coord.rounds_done == 3, (
        f"kill cost rounds: {coord.rounds_done}/3 completed")
    assert coord.exclusions >= 1 and excluded, (
        "the mid-round crash never excluded worker b")
    for r in excluded:
        assert "b" not in r.delivered, "dead worker counted as delivered"
        assert r.samples == sum(
            coord.cfg.local_steps * coord.cfg.batch for _ in r.delivered), (
            "round weighted by more than its delivered samples")
    assert clean, "no round completed cleanly after the kill"

    rows = [["fed_kill", round(wall * 1e6, 0),
             f"rounds={coord.rounds_done}",
             f"exclusions={coord.exclusions}",
             f"deliveries={coord.deliveries}"]]
    summary = {
        "rounds": coord.rounds_done,
        "lost_rounds": 3 - coord.rounds_done,
        "exclusions": coord.exclusions,
        "deliveries": coord.deliveries,
        "excluded_rounds": [r.round_id for r in excluded],
        "wall_s": wall,
    }
    return rows, summary


def bench_scale(smoke):
    n_workers = 20
    duration = 20.0 if smoke else 60.0
    spec = ScaleWorkerSpec(profile=_profile("phone-sim"),
                           max_batch=4, max_queue=64)
    trace = poisson_trace(4.0, duration, seed=11,
                          prompt_tokens=(8, 48), max_new_tokens=(8, 32))
    slo = (SLOClass("default", ttft_s=2.0, tpot_s=1.0),)
    fed_cfg = FedSimConfig(rounds=N_ROUNDS, participants=2, local_steps=2,
                           step_tokens=128, frame_bytes=1 << 18,
                           round_timeout_s=60.0)

    def run(fed, impl):
        fleet = SimFleet(make_rows(spec, n_workers), tick_s=0.05, slo=slo,
                         admission=False, fed=fed, impl=impl)
        play(fleet, trace)
        while (fed is not None and fleet.fed_rounds < fed.rounds
               and fleet.ticks < 200_000):
            fleet.tick()
        return fleet

    t0 = time.perf_counter()
    base = run(None, "vector")
    fed_v = run(fed_cfg, "vector")
    fed_l = run(fed_cfg, "loop")
    wall = time.perf_counter() - t0

    snap_b, snap_v, snap_l = base.snapshot(), fed_v.snapshot(), fed_l.snapshot()
    identical = snap_v == snap_l
    att_base = snap_b.slo.attainment
    att_fed = snap_v.slo.attainment

    assert identical, "loop and vector diverged with the training plane on"
    assert snap_v.fed_rounds == N_ROUNDS, (
        f"mirror finished {snap_v.fed_rounds}/{N_ROUNDS} rounds")
    assert snap_v.fed_deliveries == N_ROUNDS * 2
    assert snap_v.fed_train_s > 0.0 and snap_v.fed_wire_bytes > 0
    assert snap_v.completed == snap_b.completed == len(trace), (
        "training interleave changed request completion")
    assert att_fed >= att_base - 0.05, (
        f"serve-while-train SLO attainment {att_fed:.3f} fell more than "
        f"0.05 below serve-only {att_base:.3f}")

    rows = [["fed_scale", round(wall * 1e6, 0),
             f"workers={n_workers}", f"rounds={snap_v.fed_rounds}",
             f"att_base={att_base:.3f}", f"att_fed={att_fed:.3f}",
             f"identical={identical}"]]
    summary = {
        "workers": n_workers,
        "offered": snap_v.offered,
        "completed": snap_v.completed,
        "identical": identical,
        "fed_rounds": snap_v.fed_rounds,
        "fed_deliveries": snap_v.fed_deliveries,
        "fed_excluded": snap_v.fed_excluded,
        "fed_train_s": snap_v.fed_train_s,
        "fed_wire_bytes": snap_v.fed_wire_bytes,
        "fed_preempt_ticks": snap_v.fed_preempt_ticks,
        "attainment_serve_only": att_base,
        "attainment_serve_train": att_fed,
        "attainment_drop": att_base - att_fed,
        "wall_s": wall,
    }
    return rows, summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized config (the asserts ARE the gate: "
                         "bit-deterministic fed-avg, >= 3x wire cut at "
                         "equal-or-better loss, bounded SLO drop, zero "
                         "rounds lost to a mid-round kill)")
    args = ap.parse_args(argv)
    train_rows, train_summary = bench_train(args.smoke)
    kill_rows, kill_summary = bench_kill(args.smoke)
    scale_rows, scale_summary = bench_scale(args.smoke)
    rows = train_rows + kill_rows + scale_rows
    width = max(len(r) for r in rows)
    rows = [r + [""] * (width - len(r)) for r in rows]
    emit("fed", rows,
         ["name", "us"] + [f"d{i}" for i in range(1, width - 1)])
    out = OUT_DIR / "fed.json"
    out.write_text(json.dumps({
        "smoke": args.smoke,
        "rows": [[str(x) for x in r] for r in rows],
        "train": train_summary,
        "kill": kill_summary,
        "scale": scale_summary,
    }, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
