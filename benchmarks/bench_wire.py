"""Wire codec / checkpoint throughput (the paper's Fig. 2 protocol at the
sizes a checkpoint shard actually moves)."""
import io

import numpy as np

from benchmarks.common import emit, timeit
from repro.wire import codec


def main():
    rows = []
    for mb in [1, 16, 64]:
        arr = np.random.default_rng(0).standard_normal(
            (mb * 1024 * 1024 // 4,)).astype(np.float32)
        data = codec.dumps({"a": arr})
        us_enc = timeit(lambda: codec.dumps({"a": arr}), n=3)
        us_dec = timeit(lambda: codec.loads(data), n=3)
        rows.append([f"pytree_{mb}MB", round(us_enc, 0),
                     f"encode={mb/(us_enc/1e6):.0f}MB/s",
                     f"decode={mb/(us_dec/1e6):.0f}MB/s"])
    emit("wire", rows, ["name", "us_per_call", "d1", "d2"])


if __name__ == "__main__":
    main()
