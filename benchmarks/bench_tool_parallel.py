"""Paper §4.3 / Fig. 7-8: async split-tool offload vs blocking tools.

Real measured run: tiny LM served by the continuous-batching engine; mock
vector-DB search with the paper's inflated latency (scaled to 0.4 s here);
the async mode must remove tool time from the critical path entirely.

Every agentic turn carries the same scenario prefix (system prompt +
tool-loop scaffold), so the engine runs with the paged backend and
prefix caching on: turn 1 populates the cache, later turns admit against
shared blocks, and fully-cached turns skip their prefill dispatch — the
per-mode rows report the measured hit rate and skipped prefills.
"""
import jax

from benchmarks.common import emit
from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.tool_loop import run_scenario

PREFIX_TOKENS = 48


def main():
    import dataclasses
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    db = VectorDB(n_docs=10_000, dim=64)
    queries = ["google search engine", "apple ipod", "microsoft windows"]

    def fresh():
        eng = ServeEngine(model, params, max_batch=1, max_len=96,
                          config=EngineConfig(kv_blocks=24, kv_block_size=8,
                                              prefix_cache=True))
        ex = ToolExecutor(n_workers=3)
        ex.register("vector_db_begin_search",
                    lambda query, k: db.search_text(query, int(k)),
                    simulated_seconds=0.4)
        return eng, ex

    rows = []
    for mode, async_tools in [("sync_fig8", False), ("async_fig7", True)]:
        eng, ex = fresh()
        tr = run_scenario(eng, ex, queries, async_tools=async_tools,
                          reason_tokens=10, summary_tokens=20,
                          prefix_tokens=PREFIX_TOKENS)
        snap = eng.metrics_snapshot()
        rows.append([mode, round(tr.total * 1e6, 0),
                     f"total={tr.total:.2f}s",
                     f"tool_wait={tr.time_in('tool_wait'):.2f}s",
                     f"generate={tr.time_in('reason')+tr.time_in('summarize'):.2f}s",
                     f"prefix_hit_rate={snap.prefix_hit_rate:.2f}",
                     f"prefill_skipped={snap.prefill_skipped}"])
        assert snap.prefix_hit_rate > 0.5, (
            f"shared scenario prefix must hit the cache on later turns, "
            f"got {snap.prefix_hit_rate:.2f}")
        for seg in tr.timeline():
            print(f"  timeline[{mode}] {seg['kind']:10s} "
                  f"{seg['start']:6.2f}-{seg['end']:6.2f}s {seg['label']}")
    sync_t = float(rows[0][2].split("=")[1][:-1])
    asyn_t = float(rows[1][2].split("=")[1][:-1])
    rows.append(["idle_eliminated", 0, f"saved={sync_t-asyn_t:.2f}s",
                 f"speedup={sync_t/asyn_t:.2f}x", "", "", ""])
    emit("tool_parallel", rows,
         ["name", "us_per_call", "d1", "d2", "d3", "d4", "d5"])


if __name__ == "__main__":
    main()
