"""Paper §4.3 reproduction: agentic LRM with split begin/retrieve tools.

Runs the paper's exact scenario (3 vector-DB searches + interleaved
summaries) in both modes and prints the Fig. 7 vs Fig. 8 timelines.

    PYTHONPATH=src python examples/agentic_tools.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB
from repro.serving.engine import ServeEngine
from repro.serving.tool_loop import run_scenario


def main():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    db = VectorDB(n_docs=100_000, dim=384)       # paper: 100k AG-News docs
    queries = ["google search engine", "apple ipod", "microsoft windows"]

    def fresh():
        eng = ServeEngine(model, params, max_batch=1, max_len=96)
        ex = ToolExecutor(n_workers=3)
        ex.register("vector_db_begin_search",
                    lambda query, k: db.search_text(query, int(k)),
                    simulated_seconds=0.5)       # paper's Task.sleep trick
        return eng, ex

    for label, mode in [("Fig.8 (blocking tools)", False),
                        ("Fig.7 (async offload)", True)]:
        tr = run_scenario(*fresh(), queries, async_tools=mode)
        print(f"\n[{label}] total={tr.total:.2f}s "
              f"tool_wait={tr.time_in('tool_wait'):.2f}s")
        for seg in tr.timeline():
            bar = "#" * max(1, int((seg["end"] - seg["start"]) * 20))
            print(f"  {seg['kind']:10s} {seg['start']:5.2f}s {bar} {seg['label']}")


if __name__ == "__main__":
    main()
