"""Quickstart: train a small LM end-to-end on CPU with the full stack
(synthetic bigram data -> model -> AdamW -> async checkpoints), then serve
it with batched requests.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, reduced_config
from repro.data.synthetic import DataConfig, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.engine import ServeEngine


def main():
    cfg = reduced_config(get_config("granite-8b"))
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=200,
                                weight_decay=0.01)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
        p2, o2, st = adamw.update(opt_cfg, g, opt, params)
        return p2, o2, dict(loss=loss, **st)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8,
                      seed=0)
    pipe = TokenPipeline(dcfg)

    def data_iter(start):
        def gen():
            s = start
            while True:
                yield {"tokens": jnp.asarray(pipe.batch(s)["tokens"])}
                s += 1
        return iter(gen())

    def init_state():
        p = model.init(jax.random.key(0))
        return p, adamw.init(p)

    tr = Trainer(TrainerConfig(total_steps=200, ckpt_every=50,
                               ckpt_dir="/tmp/repro_quickstart",
                               log_every=25),
                 step_fn, init_state, data_iter)
    out = tr.run()
    if out["losses"]:
        print(f"[quickstart] loss {out['losses'][0]:.3f} -> "
              f"{out['losses'][-1]:.3f} (bigram entropy floor ~{np.log(8):.3f})")
    else:
        # a finished checkpoint in ckpt_dir resumes AT total_steps: no new
        # train steps, no losses — still serve below
        print("[quickstart] restored fully-trained checkpoint (no new steps)")

    # serve the trained model
    eng = ServeEngine(model, out["params"], max_batch=4, max_len=160)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, size=10), max_new=8)
    done = eng.run_until_drained()
    print(f"[quickstart] served {len(done)} requests, "
          f"sample continuation: {done[0].out_tokens}")


if __name__ == "__main__":
    main()
