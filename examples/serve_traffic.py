"""Thermal-aware fleet demo: heterogeneous serving under a mid-run throttle.

Two simulated workers — a desktop host (``m2-max-cpu``) and a phone
(``iphone-11-pro``) — serve Poisson traffic.  Mid-run the phone starts
thermally throttling (paper §4.2, Fig. 6 ramp); the thermal monitor sees
its per-step latency creep, and the §5.2 elastic policies react on live
serving traffic: the phone is duty-cycled, drained (new arrivals route to
the host) and its decode lanes are MIGRATED — each preempted request
resumes token-identically on the host.  Every arrival's routing decision,
every elastic action, and the final per-worker goodput / thermal-state
occupancy are printed.

With ``--kill-trace`` the phone doesn't merely throttle — it CRASHES
mid-decode.  The heartbeat monitor narrates the suspect -> dead episode
and every stranded lane's resurrection from its last checkpoint on the
host (docs/SERVING.md, "Fault tolerance"); the summary reports deaths /
resurrections / recompute_tokens from the snapshot.

    PYTHONPATH=src python examples/serve_traffic.py [fcfs|spf|priority]
                                                    [--kill-trace]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import RunConfig, get_config, reduced_config
from repro.hw.specs import get_profile
from repro.models.api import build_model
from repro.runtime.elastic import ServingElasticPolicy
from repro.runtime.faults import make_kill_trace
from repro.serving.failover import FailoverConfig
from repro.serving.fleet import (ServingFleet, ThrottleTrace, WorkerSpec,
                                 drive_sim)
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import SchedulerConfig

RATE_RPS = 10.0          # offered load (requests per simulated second)
N_REQUESTS = 16
MAX_NEW = 12
THROTTLE_AT_S = 0.6      # phone starts ramping toward 6x slowdown here


def main(policy: str = "fcfs", kill: bool = False):
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))

    workers = [WorkerSpec("host", get_profile("m2-max-cpu"), max_batch=3),
               WorkerSpec("phone", get_profile("iphone-11-pro"),
                          max_batch=3)]
    # with --kill-trace the phone crashes outright instead of throttling:
    # the heartbeat monitor declares it dead and its lanes resurrect on
    # the host from their last checkpoint
    trace = make_kill_trace(["phone"], 1, t0_s=THROTTLE_AT_S,
                            t1_s=THROTTLE_AT_S + 0.01, seed=7) \
        if kill else None
    fleet = ServingFleet(
        model, params, workers, max_len=64, tick_s=0.05,
        scheduler=SchedulerConfig(policy=policy, max_queue=16),
        policy=ServingElasticPolicy(),
        throttle=None if kill else ThrottleTrace(
            {"phone": (THROTTLE_AT_S, 6.0, 0.15)}),
        kill_trace=trace,
        failover=FailoverConfig(checkpoint_every_s=0.25) if kill else None)

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / RATE_RPS, size=N_REQUESTS))
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 24)))
               for _ in range(N_REQUESTS)]

    fate = (f"phone CRASHES at t~{THROTTLE_AT_S}s (kill trace)" if kill
            else f"phone throttles 6x from t={THROTTLE_AT_S}s")
    print(f"policy={policy}  offered_load={RATE_RPS:g} req/s (simulated)  "
          f"n={N_REQUESTS}  workers=host(m2-max-cpu)+phone(iphone-11-pro)  "
          f"{fate}")

    def arrive(i: int) -> None:
        rid = fleet.submit(
            prompts[i], max_new=MAX_NEW,
            sampling=SamplingParams(temperature=0.7, top_p=0.95, seed=i))
        where = fleet.routed.get(rid, "REJECTED (queues full)") \
            if rid is not None else "REJECTED (queues full)"
        print(f"  t={fleet.sim_t:5.2f}s  arrive rid={i:<3d} "
              f"len={len(prompts[i]):<3d} -> {where}")

    drive_sim(fleet, arrivals, arrive)

    if kill:
        print("\nfailure plane (kill -> missed heartbeats -> suspect -> "
              "dead -> lanes resurrect from checkpoint):")
        for t, kind, name in fleet.failure_log:
            print(f"  t={t:5.2f}s  {kind:<24s} {name}")

    print("\nelastic actions (duty_cycle is re-asserted every tick while "
          "hot; repeats collapsed):")
    last = {}
    shown = 0
    for t, act in fleet.action_log:
        key = (act.kind, act.worker)
        if act.kind == "duty_cycle" and last.get(key) == act.detail["duty"]:
            continue
        last[key] = act.detail.get("duty")
        print(f"  t={t:5.2f}s  {act.kind:<10s} worker={act.worker} "
              f"{act.detail}")
        shown += 1
    if not shown:
        print("  (none — traffic finished before the throttle bit)")

    snap = fleet.snapshot()
    print(f"\ncompleted={snap.completed}  rejected={snap.rejected}  "
          f"expired={snap.expired}  sim_time={snap.sim_t:.2f}s")
    if kill:
        print(f"deaths={snap.deaths}  dead_units={list(snap.dead_units)}  "
              f"resurrections={snap.resurrections}  "
              f"recompute_tokens={snap.recompute_tokens}  "
              f"orphaned={snap.orphaned}  checkpoints={snap.checkpoints}")
    print(f"fleet goodput {snap.goodput_tokens_per_s:.1f} tok/s (sim)  "
          f"migrations={snap.migrations} "
          f"(requests moved: {snap.migrated_requests})  "
          f"drains={snap.drains} undrains={snap.undrains}")
    for name, w in snap.per_worker.items():
        occ = {s: f"{f:.0%}" for s, f in w.state_occupancy.items()}
        print(f"  {name:<6s} [{w.profile}]  "
              f"goodput={w.goodput_tokens_per_s:6.1f} tok/s  "
              f"steps={w.steps_run:<5d} state={w.thermal_state:<8s} "
              f"occupancy={occ}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    kill = "--kill-trace" in argv
    rest = [a for a in argv if a != "--kill-trace"]
    main(rest[0] if rest else "fcfs", kill=kill)
