"""Open-loop traffic demo: the serving engine under a synthetic arrival
process, the way a load balancer would see it.

Requests arrive as a Poisson process (open loop: arrivals don't wait for
the server), with mixed prompt lengths, priorities, per-request sampling
params, and a deadline on the lowest class.  The engine admits them through
the chosen policy with bucketed batched prefill, and the structured metrics
snapshot is printed at the end.

    PYTHONPATH=src python examples/serve_traffic.py [fcfs|spf|priority]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.serving.engine import ServeEngine
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import SchedulerConfig
from repro.serving.traffic import drive_open_loop

RATE_RPS = 12.0          # offered load (requests/second)
N_REQUESTS = 30
MAX_NEW = 8


def main(policy: str = "fcfs"):
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_batch=8, max_len=64,
                         scheduler=SchedulerConfig(policy=policy,
                                                   max_queue=16))

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / RATE_RPS, size=N_REQUESTS))
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 32)))
               for _ in range(N_REQUESTS)]
    priorities = rng.integers(0, 3, size=N_REQUESTS)

    # warm the jit caches so the first arrivals measure serving, not compiles
    engine.submit(prompts[0], max_new=2)
    engine.run_until_drained()
    engine.reset_stats()

    print(f"policy={policy}  offered_load={RATE_RPS:g} req/s  "
          f"n={N_REQUESTS}  slots={engine.max_batch}")

    def arrive(i: int, now: float) -> None:
        pr = int(priorities[i])
        rid = engine.submit(
            prompts[i], max_new=MAX_NEW, priority=pr,
            deadline_s=2.0 if pr == 0 else None,
            sampling=SamplingParams(temperature=0.7, top_p=0.95, seed=i))
        state = "queued" if rid is not None else "REJECTED (queue full)"
        print(f"  t={now:6.2f}s  arrive rid={i:<3d} prio={pr} "
              f"len={len(prompts[i]):<3d} -> {state}")

    drive_open_loop(engine, arrivals, arrive)
    snap = engine.metrics_snapshot()
    print(f"\ncompleted={snap.completed}  rejected={snap.rejected}  "
          f"expired={snap.expired}")
    print(f"ttft   mean={snap.ttft.mean:.3f}s  p50={snap.ttft.p50:.3f}s  "
          f"p95={snap.ttft.p95:.3f}s")
    print(f"tpot   mean={snap.tpot.mean * 1e3:.1f}ms/token")
    print(f"thruput {snap.tokens_per_s:.1f} tok/s over {snap.wall_s:.2f}s  "
          f"(slot_util={snap.slot_utilization:.0%}, "
          f"queue_depth_mean={snap.queue_depth_mean:.1f})")
    print(f"prefill {snap.prefill_requests} requests in "
          f"{snap.prefill_dispatches} dispatches "
          f"(x{snap.prefill_batch_mean:.1f} amortisation)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "fcfs")
