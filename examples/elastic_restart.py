"""Fault tolerance + thermal mitigation demo (paper §4.2/§5.2):
training hits an injected worker failure, restarts from the async
checkpoint, then a thermal throttle triggers the monitor's state machine
and the policies react (swap / duty-cycle / rebalance).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses
import shutil

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config, reduced_config
from repro.core.calibrate import calibrated_profiles, resnet_costs
from repro.data.synthetic import DataConfig, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw
from repro.runtime.elastic import DutyCyclePolicy, RebalancePolicy, SwapPolicy
from repro.runtime.faults import FaultPlan
from repro.runtime.monitor import ThermalMonitor
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=2)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
        p2, o2, st = adamw.update(opt_cfg, g, opt, params)
        return p2, o2, dict(loss=loss, **st)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    pipe = TokenPipeline(dcfg)

    def data_iter(start):
        def gen():
            s = start
            while True:
                yield {"tokens": jnp.asarray(pipe.batch(s)["tokens"])}
                s += 1
        return iter(gen())

    shutil.rmtree("/tmp/repro_elastic", ignore_errors=True)
    faults = FaultPlan(fail_at={23: "worker0"},
                       throttle={"worker0": (30, 1.15, 4)})
    tr = Trainer(TrainerConfig(total_steps=50, ckpt_every=10,
                               ckpt_dir="/tmp/repro_elastic", log_every=10),
                 step_fn,
                 lambda: (model.init(jax.random.key(0)),
                          adamw.init(model.init(jax.random.key(0)))),
                 data_iter, fault_plan=faults)
    out = tr.run()
    print(f"[elastic] survived {tr.restarts} failure(s); "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    states = [h["thermal"] for h in out["history"]]
    print(f"[elastic] thermal states: {'->'.join(dict.fromkeys(states))}")

    # mitigation policies on the paper's calibrated 2-device pipeline
    costs = resnet_costs()
    profs = calibrated_profiles()
    mon = ThermalMonitor(alpha=1.0, calibration_steps=1, warmup_skip=0)
    for t in [1.0, 1.0, 1.12, 1.12]:
        mon.observe("phone", t)
    for pol, act in [("swap", SwapPolicy(["spare0"]).step(mon)),
                     ("duty", DutyCyclePolicy().step(mon)),
                     ("rebalance", RebalancePolicy(
                         costs, [profs["xeon"], profs["iphone11"]],
                         efficiency=1.0).step(mon, ["host", "phone"]))]:
        print(f"[elastic] policy {pol}: {[a.kind for a in act]} "
              f"{[a.detail for a in act]}")


if __name__ == "__main__":
    main()
