"""Paper §4.1 reproduction as a runnable example: the hybrid GPipe/1F1B
pipeline training an LM across 8 (emulated) devices, vs the same model
single-device — gradients identical, schedule visible.

    python examples/pipeline_train.py            (sets its own XLA_FLAGS)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, get_config, reduced_config
from repro.core import pipeline as pp
from repro.core import schedules as S
from repro.data.synthetic import DataConfig, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw


def main():
    cfg = dataclasses.replace(reduced_config(get_config("granite-8b")),
                              n_layers=8)
    shape = ShapeConfig("ex", seq_len=64, global_batch=8, kind="train")
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False, schedule="hybrid", microbatches=4)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60,
                                weight_decay=0.01)
    built = pp.make_pp_train_step(cfg, shape, rcfg, mesh, opt_cfg)
    meta = built["meta"]
    print(f"[pipeline] S={meta['S']} stages x R={meta['R']} replica columns, "
          f"M={meta['M']} microbatches, schedule={meta['schedule']}, "
          f"{meta['ticks']} ticks/step")
    print("[pipeline] paper Fig.3 schedule for this run:")
    print(S.render(S.hybrid_table(meta["S"], meta["M"])))

    model = build_model(cfg, rcfg)
    params = built["to_pipeline"](model.init(jax.random.key(0)))
    opt = adamw.init(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      seed=0)
    pipe = TokenPipeline(dcfg)
    with mesh:
        step = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                       out_shardings=built["out_shardings"])
        losses = []
        for s in range(60):
            batch = {"tokens": jnp.asarray(pipe.batch(s)["tokens"])}
            t0 = time.perf_counter()
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
            if s % 10 == 0:
                print(f"[pipeline] step {s:3d} loss {losses[-1]:.4f} "
                      f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
    print(f"[pipeline] loss {losses[0]:.3f} -> {losses[-1]:.3f} — "
          f"trained entirely through the hybrid fused-F+B pipeline")


if __name__ == "__main__":
    main()
