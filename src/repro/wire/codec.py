"""Tensor wire protocol (paper Fig. 2), production-hardened.

The paper frames tensors on a TCP socket as ``dtype, shape, raw values``.
We keep that exact framing, add a magic/version header and a CRC32 trailer
(integrity matters once this carries checkpoints), and extend it to pytrees.

Frame layout (little-endian)::

    u32  magic        0x52505257  ("RPRW")
    u8   version      1
    u8   dtype_code   (see DTYPE_CODES)
    u16  rank
    u64  dim[rank]
    u8   payload[prod(dims) * itemsize]   (C-order raw values)
    u32  crc32(payload)

A *pytree frame* is a JSON header frame (dtype_code=255 carrying UTF-8) with
the treedef + leaf count, followed by one tensor frame per leaf.

This codec is used by: the checkpoint store, the elastic control plane, and
the tool-offload RPC — i.e. everywhere the paper used its socket protocol
except the activation plane (which on TPU is `lax.ppermute`, see DESIGN §8).
"""

from __future__ import annotations

import io
import json
import struct
import zlib
from typing import Any, BinaryIO, List, Tuple

import jax
import numpy as np

MAGIC = 0x52505257
VERSION = 1

# dtype_code -> numpy dtype. bfloat16 is serialised via its uint16 bit pattern.
DTYPE_CODES = {
    0: np.dtype(np.float32),
    1: np.dtype(np.float64),
    2: np.dtype(np.float16),
    3: np.dtype(np.int8),
    4: np.dtype(np.int16),
    5: np.dtype(np.int32),
    6: np.dtype(np.int64),
    7: np.dtype(np.uint8),
    8: np.dtype(np.uint16),
    9: np.dtype(np.uint32),
    10: np.dtype(np.uint64),
    11: np.dtype(np.bool_),
    12: "bfloat16",  # special-cased
    255: None,       # JSON header frame
}
_CODE_FOR: dict = {}
for _c, _d in DTYPE_CODES.items():
    if isinstance(_d, np.dtype):
        _CODE_FOR[_d] = _c
_BF16_CODE = 12
_JSON_CODE = 255

_HDR = struct.Struct("<IBBH")  # magic, version, dtype_code, rank


class WireError(ValueError):
    pass


def _np_bf16():
    import ml_dtypes  # ships with jax

    return np.dtype(ml_dtypes.bfloat16)


def encode_tensor(arr: Any, out: BinaryIO) -> int:
    """Encode one array as a wire frame. Returns bytes written."""
    arr = np.asarray(arr)
    if arr.dtype == _np_bf16():
        code = _BF16_CODE
        payload_arr = arr.view(np.uint16)
    else:
        try:
            code = _CODE_FOR[arr.dtype]
        except KeyError:
            raise WireError(f"unsupported dtype {arr.dtype}")
        payload_arr = arr
    payload = np.ascontiguousarray(payload_arr).tobytes()
    n = out.write(_HDR.pack(MAGIC, VERSION, code, arr.ndim))
    n += out.write(struct.pack(f"<{arr.ndim}Q", *arr.shape))
    n += out.write(payload)
    n += out.write(struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF))
    return n


def _read_exact(src: BinaryIO, n: int) -> bytes:
    buf = src.read(n)
    if len(buf) != n:
        raise WireError(f"truncated frame: wanted {n} bytes, got {len(buf)}")
    return buf


def decode_tensor(src: BinaryIO) -> np.ndarray:
    magic, version, code, rank = _HDR.unpack(_read_exact(src, _HDR.size))
    if magic != MAGIC:
        raise WireError(f"bad magic {magic:#x}")
    if version != VERSION:
        raise WireError(f"unsupported version {version}")
    shape = struct.unpack(f"<{rank}Q", _read_exact(src, 8 * rank)) if rank else ()
    if code == _JSON_CODE:
        raise WireError("unexpected JSON frame; use decode_pytree")
    if code == _BF16_CODE:
        np_dtype, view_as = np.dtype(np.uint16), _np_bf16()
    else:
        try:
            np_dtype, view_as = DTYPE_CODES[code], None
        except KeyError:
            raise WireError(f"unknown dtype code {code}")
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    payload = _read_exact(src, count * np_dtype.itemsize)
    (crc,) = struct.unpack("<I", _read_exact(src, 4))
    if crc != (zlib.crc32(payload) & 0xFFFFFFFF):
        raise WireError("payload CRC mismatch")
    arr = np.frombuffer(payload, dtype=np_dtype).reshape(shape)
    if view_as is not None:
        arr = arr.view(view_as)
    return arr.copy()  # own the memory


def _encode_json(obj: Any, out: BinaryIO) -> int:
    payload = json.dumps(obj, sort_keys=True).encode("utf-8")
    n = out.write(_HDR.pack(MAGIC, VERSION, _JSON_CODE, 1))
    n += out.write(struct.pack("<1Q", len(payload)))
    n += out.write(payload)
    n += out.write(struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF))
    return n


def _decode_json(src: BinaryIO) -> Any:
    magic, version, code, rank = _HDR.unpack(_read_exact(src, _HDR.size))
    if magic != MAGIC or code != _JSON_CODE or rank != 1:
        raise WireError("expected JSON frame")
    (length,) = struct.unpack("<1Q", _read_exact(src, 8))
    payload = _read_exact(src, length)
    (crc,) = struct.unpack("<I", _read_exact(src, 4))
    if crc != (zlib.crc32(payload) & 0xFFFFFFFF):
        raise WireError("JSON CRC mismatch")
    return json.loads(payload.decode("utf-8"))


def encode_pytree(tree: Any, out: BinaryIO) -> int:
    """Encode an arbitrary pytree of arrays (+ scalar ints/floats)."""
    leaves, treedef = jax.tree.flatten(tree)
    header = {"treedef": _treedef_to_json(treedef), "n_leaves": len(leaves)}
    n = _encode_json(header, out)
    for leaf in leaves:
        n += encode_tensor(np.asarray(leaf), out)
    return n


def decode_pytree(src: BinaryIO) -> Any:
    header = _decode_json(src)
    leaves = [decode_tensor(src) for _ in range(header["n_leaves"])]
    treedef = _treedef_from_json(header["treedef"])
    return jax.tree.unflatten(treedef, leaves)


def dumps(tree: Any) -> bytes:
    buf = io.BytesIO()
    encode_pytree(tree, buf)
    return buf.getvalue()


def loads(data: bytes) -> Any:
    return decode_pytree(io.BytesIO(data))


# --- treedef <-> JSON (dict/list/tuple/leaf structures only) ----------------
class _Leaf:
    """Sentinel marking a leaf position (distinct from a literal None node)."""


_LEAF = _Leaf()


def _treedef_to_json(treedef) -> Any:
    # Round-trip through an example tree of sentinels: structure only.
    example = jax.tree.unflatten(treedef, [_LEAF] * treedef.num_leaves)
    return _structure_to_json(example)


def _structure_to_json(obj: Any) -> Any:
    if obj is _LEAF:
        return {"t": "leaf"}
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, dict):
        return {"t": "dict", "k": sorted(obj.keys()),
                "v": [_structure_to_json(obj[k]) for k in sorted(obj.keys())]}
    if isinstance(obj, tuple):
        return {"t": "tuple", "v": [_structure_to_json(x) for x in obj]}
    if isinstance(obj, list):
        return {"t": "list", "v": [_structure_to_json(x) for x in obj]}
    raise WireError(f"unsupported pytree node {type(obj)}")


def _json_to_structure(spec: Any) -> Any:
    t = spec["t"]
    if t == "leaf":
        return _LEAF
    if t == "none":
        return None
    if t == "dict":
        return {k: _json_to_structure(v) for k, v in zip(spec["k"], spec["v"])}
    if t == "tuple":
        return tuple(_json_to_structure(v) for v in spec["v"])
    if t == "list":
        return [_json_to_structure(v) for v in spec["v"]]
    raise WireError(f"bad structure spec {t}")


def _treedef_from_json(spec: Any):
    example = _json_to_structure(spec)
    return jax.tree.structure(example, is_leaf=lambda x: x is _LEAF)
