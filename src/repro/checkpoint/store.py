"""Checkpoint store built on the paper's tensor wire protocol (Fig. 2).

Layout::

    <dir>/step_<N>/
        manifest.json        step, mesh, partition plan, data cursor, rng,
                             leaf index (path -> file, shape, dtype), crc
        shard_<k>.bin        wire-codec pytree frames (one per host in a real
                             fleet; single-host here writes shard_0)

Async: ``save_async`` snapshots to host RAM synchronously (donation-safe)
and writes to disk on a background thread — training continues immediately
(the paper's host kept computing while tensors streamed to the phone; same
overlap idea at the checkpoint layer).

Restore supports RESHARDING: arrays come back as host numpy and are
device_put against whatever sharding the (possibly different) mesh wants —
this is what elastic shrink/grow rides on.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.wire import codec


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        flat[name] = np.asarray(leaf)
    return flat


def save(ckpt_dir: Path, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    with open(tmp / "shard_0.bin", "wb") as f:
        n = codec.encode_pytree(flat, f)
    manifest = {
        "step": step,
        "format": "repro-wire-v1",
        "bytes": n,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if out.exists():
        import shutil

        shutil.rmtree(out)
    tmp.rename(out)                                   # atomic publish
    _gc(ckpt_dir, keep)
    return out


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        import shutil

        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: Path) -> Optional[int]:
    steps = sorted(p.name for p in Path(ckpt_dir).glob("step_*") if p.is_dir())
    # repro-lint: allow[R004] parses a checkpoint directory name (host string), not a device array
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: Path, step: Optional[int] = None,
            like: Any = None, shardings: Any = None) -> (Any, dict):
    """Returns (tree, manifest_extra).  ``like`` gives the target structure;
    ``shardings`` (optional pytree) reshard-places each leaf."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    src = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    with open(src / "shard_0.bin", "rb") as f:
        flat = codec.decode_pytree(f)
    if like is None:
        return flat, manifest.get("extra", {})
    leaves_like = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    flat_shard = None
    if shardings is not None:
        flat_shard = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    for i, (path, leaf) in enumerate(leaves_like[0]):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        arr = flat[name]
        want_dtype = np.asarray(leaf).dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype) if str(arr.dtype) != str(want_dtype) else arr
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[i])
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(leaves_like[1], out_leaves)
    return tree, manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist on a daemon thread."""

    def __init__(self, ckpt_dir: Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self.error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error:
            raise self.error

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()                                   # one in flight
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # snapshot

        def work():
            try:
                save(self.dir, step, host_tree, extra, self.keep)
                self.last_saved = step
            except BaseException as e:  # noqa: BLE001
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
