"""ResNet-34 in JAX — the paper's own experiment model (§4.1).

Implemented as an explicit list of blocks so the heterogeneous partitioner
can split it at any block boundary (the paper hand-picked splits like
"before Layer3 Block4"); `block_costs` exposes per-block FLOPs/bytes for the
cost model.  BatchNorm runs in batch-stats mode (training).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet34 import ResNetConfig


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _init_conv(key, k, cin, cout):
    fan_in = k * k * cin
    return jax.random.normal(key, (k, k, cin, cout)) * np.sqrt(2.0 / fan_in)


def _init_bn(c):
    return {"scale": jnp.ones((1, 1, 1, c)), "bias": jnp.zeros((1, 1, 1, c))}


def init_resnet(cfg: ResNetConfig, key) -> Tuple[List[dict], List[dict]]:
    """Returns (meta, params): ordered block lists.  ``meta`` holds static
    structure (kind/stride), ``params`` holds only arrays (differentiable)."""
    ks = iter(jax.random.split(key, 256))
    meta: List[dict] = [{"kind": "stem"}]
    params: List[dict] = [{
        "conv": _init_conv(next(ks), 7, 3, cfg.channels[0]),
        "bn": _init_bn(cfg.channels[0]),
    }]
    cin = cfg.channels[0]
    for stage, (n, cout) in enumerate(zip(cfg.stages, cfg.channels)):
        for i in range(n):
            stride = 2 if (i == 0 and stage > 0) else 1
            m = {"kind": "basic", "stride": stride}
            b = {
                "conv1": _init_conv(next(ks), 3, cin, cout),
                "bn1": _init_bn(cout),
                "conv2": _init_conv(next(ks), 3, cout, cout),
                "bn2": _init_bn(cout),
            }
            if stride != 1 or cin != cout:
                b["proj"] = _init_conv(next(ks), 1, cin, cout)
                b["proj_bn"] = _init_bn(cout)
            meta.append(m)
            params.append(b)
            cin = cout
    meta.append({"kind": "head"})
    params.append({
        "w": jax.random.normal(next(ks), (cin, cfg.n_classes)) * cin ** -0.5,
        "b": jnp.zeros((cfg.n_classes,)),
    })
    return meta, params


def apply_block(m: dict, b: dict, x: jax.Array) -> jax.Array:
    kind = m["kind"]
    if kind == "stem":
        x = jax.nn.relu(_bn(b["bn"], _conv(x, b["conv"], 2)))
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                     (1, 2, 2, 1), "SAME")
    if kind == "basic":
        h = jax.nn.relu(_bn(b["bn1"], _conv(x, b["conv1"], m["stride"])))
        h = _bn(b["bn2"], _conv(h, b["conv2"]))
        sc = x
        if "proj" in b:
            sc = _bn(b["proj_bn"], _conv(x, b["proj"], m["stride"]))
        return jax.nn.relu(h + sc)
    if kind == "head":
        x = jnp.mean(x, axis=(1, 2))
        return x @ b["w"] + b["b"]
    raise ValueError(kind)


def forward(meta: List[dict], params: List[dict], x: jax.Array,
            upto: int = None, start: int = 0) -> jax.Array:
    for m, b in zip(meta[start:upto], params[start:upto]):
        x = apply_block(m, b, x)
    return x


def loss_fn(params: List[dict], meta: List[dict], images: jax.Array,
            labels: jax.Array) -> jax.Array:
    logits = forward(meta, params, images)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def block_costs(cfg: ResNetConfig, meta: List[dict], params: List[dict],
                batch: int) -> List[Tuple[float, float]]:
    """(flops, boundary_bytes) per block for the heterogeneous partitioner.

    boundary_bytes = activation bytes crossing the cut AFTER this block —
    exactly what the paper's USB link had to carry per microbatch.
    """
    out = []
    hw = cfg.img_size // 4                 # after stem
    cin = cfg.channels[0]
    # stem
    sf = 2 * batch * (cfg.img_size // 2) ** 2 * 7 * 7 * 3 * cfg.channels[0]
    out.append((sf, batch * hw * hw * cin * 4))
    for m, b in zip(meta[1:-1], params[1:-1]):
        cout = b["conv1"].shape[-1]
        if m["stride"] == 2:
            hw //= 2
        f = 2 * batch * hw * hw * 9 * (cin * cout + cout * cout)
        if "proj" in b:
            f += 2 * batch * hw * hw * cin * cout
        out.append((f, batch * hw * hw * cout * 4))
        cin = cout
    out.append((2 * batch * cin * cfg.n_classes, batch * cfg.n_classes * 4))
    return out
