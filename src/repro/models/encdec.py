"""Encoder-decoder (whisper-small): conv audio frontend STUBBED — the
encoder consumes precomputed frame embeddings (B, n_frames, D) per the
assignment; sinusoidal positions; decoder = causal self-attn + cross-attn.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import attention as attn
from repro.models.common import (apply_mlp, chunked_xent, embed_tokens,
                                 init_embed, init_mlp, init_rmsnorm,
                                 rmsnorm, sinusoidal_positions)


def _dt(name):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attention(ks[0], cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def _init_dec_block(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attention(ks[0], cfg, dtype),
            "lnx": init_rmsnorm(cfg.d_model, dtype),
            "xattn": attn.init_attention(ks[1], cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.glu, dtype)}


def init_encdec(cfg: ModelConfig, key, param_dtype) -> dict:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": init_embed(ks[2], cfg.vocab_size, cfg.d_model, param_dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, param_dtype))(enc_keys),
        "enc_ln": init_rmsnorm(cfg.d_model, param_dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, param_dtype))(dec_keys),
        "final_ln": init_rmsnorm(cfg.d_model, param_dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           rcfg: RunConfig) -> jax.Array:
    cdt = _dt(rcfg.compute_dtype)
    t = frames.shape[1]
    pos = jnp.asarray(sinusoidal_positions(t, cfg.d_model))
    x = frames.astype(cdt) + pos.astype(cdt)[None]

    def body(x, bp):
        h = attn.attention(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                           use_rope=False, causal=False,
                           use_kernels=rcfg.use_kernels)
        x = x + h
        return x + apply_mlp(bp["mlp"], rmsnorm(bp["ln2"], x), cfg.act), None

    fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
    from repro.models.lm import maybe_scan
    x, _ = maybe_scan(fn, x, params["enc_blocks"], cfg.n_enc_layers,
                      rcfg.unroll_layers)
    return rmsnorm(params["enc_ln"], x)


# ---------------------------------------------------------------------------
# decoder (train)
# ---------------------------------------------------------------------------

def _dec_block_train(cfg, bp, x, enc_out, uk):
    x = x + attn.attention(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                           use_rope=False, causal=True, use_kernels=uk)
    x = x + attn.cross_attention(bp["xattn"], cfg, rmsnorm(bp["lnx"], x), enc_out)
    return x + apply_mlp(bp["mlp"], rmsnorm(bp["ln2"], x), cfg.act)


def encdec_loss(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array],
                rcfg: RunConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    cdt = _dt(rcfg.compute_dtype)
    tokens = batch["tokens"]
    enc_out = encode(cfg, params, batch["frames"], rcfg)
    t = tokens.shape[1]
    pos = jnp.asarray(sinusoidal_positions(t, cfg.d_model)).astype(cdt)
    x = embed_tokens(params["embed"], tokens, cdt) + pos[None]

    def body(x, bp):
        return _dec_block_train(cfg, bp, x, enc_out, rcfg.use_kernels), None

    fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
    from repro.models.lm import maybe_scan
    x, _ = maybe_scan(fn, x, params["dec_blocks"], cfg.n_layers,
                      rcfg.unroll_layers)
    x = rmsnorm(params["final_ln"], x)
    w = params["embed"]["tok"].T.astype(cdt)
    ce = chunked_xent(x[:, :-1], w, tokens[:, 1:], cfg.vocab_size,
                      chunk=min(2048, t - 1), unroll=rcfg.unroll_layers)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# prefill / decode (serving)
# ---------------------------------------------------------------------------

def encdec_prefill(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array],
                   rcfg: RunConfig, max_len: int) -> Tuple[jax.Array, dict]:
    """Encode frames + run the prompt through the decoder, building caches."""
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    tokens = batch["tokens"]
    bsz, t = tokens.shape
    enc_out = encode(cfg, params, batch["frames"], rcfg)
    pos = jnp.asarray(sinusoidal_positions(t, cfg.d_model)).astype(cdt)
    x = embed_tokens(params["embed"], tokens, cdt) + pos[None]
    positions = jnp.broadcast_to(jnp.arange(t), (bsz, t))

    def body(x, bp):
        h, ck, cv = attn.prefill_attn(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                                      positions, max_len, use_rope=False,
                                      use_kernels=uk)
        x = x + h
        x = x + attn.cross_attention(bp["xattn"], cfg, rmsnorm(bp["lnx"], x), enc_out)
        x = x + apply_mlp(bp["mlp"], rmsnorm(bp["ln2"], x), cfg.act)
        # cross K/V computed once here for reuse at decode time
        xk = enc_out @ bp["xattn"]["wk"]
        xv = enc_out @ bp["xattn"]["wv"]
        te = enc_out.shape[1]
        cl = {"k": ck.astype(cdt), "v": cv.astype(cdt),
              "xk": xk.reshape(bsz, te, cfg.n_kv_heads, cfg.head_dim).astype(cdt),
              "xv": xv.reshape(bsz, te, cfg.n_kv_heads, cfg.head_dim).astype(cdt)}
        return x, cl

    from repro.models.lm import maybe_scan
    x, layer_caches = maybe_scan(body, x, params["dec_blocks"], cfg.n_layers,
                                 rcfg.unroll_layers)
    x = rmsnorm(params["final_ln"], x)
    logits = x[:, -1] @ params["embed"]["tok"].T.astype(cdt)
    return logits, {"layers": layer_caches,
                    "pos": jnp.full((bsz,), t, jnp.int32)}


def encdec_decode_step(cfg: ModelConfig, params: dict, cache: dict,
                       tokens: jax.Array, rcfg: RunConfig) -> Tuple[jax.Array, dict]:
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    bsz = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (bsz,))
    # sinusoidal position for the current step (dynamic row per lane)
    span = cache["layers"]["k"].shape[2]
    table = jnp.asarray(sinusoidal_positions(span, cfg.d_model)).astype(cdt)
    x = embed_tokens(params["embed"], tokens, cdt)
    x = x + jnp.take(table, jnp.minimum(pos, span - 1), axis=0)[:, None]

    def body(x, inp):
        bp, cl = inp
        h, ck, cv = attn.decode_attn(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                                     cl["k"], cl["v"], pos, use_rope=False,
                                     use_kernels=uk)
        x = x + h
        # cross attention against precomputed enc K/V
        q = rmsnorm(bp["lnx"], x)
        h = attn.sdpa((q @ bp["xattn"]["wq"]).reshape(bsz, 1, cfg.n_heads, cfg.head_dim),
                      cl["xk"].astype(q.dtype), cl["xv"].astype(q.dtype),
                      None, cfg.head_dim ** -0.5)
        x = x + h.reshape(bsz, 1, cfg.q_dim) @ bp["xattn"]["wo"]
        x = x + apply_mlp(bp["mlp"], rmsnorm(bp["ln2"], x), cfg.act)
        return x, {"k": ck, "v": cv, "xk": cl["xk"], "xv": cl["xv"]}

    from repro.models.lm import maybe_scan
    x, new_layers = maybe_scan(body, x, (params["dec_blocks"], cache["layers"]),
                               cfg.n_layers, rcfg.unroll_layers)
    x = rmsnorm(params["final_ln"], x)
    logits = x[:, -1] @ params["embed"]["tok"].T.astype(cdt)
    return logits, {"layers": new_layers, "pos": pos + 1}


# ---------------------------------------------------------------------------
# cache + input specs
# ---------------------------------------------------------------------------

def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    L = cfg.n_layers
    te = cfg.frontend_seq
    kv = lambda s: jnp.zeros((L, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype)
    return {"layers": {"k": kv(max_len), "v": kv(max_len),
                       "xk": kv(te), "xv": kv(te)},
            "pos": jnp.zeros((batch,), jnp.int32)}


def encdec_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       rcfg: RunConfig) -> Dict[str, Any]:
    cdt = _dt(rcfg.compute_dtype)
    bsz = shape.global_batch
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((bsz, shape.seq_len), jnp.int32)
        specs["frames"] = jax.ShapeDtypeStruct((bsz, cfg.frontend_seq, cfg.d_model), cdt)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(
            functools.partial(init_encdec_cache, cfg, bsz, shape.seq_len, cdt))
    return specs
