"""Uniform per-layer blocks for each family.

Every family exposes the same entry points so that scan-over-layers, the
GSPMD pipelines and the shard_map pipeline can all treat layers as an opaque
stacked unit:

    init_block(key, cfg, dtype)                       -> bparams (one layer)
    block_train(cfg, bp, x, idx, uk)                  -> (x, aux)
    block_prefill(cfg, bp, x, idx, positions, span, uk) -> (x, cache_layer)
    block_decode(cfg, bp, x, cache_layer, pos, idx, uk) -> (x, cache_layer)

zamba2's SHARED attention block (one set of weights fired every
``attn_every`` layers) is handled by the assembly layer (`repro.models.lm`)
with its own compact ``n_attn``-slot cache — per-layer stacking would waste
``attn_every``× KV memory.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_mlp, init_mlp, init_rmsnorm, rmsnorm

ZERO = jnp.float32(0.0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.rwkv:
        return {"ln1": init_rmsnorm(d, dtype), "ln2": init_rmsnorm(d, dtype),
                "rwkv": rwkv_mod.init_rwkv6(ks[0], cfg, dtype)}
    if cfg.family in ("ssm", "hybrid"):
        return {"ln": init_rmsnorm(d, dtype),
                "mamba": ssm_mod.init_mamba2(ks[0], cfg, dtype)}
    p = {"ln1": init_rmsnorm(d, dtype), "ln2": init_rmsnorm(d, dtype),
         "attn": attn.init_attention(ks[0], cfg, dtype)}
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def init_shared(key, cfg: ModelConfig, dtype) -> Optional[dict]:
    """zamba2: one shared attention+MLP block applied every ``attn_every``."""
    if cfg.family == "hybrid" and cfg.attn_every:
        ks = jax.random.split(key, 2)
        return {"ln1": init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_attention(ks[0], cfg, dtype),
                "ln2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)}
    return None


def init_stacked_blocks(key, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_block(k, cfg, dtype))(keys)


def n_attn_applications(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid" and cfg.attn_every:
        return -(-cfg.n_layers // cfg.attn_every)      # ceil
    return 0


# ---------------------------------------------------------------------------
# train (full sequence, no cache)
# ---------------------------------------------------------------------------

def block_train(cfg: ModelConfig, bp: dict, x: jax.Array, idx,
                uk: bool) -> Tuple[jax.Array, jax.Array]:
    aux = ZERO
    if cfg.rwkv:
        h, _ = rwkv_mod.apply_rwkv6_tmix(bp["rwkv"], cfg, rmsnorm(bp["ln1"], x),
                                         use_kernels=uk)
        x = x + h
        h, _ = rwkv_mod.apply_rwkv6_cmix(bp["rwkv"], cfg, rmsnorm(bp["ln2"], x))
        return x + h, aux
    if cfg.family in ("ssm", "hybrid"):
        h, _ = ssm_mod.apply_mamba2(bp["mamba"], cfg, rmsnorm(bp["ln"], x),
                                    use_kernels=uk)
        return x + h, aux
    x = x + attn.attention(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                           use_rope=True, causal=True, use_kernels=uk)
    h = rmsnorm(bp["ln2"], x)
    if cfg.n_experts:
        y, aux = moe_mod.apply_moe(bp["moe"], cfg, h)
        return x + y, aux
    return x + apply_mlp(bp["mlp"], h, cfg.act), aux


# ---------------------------------------------------------------------------
# caches (one layer; the assembly stacks over layers)
# ---------------------------------------------------------------------------

def init_cache_layer(cfg: ModelConfig, batch: int, span: int, dtype) -> dict:
    if cfg.rwkv:
        d, h = cfg.d_model, cfg.n_heads
        dk = d // h
        return {"S": jnp.zeros((batch, h, dk, dk), jnp.float32),
                "last": jnp.zeros((batch, d), dtype),
                "last_c": jnp.zeros((batch, d), dtype)}
    if cfg.family in ("ssm", "hybrid"):
        d_in, nheads, conv_dim = ssm_mod.dims(cfg)
        return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
                "ssm": jnp.zeros((batch, nheads, cfg.ssm_headdim, cfg.ssm_state),
                                 jnp.float32)}
    return {"k": jnp.zeros((batch, span, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, span, cfg.n_kv_heads, cfg.head_dim), dtype)}


# ---------------------------------------------------------------------------
# prefill (full sequence -> activations + cache layer)
# ---------------------------------------------------------------------------

def block_prefill(cfg: ModelConfig, bp: dict, x: jax.Array, idx,
                  positions: jax.Array, span: int,
                  uk: bool) -> Tuple[jax.Array, dict]:
    b, t, _ = x.shape
    dtype = x.dtype
    if cfg.rwkv:
        d, hn = cfg.d_model, cfg.n_heads
        dk = d // hn
        st0 = {"S": jnp.zeros((b, hn, dk, dk), jnp.float32),
               "last": jnp.zeros((b, d), dtype)}
        h, st = rwkv_mod.apply_rwkv6_tmix(bp["rwkv"], cfg, rmsnorm(bp["ln1"], x),
                                          use_kernels=uk, state=st0)
        x = x + h
        h, last_c = rwkv_mod.apply_rwkv6_cmix(
            bp["rwkv"], cfg, rmsnorm(bp["ln2"], x),
            state={"last_c": jnp.zeros((b, d), dtype)})
        x = x + h
        return x, {"S": st["S"], "last": st["last"].astype(dtype),
                   "last_c": last_c.astype(dtype)}
    if cfg.family in ("ssm", "hybrid"):
        d_in, nheads, conv_dim = ssm_mod.dims(cfg)
        st0 = {"conv": jnp.zeros((b, cfg.ssm_conv - 1, conv_dim), dtype),
               "ssm": jnp.zeros((b, nheads, cfg.ssm_headdim, cfg.ssm_state),
                                jnp.float32)}
        h, st = ssm_mod.apply_mamba2(bp["mamba"], cfg, rmsnorm(bp["ln"], x),
                                     use_kernels=uk, state=st0)
        x = x + h
        return x, {"conv": st["conv"].astype(dtype), "ssm": st["ssm"]}
    h, ck, cv = attn.prefill_attn(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                                  positions, span, use_kernels=uk)
    x = x + h
    h = rmsnorm(bp["ln2"], x)
    if cfg.n_experts:
        y, _ = moe_mod.apply_moe(bp["moe"], cfg, h)
        x = x + y
    else:
        x = x + apply_mlp(bp["mlp"], h, cfg.act)
    return x, {"k": ck.astype(dtype), "v": cv.astype(dtype)}


# ---------------------------------------------------------------------------
# decode (one token, stateful)
# ---------------------------------------------------------------------------

def block_decode(cfg: ModelConfig, bp: dict, x: jax.Array, cache: dict,
                 pos: jax.Array, idx, uk: bool) -> Tuple[jax.Array, dict]:
    if cfg.rwkv:
        h, st = rwkv_mod.apply_rwkv6_tmix(
            bp["rwkv"], cfg, rmsnorm(bp["ln1"], x), use_kernels=False,
            state={"S": cache["S"], "last": cache["last"]})
        x = x + h
        h, last_c = rwkv_mod.apply_rwkv6_cmix(
            bp["rwkv"], cfg, rmsnorm(bp["ln2"], x), state={"last_c": cache["last_c"]})
        x = x + h
        return x, {"S": st["S"], "last": st["last"].astype(cache["last"].dtype),
                   "last_c": last_c.astype(cache["last_c"].dtype)}
    if cfg.family in ("ssm", "hybrid"):
        st0 = {"conv": cache["conv"], "ssm": cache["ssm"]}
        h, st = ssm_mod.apply_mamba2(bp["mamba"], cfg, rmsnorm(bp["ln"], x),
                                     use_kernels=False, state=st0)
        x = x + h
        return x, {"conv": st["conv"].astype(cache["conv"].dtype), "ssm": st["ssm"]}
    h, ck, cv = attn.decode_attn(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                                 cache["k"], cache["v"], pos, use_kernels=uk)
    x = x + h
    h = rmsnorm(bp["ln2"], x)
    if cfg.n_experts:
        y, _ = moe_mod.apply_moe(bp["moe"], cfg, h, group_size=max(1, x.shape[0]))
        x = x + y
    else:
        x = x + apply_mlp(bp["mlp"], h, cfg.act)
    return x, {"k": ck, "v": cv}


def block_decode_paged(cfg: ModelConfig, bp: dict, x: jax.Array,
                       kp: jax.Array, vp: jax.Array, block_tables: jax.Array,
                       pos: jax.Array, idx, uk: bool):
    """One-token decode against a paged KV pool (attention-cache families
    only — the assembly gates ssm/rwkv/hybrid to the dense path)."""
    h, kp, vp = attn.decode_attn_paged(bp["attn"], cfg, rmsnorm(bp["ln1"], x),
                                       kp, vp, block_tables, pos,
                                       use_kernels=uk)
    x = x + h
    h = rmsnorm(bp["ln2"], x)
    if cfg.n_experts:
        y, _ = moe_mod.apply_moe(bp["moe"], cfg, h, group_size=max(1, x.shape[0]))
        x = x + y
    else:
        x = x + apply_mlp(bp["mlp"], h, cfg.act)
    return x, kp, vp


# ---------------------------------------------------------------------------
# zamba2 shared attention block — fired by the assembly every ``attn_every``
# ---------------------------------------------------------------------------

def shared_attn_train(cfg: ModelConfig, shared: dict, x: jax.Array, idx,
                      uk: bool) -> jax.Array:
    def fire(x):
        h = x + attn.attention(shared["attn"], cfg, rmsnorm(shared["ln1"], x),
                               use_rope=True, causal=True, use_kernels=uk)
        return h + apply_mlp(shared["mlp"], rmsnorm(shared["ln2"], h), cfg.act)
    return jax.lax.cond(idx % cfg.attn_every == 0, fire, lambda x: x, x)


def shared_attn_prefill(cfg: ModelConfig, shared: dict, x: jax.Array, idx,
                        positions: jax.Array, ak: jax.Array, av: jax.Array,
                        uk: bool):
    """ak/av: (n_attn, B, span, KVH, Dh) stacked slots; slot = idx//attn_every."""
    span = ak.shape[2]
    slot = idx // cfg.attn_every

    def fire(arg):
        x, ak, av = arg
        h, ck, cv = attn.prefill_attn(shared["attn"], cfg, rmsnorm(shared["ln1"], x),
                                      positions, span, use_kernels=uk)
        y = x + h
        y = y + apply_mlp(shared["mlp"], rmsnorm(shared["ln2"], y), cfg.act)
        ak = jax.lax.dynamic_update_index_in_dim(ak, ck.astype(ak.dtype), slot, 0)
        av = jax.lax.dynamic_update_index_in_dim(av, cv.astype(av.dtype), slot, 0)
        return y, ak, av

    return jax.lax.cond(idx % cfg.attn_every == 0, fire, lambda a: a, (x, ak, av))


def shared_attn_decode(cfg: ModelConfig, shared: dict, x: jax.Array, idx,
                       pos: jax.Array, ak: jax.Array, av: jax.Array, uk: bool):
    slot = idx // cfg.attn_every

    def fire(arg):
        x, ak, av = arg
        ck = jax.lax.dynamic_index_in_dim(ak, slot, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(av, slot, 0, keepdims=False)
        h, nck, ncv = attn.decode_attn(shared["attn"], cfg, rmsnorm(shared["ln1"], x),
                                       ck, cv, pos, use_kernels=uk)
        y = x + h
        y = y + apply_mlp(shared["mlp"], rmsnorm(shared["ln2"], y), cfg.act)
        ak = jax.lax.dynamic_update_index_in_dim(ak, nck, slot, 0)
        av = jax.lax.dynamic_update_index_in_dim(av, ncv, slot, 0)
        return y, ak, av

    return jax.lax.cond(idx % cfg.attn_every == 0, fire, lambda a: a, (x, ak, av))
