"""Attention: GQA (full causal / chunked-local / cross), with KV-cache decode.

Reference path is pure jnp (memory-safe blockwise softmax for long seqs via
the flash oracle in :mod:`repro.kernels.ref`); the Pallas kernels in
:mod:`repro.kernels` are routed in when ``use_kernels`` is on.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, truncated_normal

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = d ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (d, qd), s, dtype),
        "wk": truncated_normal(ks[1], (d, kvd), s, dtype),
        "wv": truncated_normal(ks[2], (d, kvd), s, dtype),
        "wo": truncated_normal(ks[3], (qd, d), qd ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def _proj_qkv(params: dict, x: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    q = x @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    b, t = x.shape[:2]
    tk = xkv.shape[1]
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, tk, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, tk, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)).reshape(b, t, h * n_rep, d)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         mask: Optional[jax.Array], scale: float) -> jax.Array:
    """q (B,Tq,H,Dh), k/v (B,Tk,H,Dh) [already GQA-expanded]; mask broadcastable
    to (B,H,Tq,Tk) boolean (True = attend)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(tq: int, tk: int, offset: int = 0) -> jax.Array:
    """True where kv position <= query position. offset = tk - tq alignment."""
    qpos = jnp.arange(tq)[:, None] + offset
    kpos = jnp.arange(tk)[None, :]
    return kpos <= qpos


def chunk_mask(tq: int, tk: int, chunk: int, offset: int = 0) -> jax.Array:
    """Causal AND same-chunk (llama4 iRoPE-style chunked attention)."""
    qpos = jnp.arange(tq)[:, None] + offset
    kpos = jnp.arange(tk)[None, :]
    return (kpos <= qpos) & (qpos // chunk == kpos // chunk)


def attention(params: dict, cfg: ModelConfig, x: jax.Array, *,
              positions: Optional[jax.Array] = None,
              use_rope: bool = True,
              causal: bool = True,
              use_kernels: bool = False) -> jax.Array:
    """Self-attention over full sequence (training / prefill)."""
    b, t, _ = x.shape
    q, k, v = _proj_qkv(params, x, x, cfg)
    if use_rope:
        if positions is None:
            positions = jnp.arange(t)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    local_chunk = cfg.chunk_size if cfg.attention == "chunked_local" else 0
    if use_kernels:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, scale=scale,
                                   chunk=local_chunk)
    elif t > 1024:
        # blockwise online-softmax path: the (T,T) score matrix would not fit
        from repro.models.flash_ref import flash_attention_ref
        out = flash_attention_ref(q, k, v, causal=causal, scale=scale,
                                  chunk=local_chunk)
    else:
        nrep = cfg.n_heads // cfg.n_kv_heads
        kk, vv = _repeat_kv(k, nrep), _repeat_kv(v, nrep)
        if local_chunk:
            mask = chunk_mask(t, t, local_chunk)[None, None]
        elif causal:
            mask = causal_mask(t, t)[None, None]
        else:
            mask = None
        out = sdpa(q, kk, vv, mask, scale)
    return out.reshape(b, t, cfg.q_dim) @ params["wo"]


def cross_attention(params: dict, cfg: ModelConfig, x: jax.Array,
                    enc_out: jax.Array) -> jax.Array:
    b, t, _ = x.shape
    q, k, v = _proj_qkv(params, x, enc_out, cfg)
    nrep = cfg.n_heads // cfg.n_kv_heads
    out = sdpa(q, _repeat_kv(k, nrep), _repeat_kv(v, nrep), None, cfg.head_dim ** -0.5)
    return out.reshape(b, t, cfg.q_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# KV-cache decode (layer-local API: caches are scanned over layers)
# ---------------------------------------------------------------------------

def cache_span(cfg: ModelConfig, max_len: int) -> int:
    """chunked_local archs only need the last ``chunk_size`` positions."""
    return max_len if cfg.attention != "chunked_local" else min(max_len, cfg.chunk_size)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  n_layers: Optional[int] = None) -> dict:
    L = cfg.n_layers if n_layers is None else n_layers
    span = cache_span(cfg, max_len)
    shape = (L, batch, span, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attn(params: dict, cfg: ModelConfig, x: jax.Array,
                ck: jax.Array, cv: jax.Array, pos: jax.Array, *,
                use_rope: bool = True,
                use_kernels: bool = False) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode for one layer.

    x: (B,1,D); ck/cv: (B,span,KVH,Dh); pos: (B,) int32 per-lane positions
    (tokens seen) — per-lane so the serving engine can continuously batch.
    Returns (out (B,1,D), new ck, new cv).
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = _proj_qkv(params, x, x, cfg)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    span = ck.shape[1]
    if cfg.attention == "chunked_local":
        slot = pos % span      # ring buffer: sliding-window approximation of
        #                        chunked attention at decode time (DESIGN §8)
    else:
        slot = jnp.minimum(pos, span - 1)
    lane = jnp.arange(b)
    ck = ck.at[lane, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[lane, slot].set(v[:, 0].astype(cv.dtype))

    # valid positions: everything written so far (ring keeps only the window
    # for chunked_local, so "written" == "within window" by construction)
    kidx = jnp.arange(span)[None, :]
    valid = kidx <= jnp.minimum(pos, span - 1)[:, None]   # (B, span)
    if use_kernels:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                    valid, scale=cfg.head_dim ** -0.5)
    else:
        nrep = cfg.n_heads // cfg.n_kv_heads
        kk = _repeat_kv(ck.astype(q.dtype), nrep)
        vv = _repeat_kv(cv.astype(q.dtype), nrep)
        mask = valid[:, None, None, :]                # -> (B,H,1,span)
        out = sdpa(q, kk, vv, mask, cfg.head_dim ** -0.5)
    return out.reshape(b, 1, cfg.q_dim) @ params["wo"], ck, cv


def decode_attn_paged(params: dict, cfg: ModelConfig, x: jax.Array,
                      kp: jax.Array, vp: jax.Array, block_tables: jax.Array,
                      pos: jax.Array, *, use_rope: bool = True,
                      use_kernels: bool = False):
    """One-token decode for one layer against a PAGED KV pool.

    x: (B,1,D); kp/vp: (nb, bs, KVH, Dh) — the shared block pool for this
    layer (block 0 is the garbage sink); block_tables: (B, max_blocks)
    int32 physical block ids per lane; pos: (B,) int32 tokens seen.
    Returns (out (B,1,D), new kp, new vp).

    Token ``pos`` of a lane lives at physical slot
    ``block_tables[lane, pos // bs] * bs + pos % bs`` of the flattened
    pool; lanes own disjoint blocks so the scatter below cannot collide
    (idle lanes all point at the sink, whose content is never read).
    """
    b = x.shape[0]
    nb, bs = kp.shape[0], kp.shape[1]
    span_l = block_tables.shape[1] * bs           # per-lane logical capacity
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = _proj_qkv(params, x, x, cfg)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    p_eff = jnp.minimum(pos, span_l - 1)          # saturate like the dense path
    lane = jnp.arange(b)
    dest = block_tables[lane, p_eff // bs] * bs + p_eff % bs      # (B,) flat
    kp = kp.reshape((nb * bs,) + kp.shape[2:]).at[dest].set(
        k[:, 0].astype(kp.dtype)).reshape(kp.shape)
    vp = vp.reshape((nb * bs,) + vp.shape[2:]).at[dest].set(
        v[:, 0].astype(vp.dtype)).reshape(vp.shape)
    scale = cfg.head_dim ** -0.5
    if use_kernels:
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(q, kp.astype(q.dtype),
                                          vp.astype(q.dtype), block_tables,
                                          p_eff, scale=scale)
    else:
        # gather reference: materialise each lane's logical KV view
        ck = kp[block_tables].reshape(b, span_l, cfg.n_kv_heads, cfg.head_dim)
        cv = vp[block_tables].reshape(b, span_l, cfg.n_kv_heads, cfg.head_dim)
        valid = jnp.arange(span_l)[None, :] <= p_eff[:, None]     # (B, span_l)
        nrep = cfg.n_heads // cfg.n_kv_heads
        kk = _repeat_kv(ck.astype(q.dtype), nrep)
        vv = _repeat_kv(cv.astype(q.dtype), nrep)
        out = sdpa(q, kk, vv, valid[:, None, None, :], scale)
    return out.reshape(b, 1, cfg.q_dim) @ params["wo"], kp, vp


def prefill_attn(params: dict, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, span: int, *,
                 use_rope: bool = True,
                 use_kernels: bool = False):
    """Full self-attention AND the K/V cache content for one layer.

    Returns (out (B,T,D), ck (B,span,KVH,Dh), cv)."""
    b, t, _ = x.shape
    q, k, v = _proj_qkv(params, x, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    local_chunk = cfg.chunk_size if cfg.attention == "chunked_local" else 0
    if use_kernels:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, scale=scale, chunk=local_chunk)
    elif t > 1024:
        from repro.models.flash_ref import flash_attention_ref
        out = flash_attention_ref(q, k, v, causal=True, scale=scale,
                                  chunk=local_chunk)
    else:
        nrep = cfg.n_heads // cfg.n_kv_heads
        if local_chunk:
            mask = chunk_mask(t, t, local_chunk)[None, None]
        else:
            mask = causal_mask(t, t)[None, None]
        out = sdpa(q, _repeat_kv(k, nrep), _repeat_kv(v, nrep), mask, scale)
    out = out.reshape(b, t, cfg.q_dim) @ params["wo"]
    if t >= span:                                     # chunked_local: keep tail
        ck, cv = k[:, t - span:], v[:, t - span:]
    else:
        pad = jnp.zeros((b, span - t) + k.shape[2:], k.dtype)
        ck, cv = jnp.concatenate([k, pad], 1), jnp.concatenate([v, pad], 1)
    return out, ck, cv
