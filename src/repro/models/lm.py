"""Decoder-only LM assembly: dense / MoE / SSM / RWKV / hybrid / VLM-audio-backbone.

Params pytree::

    {"embed": {"tok": (Vp, D)},
     "blocks": <stacked (L, ...) block params>,
     "shared": <zamba2 shared attn block>          (hybrid only)
     "final_ln": {"scale": (D,)},
     "head": {"w": (D, Vp)}}                       (absent when tied)

Cache pytree (decode)::

    {"layers": <stacked (L, ...) per-layer cache>,
     "ak"/"av": (n_attn, B, span, KVH, Dh)         (hybrid only)
     "pos": int32 scalar}

The frontends ([audio]/[vlm]) are STUBS per the assignment: ``input_specs``
exposes precomputed frame/patch embeddings of shape (B, P, D); the first P
sequence positions are those embeddings, the rest are token embeddings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import blocks as B
from repro.models.common import (chunked_xent, embed_tokens, init_embed,
                                 init_head, init_rmsnorm, pad_vocab, rmsnorm)

AUX_COEF = 0.01


def _dt(name: str):
    return jnp.dtype(name)


def maybe_scan(body, carry, xs, length: int, unroll: bool):
    """lax.scan, or an unrolled Python loop (dry-run: exact HLO accounting).

    ``body(carry, x) -> (carry, y)``; xs is a pytree with leading dim
    ``length`` (or None).  Returns (carry, stacked_ys or None).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    ys = []
    for i in range(length):
        x = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key, param_dtype) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, param_dtype),
        "blocks": B.init_stacked_blocks(ks[1], cfg, cfg.n_layers, param_dtype),
        "final_ln": init_rmsnorm(cfg.d_model, param_dtype),
    }
    shared = B.init_shared(ks[2], cfg, param_dtype)
    if shared is not None:
        p["shared"] = shared
    if not cfg.tie_embeddings:
        p["head"] = init_head(ks[3], cfg.d_model, cfg.vocab_size, param_dtype)
    return p


def head_weight(cfg: ModelConfig, params: dict, dtype) -> jax.Array:
    if cfg.tie_embeddings:
        # embed rows are ~unit-norm; rescale for head use to keep logits O(1)
        return params["embed"]["tok"].T.astype(dtype) * (cfg.d_model ** -0.5)
    return params["head"]["w"].astype(dtype)


# ---------------------------------------------------------------------------
# train loss
# ---------------------------------------------------------------------------

def _scan_train(cfg: ModelConfig, params: dict, x: jax.Array,
                rcfg: RunConfig) -> Tuple[jax.Array, jax.Array]:
    uk = rcfg.use_kernels
    shared = params.get("shared")

    from repro.core.sharding import constrain

    def body(carry, inp):
        x, aux = carry
        bp, idx = inp
        x = constrain("residual", x)
        x, a = B.block_train(cfg, bp, x, idx, uk)
        if shared is not None:
            x = B.shared_attn_train(cfg, shared, x, idx, uk)
        return (x, aux + a), None

    fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
    (x, aux), _ = maybe_scan(fn, (x, B.ZERO),
                             (params["blocks"], jnp.arange(cfg.n_layers)),
                             cfg.n_layers, rcfg.unroll_layers)
    return x, aux


def lm_loss(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array],
            rcfg: RunConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    cdt = _dt(rcfg.compute_dtype)
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cdt)
    if "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(cdt), x], axis=1)
    p = 0 if "frontend" not in batch else batch["frontend"].shape[1]
    x, aux = _scan_train(cfg, params, x, rcfg)
    x = rmsnorm(params["final_ln"], x)
    w = head_weight(cfg, params, cdt)
    t_tok = tokens.shape[1]
    if p:
        h = x[:, p - 1 : p + t_tok - 1]
        labels = tokens
    else:
        h = x[:, : t_tok - 1]
        labels = tokens[:, 1:]
    ce = chunked_xent(h, w, labels, cfg.vocab_size,
                      unroll=rcfg.unroll_layers)
    loss = ce + AUX_COEF * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _prefill_trunk(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array],
                   rcfg: RunConfig, max_len: int):
    """Shared prompt forward: returns (hidden (B,T,D) post-final-norm,
    layer_caches, (ak, av) or None)."""
    from repro.models.attention import cache_span

    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    tokens = batch["tokens"]
    bsz = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cdt)
    if "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(cdt), x], axis=1)
    t = x.shape[1]
    span = cache_span(cfg, max_len)
    positions = jnp.broadcast_to(jnp.arange(t), (bsz, t))
    shared = params.get("shared")
    n_attn = B.n_attn_applications(cfg)
    ak = av = None
    if n_attn:
        ak = jnp.zeros((n_attn, bsz, span, cfg.n_kv_heads, cfg.head_dim), cdt)
        av = jnp.zeros_like(ak)

    def body(carry, inp):
        bp, idx = inp
        if n_attn:
            x, ak, av = carry
            x, cl = B.block_prefill(cfg, bp, x, idx, positions, span, uk)
            x, ak, av = B.shared_attn_prefill(cfg, shared, x, idx, positions,
                                              ak, av, uk)
            return (x, ak, av), cl
        x = carry
        x, cl = B.block_prefill(cfg, bp, x, idx, positions, span, uk)
        return x, cl

    init = (x, ak, av) if n_attn else x
    fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
    carry, layer_caches = maybe_scan(fn, init,
                                     (params["blocks"], jnp.arange(cfg.n_layers)),
                                     cfg.n_layers, rcfg.unroll_layers)
    if n_attn:
        x, ak, av = carry
    else:
        x = carry
    x = rmsnorm(params["final_ln"], x)
    return x, layer_caches, ((ak, av) if n_attn else None)


def lm_prefill(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array],
               rcfg: RunConfig, max_len: int) -> Tuple[jax.Array, dict]:
    """Process a prompt; return (last-token logits (B, Vp), cache)."""
    cdt = _dt(rcfg.compute_dtype)
    x, layer_caches, attn = _prefill_trunk(cfg, params, batch, rcfg, max_len)
    bsz, t = x.shape[:2]
    logits = x[:, -1] @ head_weight(cfg, params, cdt)
    cache = {"layers": layer_caches, "pos": jnp.full((bsz,), t, jnp.int32)}
    if attn is not None:
        cache["ak"], cache["av"] = attn
    return logits, cache


def lm_prefill_padded(cfg: ModelConfig, params: dict,
                      batch: Dict[str, jax.Array], lengths: jax.Array,
                      rcfg: RunConfig, max_len: int) -> Tuple[jax.Array, dict]:
    """Batched prefill of right-padded prompts with true ``lengths`` (B,).

    Exact for full causal attention: pad tokens sit strictly AFTER every real
    token, so causality keeps them out of all real hidden states, the logits
    are gathered at each lane's last real position, and the per-lane cache
    ``pos`` masks the pad garbage out of decode until the very step that
    overwrites it.  Recurrent families (ssm / rwkv / hybrid) fold pad tokens
    into their state, so serving must not route them here — build_model
    only wires ``DecodeState.batched_prefill`` for eligible configs.
    """
    cdt = _dt(rcfg.compute_dtype)
    x, layer_caches, attn = _prefill_trunk(cfg, params, batch, rcfg, max_len)
    bsz = x.shape[0]
    lengths = jnp.asarray(lengths, jnp.int32)
    h = x[jnp.arange(bsz), lengths - 1]
    logits = h @ head_weight(cfg, params, cdt)
    cache = {"layers": layer_caches, "pos": lengths}
    if attn is not None:
        cache["ak"], cache["av"] = attn
    return logits, cache


# ---------------------------------------------------------------------------
# stage (layer-range) execution — pipeline-split serving
# ---------------------------------------------------------------------------

def lm_stage_prefill(cfg: ModelConfig, params: dict,
                     batch: Dict[str, jax.Array], rcfg: RunConfig,
                     max_len: int, *, first: bool,
                     last: bool) -> Tuple[jax.Array, dict]:
    """Prefill ONE stage of a layer-split model (paper §4.1 topology).

    ``cfg.n_layers`` is the STAGE's layer count and ``params["blocks"]``
    holds only those layers (see ``repro.models.api.split_stage_params``).
    The first stage embeds ``batch["tokens"]``; later stages continue the
    residual stream from ``batch["hidden"]`` — the boundary activation the
    previous stage shipped.  Non-last stages return the FULL hidden
    sequence (B, T, D) so the next stage can prefill from it; the last
    stage returns last-token logits like :func:`lm_prefill`.

    Only wired for families whose layers are self-contained (dense / moe,
    no shared attention block, no frontend) — ``build_model`` gates
    eligibility via ``stage_eligible``.
    """
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    from repro.models.attention import cache_span

    if first:
        x = embed_tokens(params["embed"], batch["tokens"], cdt)
    else:
        x = batch["hidden"].astype(cdt)
    bsz, t = x.shape[:2]
    span = cache_span(cfg, max_len)
    positions = jnp.broadcast_to(jnp.arange(t), (bsz, t))

    def body(carry, inp):
        bp, idx = inp
        x, cl = B.block_prefill(cfg, bp, carry, idx, positions, span, uk)
        return x, cl

    fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
    x, layer_caches = maybe_scan(fn, x,
                                 (params["blocks"], jnp.arange(cfg.n_layers)),
                                 cfg.n_layers, rcfg.unroll_layers)
    cache = {"layers": layer_caches, "pos": jnp.full((bsz,), t, jnp.int32)}
    if last:
        x = rmsnorm(params["final_ln"], x)
        return x[:, -1] @ head_weight(cfg, params, cdt), cache
    return x, cache


def lm_stage_decode_step(cfg: ModelConfig, params: dict, cache: dict,
                         x_in: jax.Array, rcfg: RunConfig, *, first: bool,
                         last: bool) -> Tuple[jax.Array, dict]:
    """One decode step of ONE stage.  ``x_in`` is tokens (B, 1) int32 on
    the first stage, the previous stage's boundary activations (B, 1, D)
    otherwise.  Returns last-token logits on the last stage, the boundary
    hidden (B, 1, D) to ship onward everywhere else."""
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    x = embed_tokens(params["embed"], x_in, cdt) if first \
        else x_in.astype(cdt)
    pos = cache["pos"]

    def body(carry, inp):
        bp, cl, idx = inp
        x, ncl = B.block_decode(cfg, bp, carry, cl, pos, idx, uk)
        return x, ncl

    x, new_layers = maybe_scan(
        body, x,
        (params["blocks"], cache["layers"], jnp.arange(cfg.n_layers)),
        cfg.n_layers, rcfg.unroll_layers)
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if last:
        x = rmsnorm(params["final_ln"], x)
        return x[:, -1] @ head_weight(cfg, params, cdt), new_cache
    return x, new_cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def lm_decode_step(cfg: ModelConfig, params: dict, cache: dict,
                   tokens: jax.Array, rcfg: RunConfig) -> Tuple[jax.Array, dict]:
    """One decode step. tokens: (B, 1) int32.  Returns (logits (B,Vp), cache)."""
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    x = embed_tokens(params["embed"], tokens, cdt)
    pos = cache["pos"]
    shared = params.get("shared")
    n_attn = B.n_attn_applications(cfg)

    def body(carry, inp):
        bp, cl, idx = inp
        if n_attn:
            x, ak, av = carry
            x, ncl = B.block_decode(cfg, bp, x, cl, pos, idx, uk)
            x, ak, av = B.shared_attn_decode(cfg, shared, x, idx, pos, ak, av, uk)
            return (x, ak, av), ncl
        x = carry
        x, ncl = B.block_decode(cfg, bp, x, cl, pos, idx, uk)
        return x, ncl

    init = (x, cache["ak"], cache["av"]) if n_attn else x
    carry, new_layers = maybe_scan(
        body, init, (params["blocks"], cache["layers"], jnp.arange(cfg.n_layers)),
        cfg.n_layers, rcfg.unroll_layers)
    if n_attn:
        x, ak, av = carry
    else:
        x = carry
    x = rmsnorm(params["final_ln"], x)
    logits = x[:, -1] @ head_weight(cfg, params, cdt)
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if n_attn:
        new_cache["ak"], new_cache["av"] = ak, av
    return logits, new_cache


def lm_decode_window(cfg: ModelConfig, params: dict, cache: dict,
                     tokens: jax.Array,
                     rcfg: RunConfig) -> Tuple[jax.Array, dict]:
    """W sequential decode steps in ONE dispatch (speculative verify).

    tokens: (B, W) int32 — W consecutive next-token inputs per lane.
    Returns (logits (B, W, Vp) — the logits AFTER each token — and the
    cache advanced by W positions).

    This is a ``lax.scan`` of :func:`lm_decode_step`'s program, NOT a
    parallel multi-token attention window: a parallel window changes the
    attention reduction shapes, and XLA's reduction order then differs
    from single-token decode at the ~1e-6 level — enough to break the
    bit-for-bit greedy-identity guarantee speculative verification is
    built on.  The scan re-runs the exact single-step body, so its
    logits and cache are bitwise identical to W separate jitted steps
    while still amortising dispatch overhead into one program.
    """

    def body(c, tok):
        lg, c = lm_decode_step(cfg, params, c, tok, rcfg)
        return c, lg

    cache, lgs = jax.lax.scan(
        body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None])
    return jnp.moveaxis(lgs, 0, 1), cache


def lm_decode_step_pool(cfg: ModelConfig, params: dict, cache: dict,
                        tokens: jax.Array, block_tables: jax.Array,
                        rcfg: RunConfig) -> Tuple[jax.Array, dict]:
    """One decode step against a block-pooled (paged) KV cache.

    cache: {"layers": {"k"/"v": (L, nb, bs, KVH, Dh)}, "pos": (B,)};
    block_tables: (B, max_blocks) int32 physical block ids (0 = sink).
    tokens: (B, 1) int32.  Returns (logits (B, Vp), cache).

    Only wired for pure-attention-cache families (build_model gates
    ssm / rwkv / hybrid / enc-dec off the pooled-KV path).
    """
    cdt = _dt(rcfg.compute_dtype)
    uk = rcfg.use_kernels
    x = embed_tokens(params["embed"], tokens, cdt)
    pos = cache["pos"]

    def body(carry, inp):
        bp, kl, vl = inp
        x = carry
        x, kl, vl = B.block_decode_paged(cfg, bp, x, kl, vl, block_tables,
                                         pos, None, uk)
        return x, {"k": kl, "v": vl}

    x, new_layers = maybe_scan(
        body, x,
        (params["blocks"], cache["layers"]["k"], cache["layers"]["v"]),
        cfg.n_layers, rcfg.unroll_layers)
    x = rmsnorm(params["final_ln"], x)
    logits = x[:, -1] @ head_weight(cfg, params, cdt)
    return logits, {"layers": new_layers, "pos": pos + 1}


def lm_decode_window_pool(cfg: ModelConfig, params: dict, cache: dict,
                          tokens: jax.Array, block_tables: jax.Array,
                          rcfg: RunConfig) -> Tuple[jax.Array, dict]:
    """W sequential pooled decode steps in one dispatch (paged verify).

    Same contract and bitwise rationale as :func:`lm_decode_window`,
    scanning :func:`lm_decode_step_pool`.  tokens: (B, W) int32.
    """

    def body(c, tok):
        lg, c = lm_decode_step_pool(cfg, params, c, tok, block_tables, rcfg)
        return c, lg

    cache, lgs = jax.lax.scan(
        body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None])
    return jnp.moveaxis(lgs, 0, 1), cache


# ---------------------------------------------------------------------------
# cache + input specs
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    from repro.models.attention import cache_span

    span = cache_span(cfg, max_len)
    one = B.init_cache_layer(cfg, batch, span, dtype)
    layers = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    cache = {"layers": layers, "pos": jnp.zeros((batch,), jnp.int32)}
    n_attn = B.n_attn_applications(cfg)
    if n_attn:
        cache["ak"] = jnp.zeros((n_attn, batch, span, cfg.n_kv_heads, cfg.head_dim),
                                dtype)
        cache["av"] = jnp.zeros_like(cache["ak"])
    return cache


def init_pool_cache(cfg: ModelConfig, n_lanes: int, n_blocks: int,
                    block_size: int, dtype) -> dict:
    """Pooled KV cache: ``n_blocks`` usable blocks + 1 sink (block id 0).

    Unlike :func:`init_cache` the pool is sized by LIVE TOKENS
    (``n_blocks * block_size`` positions per layer), not by
    lanes × worst-case length; per-lane block tables (engine-owned) map
    logical positions to pool slots.
    """
    shape = (cfg.n_layers, n_blocks + 1, block_size, cfg.n_kv_heads, cfg.head_dim)
    layers = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return {"layers": layers, "pos": jnp.zeros((n_lanes,), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rcfg: RunConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {"tokens", ["frontend"]}; decode adds {"cache"}.
    """
    cdt = _dt(rcfg.compute_dtype)
    bsz = shape.global_batch
    specs: Dict[str, Any] = {}
    p = cfg.frontend_seq if cfg.frontend else 0
    if shape.kind in ("train", "prefill"):
        t_tok = shape.seq_len - p
        specs["tokens"] = jax.ShapeDtypeStruct((bsz, t_tok), jnp.int32)
        if p:
            specs["frontend"] = jax.ShapeDtypeStruct((bsz, p, cfg.d_model), cdt)
    else:  # decode: one new token against a cache of seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(
            functools.partial(init_cache, cfg, bsz, shape.seq_len, cdt))
    return specs
