"""Model protocol: one object per (arch, run) pair with uniform entry points.

    model = build_model(cfg, rcfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens)
    specs = model.input_specs(shape)

Optional serving hook: ``prefill_ragged(params, batch, lengths, max_len)``
prefills a batch of right-padded prompts in ONE call, returning per-lane
last-real-token logits and a cache with per-lane ``pos``.  It is only set
when padding is provably inert (full causal attention, no recurrent state);
callers must fall back to per-request ``prefill`` when it is ``None``.

Optional paged-KV hooks (block-pooled serving — repro.serving.engine):
``init_paged_cache(n_lanes, n_blocks, block_size)`` builds a block-pool
cache sized by live tokens rather than lanes × max_len, and
``decode_step_paged(params, cache, tokens, block_tables)`` advances it one
token per lane through per-lane block tables.  Only families whose decode
state is a pure attention K/V cache get these hooks; ssm / rwkv / hybrid /
enc-dec (recurrent or cross-attention state is not pageable by position)
stay ``None`` and the engine falls back to dense lanes.

Families: decoder-only (dense/moe/ssm/hybrid/vlm) -> repro.models.lm;
enc-dec (audio/whisper) -> repro.models.encdec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import lm as LM


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rcfg: RunConfig
    init: Callable[[jax.Array], dict]
    loss: Callable[[dict, Dict[str, jax.Array]], Tuple[jax.Array, dict]]
    prefill: Callable[[dict, Dict[str, jax.Array], int], Tuple[jax.Array, dict]]
    decode_step: Callable[[dict, dict, jax.Array], Tuple[jax.Array, dict]]
    init_cache: Callable[[int, int], dict]
    input_specs: Callable[[ShapeConfig], Dict[str, Any]]
    prefill_ragged: Optional[
        Callable[[dict, Dict[str, jax.Array], jax.Array, int],
                 Tuple[jax.Array, dict]]] = None
    init_paged_cache: Optional[Callable[[int, int, int], dict]] = None
    decode_step_paged: Optional[
        Callable[[dict, dict, jax.Array, jax.Array],
                 Tuple[jax.Array, dict]]] = None


def build_model(cfg: ModelConfig, rcfg: RunConfig) -> Model:
    pdt = jnp.dtype(rcfg.param_dtype)
    cdt = jnp.dtype(rcfg.compute_dtype)
    if cfg.family == "audio" and cfg.n_enc_layers:
        return Model(
            cfg=cfg, rcfg=rcfg,
            init=lambda key: ED.init_encdec(cfg, key, pdt),
            loss=lambda p, b: ED.encdec_loss(cfg, p, b, rcfg),
            prefill=lambda p, b, ml: ED.encdec_prefill(cfg, p, b, rcfg, ml),
            decode_step=lambda p, c, t: ED.encdec_decode_step(cfg, p, c, t, rcfg),
            init_cache=lambda bsz, ml: ED.init_encdec_cache(cfg, bsz, ml, cdt),
            input_specs=lambda s: ED.encdec_input_specs(cfg, s, rcfg),
        )
    # right-padded batched prefill is exact only when pad tokens cannot leak
    # into real lanes: full causal attention, no recurrent state, no frontend.
    # MoE is excluded too — pad tokens compete for (and resize) expert
    # capacity, perturbing real tokens' routing vs an exact-length prefill.
    ragged_ok = (cfg.family == "dense" and not cfg.rwkv
                 and cfg.attention == "full" and not cfg.frontend
                 and not cfg.n_enc_layers)
    # paged KV is exact wherever the per-layer decode state is a pure
    # attention K/V cache addressed by position: dense and moe (routing is
    # per-token at decode, so paging cannot perturb it).  Recurrent state
    # (ssm/rwkv/hybrid) and enc-dec cross caches are not position-pageable;
    # chunked_local's ring-buffer addressing is dense-span specific.
    paged_ok = (cfg.family in ("dense", "moe") and not cfg.rwkv
                and cfg.attention == "full" and not cfg.n_enc_layers)
    return Model(
        cfg=cfg, rcfg=rcfg,
        init=lambda key: LM.init_lm(cfg, key, pdt),
        loss=lambda p, b: LM.lm_loss(cfg, p, b, rcfg),
        prefill=lambda p, b, ml: LM.lm_prefill(cfg, p, b, rcfg, ml),
        decode_step=lambda p, c, t: LM.lm_decode_step(cfg, p, c, t, rcfg),
        init_cache=lambda bsz, ml: LM.init_cache(cfg, bsz, ml, cdt),
        input_specs=lambda s: LM.input_specs(cfg, s, rcfg),
        prefill_ragged=(
            (lambda p, b, ln, ml: LM.lm_prefill_ragged(cfg, p, b, ln, rcfg, ml))
            if ragged_ok else None),
        init_paged_cache=(
            (lambda nl, nb, bs: LM.init_paged_cache(cfg, nl, nb, bs, cdt))
            if paged_ok else None),
        decode_step_paged=(
            (lambda p, c, t, bt: LM.lm_decode_step_paged(cfg, p, c, t, bt, rcfg))
            if paged_ok else None),
    )
