"""Model protocol: one object per (arch, run) pair with uniform entry points.

    model = build_model(cfg, rcfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens)
    specs = model.input_specs(shape)

Decode-state capabilities live in ONE structured descriptor,
``model.decode_state`` (a :class:`DecodeState`), consumed exclusively by
the serving cache backends (:mod:`repro.serving.backends`).  The engine
never inspects it — it talks to a ``CacheBackend`` built from it — and
eligibility (which family may use which state layout) is decided HERE,
once, instead of being re-derived per call site.

MIGRATION (old optional hooks -> backend methods)
-------------------------------------------------
Earlier revisions grew one ``Optional[Callable]`` per capability on
``Model``; each is now a ``DecodeState`` field feeding a backend method:

* ``model.prefill_ragged(...)``     -> ``decode_state.batched_prefill``;
  callers go through the engine's bucketed prefill, which pastes into the
  active backend via ``CacheBackend.prefill_paste``.
* ``model.init_paged_cache(...)``   -> ``decode_state.pool_init``; only
  ``PagedBackend`` calls it (``CacheBackend.alloc`` is the public verb).
* ``model.decode_step_paged(...)``  -> ``decode_state.pool_step``; only
  ``PagedBackend`` calls it (``CacheBackend.step`` is the public verb).

Code that previously probed ``model.<hook> is not None`` should either
ask ``model.decode_state`` (capability checks) or, better, build a
backend with :func:`repro.serving.backends.make_backend` and use the
protocol.  ``DecodeState.kind`` routes recurrent-state families
(ssm / rwkv / hybrid) to the pooled constant-footprint
``RecurrentBackend`` instead of exiling them to dense lanes.

Families: decoder-only (dense/moe/ssm/hybrid/vlm) -> repro.models.lm;
enc-dec (audio/whisper) -> repro.models.encdec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import lm as LM


@dataclasses.dataclass(frozen=True)
class DecodeState:
    """How this model's decode state may be laid out and advanced.

    ``kind`` is the state taxonomy the backend factory dispatches on:

    * ``"attention"`` — per-layer state is (or includes only) a
      position-addressed K/V cache; dense lanes always work, and the
      pooled (paged) layout works when ``pool_step`` is wired.
    * ``"recurrent"`` — ssm / rwkv / hybrid: constant-size per-lane state
      (conv tail, ssm state, rwkv matrix state, plus the hybrid shared
      attention span).  Not position-pageable, but cheap to snapshot and
      restore, which the ``RecurrentBackend`` exploits for
      constant-footprint preemption.
    * ``"encdec"`` — cross-attention caches keyed to an encoder pass;
      dense lanes only.

    The callables are INTERNAL plumbing for the serving backends; nothing
    else should invoke them (see the module docstring's migration note).
    ``batched_prefill(params, batch, lengths, max_len)`` is only set when
    right-padding is provably inert; ``pool_init(n_lanes, n_blocks,
    block_size)`` / ``pool_step(params, cache, tokens, block_tables)``
    only where a block pool is exact.
    """

    kind: str
    batched_prefill: Optional[
        Callable[[dict, Dict[str, jax.Array], jax.Array, int],
                 Tuple[jax.Array, dict]]] = None
    pool_init: Optional[Callable[[int, int, int], dict]] = None
    pool_step: Optional[
        Callable[[dict, dict, jax.Array, jax.Array],
                 Tuple[jax.Array, dict]]] = None

    @property
    def poolable(self) -> bool:
        return self.pool_step is not None


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rcfg: RunConfig
    init: Callable[[jax.Array], dict]
    loss: Callable[[dict, Dict[str, jax.Array]], Tuple[jax.Array, dict]]
    prefill: Callable[[dict, Dict[str, jax.Array], int], Tuple[jax.Array, dict]]
    decode_step: Callable[[dict, dict, jax.Array], Tuple[jax.Array, dict]]
    init_cache: Callable[[int, int], dict]
    input_specs: Callable[[ShapeConfig], Dict[str, Any]]
    decode_state: DecodeState = DecodeState(kind="attention")


def build_model(cfg: ModelConfig, rcfg: RunConfig) -> Model:
    pdt = jnp.dtype(rcfg.param_dtype)
    cdt = jnp.dtype(rcfg.compute_dtype)
    if cfg.family == "audio" and cfg.n_enc_layers:
        return Model(
            cfg=cfg, rcfg=rcfg,
            init=lambda key: ED.init_encdec(cfg, key, pdt),
            loss=lambda p, b: ED.encdec_loss(cfg, p, b, rcfg),
            prefill=lambda p, b, ml: ED.encdec_prefill(cfg, p, b, rcfg, ml),
            decode_step=lambda p, c, t: ED.encdec_decode_step(cfg, p, c, t, rcfg),
            init_cache=lambda bsz, ml: ED.init_encdec_cache(cfg, bsz, ml, cdt),
            input_specs=lambda s: ED.encdec_input_specs(cfg, s, rcfg),
            decode_state=DecodeState(kind="encdec"),
        )
    # right-padded batched prefill is exact only when pad tokens cannot leak
    # into real lanes: full causal attention, no recurrent state, no frontend.
    # MoE is excluded too — pad tokens compete for (and resize) expert
    # capacity, perturbing real tokens' routing vs an exact-length prefill.
    ragged_ok = (cfg.family == "dense" and not cfg.rwkv
                 and cfg.attention == "full" and not cfg.frontend
                 and not cfg.n_enc_layers)
    # a block pool is exact wherever the per-layer decode state is a pure
    # attention K/V cache addressed by position: dense and moe (routing is
    # per-token at decode, so paging cannot perturb it).  Recurrent state
    # (ssm/rwkv/hybrid) and enc-dec cross caches are not position-pageable;
    # chunked_local's ring-buffer addressing is dense-span specific.
    pool_ok = (cfg.family in ("dense", "moe") and not cfg.rwkv
               and cfg.attention == "full" and not cfg.n_enc_layers)
    recurrent = cfg.rwkv or cfg.family in ("ssm", "hybrid")
    return Model(
        cfg=cfg, rcfg=rcfg,
        init=lambda key: LM.init_lm(cfg, key, pdt),
        loss=lambda p, b: LM.lm_loss(cfg, p, b, rcfg),
        prefill=lambda p, b, ml: LM.lm_prefill(cfg, p, b, rcfg, ml),
        decode_step=lambda p, c, t: LM.lm_decode_step(cfg, p, c, t, rcfg),
        init_cache=lambda bsz, ml: LM.init_cache(cfg, bsz, ml, cdt),
        input_specs=lambda s: LM.input_specs(cfg, s, rcfg),
        decode_state=DecodeState(
            kind="recurrent" if recurrent else "attention",
            batched_prefill=(
                (lambda p, b, ln, ml: LM.lm_prefill_padded(cfg, p, b, ln, rcfg, ml))
                if ragged_ok else None),
            pool_init=(
                (lambda nl, nb, bs: LM.init_pool_cache(cfg, nl, nb, bs, cdt))
                if pool_ok else None),
            pool_step=(
                (lambda p, c, t, bt: LM.lm_decode_step_pool(cfg, p, c, t, bt, rcfg))
                if pool_ok else None),
        ),
    )
