"""Model protocol: one object per (arch, run) pair with uniform entry points.

    model = build_model(cfg, rcfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens)
    specs = model.input_specs(shape)

Decode-state capabilities live in ONE structured descriptor,
``model.decode_state`` (a :class:`DecodeState`), consumed exclusively by
the serving cache backends (:mod:`repro.serving.backends`).  The engine
never inspects it — it talks to a ``CacheBackend`` built from it — and
eligibility (which family may use which state layout) is decided HERE,
once, instead of being re-derived per call site.

MIGRATION (old optional hooks -> backend methods)
-------------------------------------------------
Earlier revisions grew one ``Optional[Callable]`` per capability on
``Model``; each is now a ``DecodeState`` field feeding a backend method:

* ``model.prefill_ragged(...)``     -> ``decode_state.batched_prefill``;
  callers go through the engine's bucketed prefill, which pastes into the
  active backend via ``CacheBackend.prefill_paste``.
* ``model.init_paged_cache(...)``   -> ``decode_state.pool_init``; only
  ``PagedBackend`` calls it (``CacheBackend.alloc`` is the public verb).
* ``model.decode_step_paged(...)``  -> ``decode_state.pool_step``; only
  ``PagedBackend`` calls it (``CacheBackend.step`` is the public verb).

Code that previously probed ``model.<hook> is not None`` should either
ask ``model.decode_state`` (capability checks) or, better, build a
backend with :func:`repro.serving.backends.make_backend` and use the
protocol.  ``DecodeState.kind`` routes recurrent-state families
(ssm / rwkv / hybrid) to the pooled constant-footprint
``RecurrentBackend`` instead of exiling them to dense lanes.

Families: decoder-only (dense/moe/ssm/hybrid/vlm) -> repro.models.lm;
enc-dec (audio/whisper) -> repro.models.encdec.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import lm as LM


@dataclasses.dataclass(frozen=True)
class DecodeState:
    """How this model's decode state may be laid out and advanced.

    ``kind`` is the state taxonomy the backend factory dispatches on:

    * ``"attention"`` — per-layer state is (or includes only) a
      position-addressed K/V cache; dense lanes always work, and the
      pooled (paged) layout works when ``pool_step`` is wired.
    * ``"recurrent"`` — ssm / rwkv / hybrid: constant-size per-lane state
      (conv tail, ssm state, rwkv matrix state, plus the hybrid shared
      attention span).  Not position-pageable, but cheap to snapshot and
      restore, which the ``RecurrentBackend`` exploits for
      constant-footprint preemption.
    * ``"encdec"`` — cross-attention caches keyed to an encoder pass;
      dense lanes only.

    The callables are INTERNAL plumbing for the serving backends; nothing
    else should invoke them (see the module docstring's migration note).
    ``batched_prefill(params, batch, lengths, max_len)`` is only set when
    right-padding is provably inert; ``pool_init(n_lanes, n_blocks,
    block_size)`` / ``pool_step(params, cache, tokens, block_tables)``
    only where a block pool is exact.

    ``window_step(params, cache, tokens (B, W))`` (and its pooled twin
    ``pool_window_step``) runs W sequential decode steps in one dispatch,
    returning per-position logits (B, W, Vp) — the speculative-decoding
    verify entry point.  It is always a scan of the single-step body, so
    its outputs are bitwise identical to W separate ``decode_step``
    calls (see :func:`repro.models.lm.lm_decode_window`).
    """

    kind: str
    batched_prefill: Optional[
        Callable[[dict, Dict[str, jax.Array], jax.Array, int],
                 Tuple[jax.Array, dict]]] = None
    pool_init: Optional[Callable[[int, int, int], dict]] = None
    pool_step: Optional[
        Callable[[dict, dict, jax.Array, jax.Array],
                 Tuple[jax.Array, dict]]] = None
    window_step: Optional[
        Callable[[dict, dict, jax.Array], Tuple[jax.Array, dict]]] = None
    pool_window_step: Optional[
        Callable[[dict, dict, jax.Array, jax.Array],
                 Tuple[jax.Array, dict]]] = None

    @property
    def poolable(self) -> bool:
        return self.pool_step is not None


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rcfg: RunConfig
    init: Callable[[jax.Array], dict]
    loss: Callable[[dict, Dict[str, jax.Array]], Tuple[jax.Array, dict]]
    prefill: Callable[[dict, Dict[str, jax.Array], int], Tuple[jax.Array, dict]]
    decode_step: Callable[[dict, dict, jax.Array], Tuple[jax.Array, dict]]
    init_cache: Callable[[int, int], dict]
    input_specs: Callable[[ShapeConfig], Dict[str, Any]]
    decode_state: DecodeState = DecodeState(kind="attention")


def _window_from_step(step: Callable) -> Callable:
    """Lift a single-token ``step(params, cache, (B,1))`` into a W-token
    window via ``lax.scan`` — bitwise identical to W separate steps (the
    scan body IS the step program; see :func:`repro.models.lm.lm_decode_window`)."""

    def window(params, cache, tokens):
        def body(c, tok):
            lg, c = step(params, c, tok)
            return c, lg

        cache, lgs = jax.lax.scan(
            body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None])
        return jnp.moveaxis(lgs, 0, 1), cache

    return window


def build_model(cfg: ModelConfig, rcfg: RunConfig) -> Model:
    pdt = jnp.dtype(rcfg.param_dtype)
    cdt = jnp.dtype(rcfg.compute_dtype)
    if cfg.family == "audio" and cfg.n_enc_layers:
        ed_step = lambda p, c, t: ED.encdec_decode_step(cfg, p, c, t, rcfg)
        return Model(
            cfg=cfg, rcfg=rcfg,
            init=lambda key: ED.init_encdec(cfg, key, pdt),
            loss=lambda p, b: ED.encdec_loss(cfg, p, b, rcfg),
            prefill=lambda p, b, ml: ED.encdec_prefill(cfg, p, b, rcfg, ml),
            decode_step=ed_step,
            init_cache=lambda bsz, ml: ED.init_encdec_cache(cfg, bsz, ml, cdt),
            input_specs=lambda s: ED.encdec_input_specs(cfg, s, rcfg),
            decode_state=DecodeState(kind="encdec",
                                     window_step=_window_from_step(ed_step)),
        )
    # right-padded batched prefill is exact only when pad tokens cannot leak
    # into real lanes: full causal attention, no recurrent state, no frontend.
    # MoE is excluded too — pad tokens compete for (and resize) expert
    # capacity, perturbing real tokens' routing vs an exact-length prefill.
    ragged_ok = (cfg.family == "dense" and not cfg.rwkv
                 and cfg.attention == "full" and not cfg.frontend
                 and not cfg.n_enc_layers)
    # a block pool is exact wherever the per-layer decode state is a pure
    # attention K/V cache addressed by position: dense and moe (routing is
    # per-token at decode, so paging cannot perturb it).  Recurrent state
    # (ssm/rwkv/hybrid) and enc-dec cross caches are not position-pageable;
    # chunked_local's ring-buffer addressing is dense-span specific.
    pool_ok = (cfg.family in ("dense", "moe") and not cfg.rwkv
               and cfg.attention == "full" and not cfg.n_enc_layers)
    recurrent = cfg.rwkv or cfg.family in ("ssm", "hybrid")
    return Model(
        cfg=cfg, rcfg=rcfg,
        init=lambda key: LM.init_lm(cfg, key, pdt),
        loss=lambda p, b: LM.lm_loss(cfg, p, b, rcfg),
        prefill=lambda p, b, ml: LM.lm_prefill(cfg, p, b, rcfg, ml),
        decode_step=lambda p, c, t: LM.lm_decode_step(cfg, p, c, t, rcfg),
        init_cache=lambda bsz, ml: LM.init_cache(cfg, bsz, ml, cdt),
        input_specs=lambda s: LM.input_specs(cfg, s, rcfg),
        decode_state=DecodeState(
            kind="recurrent" if recurrent else "attention",
            batched_prefill=(
                (lambda p, b, ln, ml: LM.lm_prefill_padded(cfg, p, b, ln, rcfg, ml))
                if ragged_ok else None),
            pool_init=(
                (lambda nl, nb, bs: LM.init_pool_cache(cfg, nl, nb, bs, cdt))
                if pool_ok else None),
            pool_step=(
                (lambda p, c, t, bt: LM.lm_decode_step_pool(cfg, p, c, t, bt, rcfg))
                if pool_ok else None),
            window_step=lambda p, c, t: LM.lm_decode_window(cfg, p, c, t, rcfg),
            pool_window_step=(
                (lambda p, c, t, bt: LM.lm_decode_window_pool(
                    cfg, p, c, t, bt, rcfg))
                if pool_ok else None),
        ),
    )


# ---------------------------------------------------------------------------
# layer-range stage models (pipeline-split serving, paper §4.1 topology)
# ---------------------------------------------------------------------------

def stage_eligible(cfg: ModelConfig) -> bool:
    """Can this family's layers be cut into self-contained stages?

    A stage is exact iff nothing couples layers across the cut: dense and
    moe qualify (per-layer attention KV + per-token routing); excluded are
    rwkv/ssm/hybrid (the zamba2 SHARED attention block fires across the
    whole depth; recurrent state would work layer-wise but the serving
    backends treat it whole), enc-dec (cross-attention keyed to one
    encoder pass) and frontend configs (the embedding concat is a
    first-stage-only input the stage protocol doesn't carry)."""
    return (cfg.family in ("dense", "moe") and not cfg.rwkv
            and not cfg.n_enc_layers and not cfg.frontend)


def _stage_stub(what: str):
    def stub(*_a, **_k):
        raise RuntimeError(
            f"stage models hold one layer slice of a split model; {what} "
            f"belongs to the full model (build_model)")
    return stub


@functools.lru_cache(maxsize=128)
def stage_model(model: Model, lo: int, hi: int) -> Model:
    """A Model executing only layers [lo, hi) of ``model``.

    Its ``prefill`` takes ``{"tokens"}`` on the first stage and
    ``{"hidden"}`` (the previous stage's boundary activations) otherwise;
    its ``decode_step`` input is tokens (B, 1) or hidden (B, 1, D) the
    same way.  Non-last stages OUTPUT the boundary hidden instead of
    logits.  Params are the slice produced by :func:`split_stage_params`.

    ``init_cache`` covers exactly the slice's layers, so a serving
    :class:`~repro.serving.backends.CacheBackend` instantiates per stage
    over the layer range — stage 0 owns the low-layer KV, stage 1 the
    rest.  Cached (lru) so every engine serving the same cut shares one
    Model object and therefore one set of jitted programs.
    """
    cfg, rcfg = model.cfg, model.rcfg
    if not stage_eligible(cfg):
        raise ValueError(
            f"family {cfg.family!r} (rwkv={cfg.rwkv}) cannot be layer-split "
            f"into serving stages")
    if not (0 <= lo < hi <= cfg.n_layers):
        raise ValueError(f"bad stage range [{lo}, {hi}) for "
                         f"{cfg.n_layers} layers")
    first, last = lo == 0, hi == cfg.n_layers
    scfg = dataclasses.replace(cfg, n_layers=hi - lo)
    cdt = jnp.dtype(rcfg.compute_dtype)
    return Model(
        cfg=scfg, rcfg=rcfg,
        init=_stage_stub("init"),
        loss=_stage_stub("loss"),
        prefill=lambda p, b, ml: LM.lm_stage_prefill(
            scfg, p, b, rcfg, ml, first=first, last=last),
        decode_step=lambda p, c, t: LM.lm_stage_decode_step(
            scfg, p, c, t, rcfg, first=first, last=last),
        init_cache=lambda bsz, ml: LM.init_cache(scfg, bsz, ml, cdt),
        input_specs=_stage_stub("input_specs"),
        decode_state=DecodeState(kind="attention"),
    )


def split_stage_params(model: Model, params: dict,
                       cuts: Sequence[int]) -> List[dict]:
    """Slice a full param tree into per-stage trees for ``cuts``.

    Stage i holds ``blocks[bounds[i]:bounds[i+1]]``; the first stage adds
    the embedding table, the last adds the final norm and the head — for
    tied embeddings the last stage carries its own copy of the embedding
    (a real deployment ships the table to both ends of the wire, which is
    exactly the honest memory accounting).  The slices are materialised
    (not views), so callers may drop the full ``params`` afterwards —
    that is the memory-wall point of the split."""
    n = model.cfg.n_layers
    bounds = (0,) + tuple(cuts) + (n,)
    if list(bounds) != sorted(set(bounds)):
        raise ValueError(f"cuts {cuts!r} not strictly increasing in (0, {n})")
    out: List[dict] = []
    for i in range(len(bounds) - 1):
        lo, hi = bounds[i], bounds[i + 1]
        p = {"blocks": jax.tree.map(lambda a: a[lo:hi], params["blocks"])}
        if i == 0:
            p["embed"] = params["embed"]
        if hi == n:
            p["final_ln"] = params["final_ln"]
            if model.cfg.tie_embeddings:
                p.setdefault("embed", params["embed"])
            else:
                p["head"] = params["head"]
        out.append(p)
    return out


def param_bytes(tree: Any) -> int:
    """Total bytes of a param (sub)tree — stage memory accounting."""
    return int(sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(tree)))
