"""Mixture-of-Experts: GShard/Switch-style grouped top-k dispatch.

TPU-native dense dispatch: tokens are split into groups; within each group a
capacity-bounded one-hot dispatch tensor routes tokens to experts via einsum,
expert FFNs run batched over the expert dim (shardable over the "model" mesh
axis = expert parallelism), and a combine einsum returns outputs.  Tokens
beyond capacity are dropped (standard); a load-balancing auxiliary loss keeps
routing spread.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import _act, init_mlp, apply_mlp, truncated_normal


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": truncated_normal(ks[0], (d, e), s_in, jnp.float32),
        "wi": truncated_normal(ks[1], (e, d, f), s_in, dtype),
        "wo": truncated_normal(ks[2], (e, f, d), s_out, dtype),
    }
    if cfg.glu:
        p["wg"] = truncated_normal(ks[3], (e, d, f), s_in, dtype)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f, cfg.glu, dtype)
    return p


def _top_k_gating(probs: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """probs (G,N,E) -> (gates (G,N,E) zero except chosen, mask (G,N,E) bool)."""
    gates = jnp.zeros_like(probs)
    mask = jnp.zeros(probs.shape, bool)
    p = probs
    for _ in range(top_k):
        idx = jnp.argmax(p, axis=-1)
        onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        gates = gates + onehot * probs
        mask = mask | onehot.astype(bool)
        p = p * (1.0 - onehot)
    return gates, mask


def apply_moe(params: dict, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = 1.25,
              group_size: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out (B,T,D), aux_loss scalar)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tok = b * t
    g = max(1, n_tok // group_size)
    while n_tok % g:
        g -= 1
    n = n_tok // g
    xg = x.reshape(g, n, d)

    logits = (xg.astype(jnp.float32) @ params["router"])          # (G,N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, mask = _top_k_gating(probs, k)

    # load-balance loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(mask.astype(jnp.float32), axis=1)             # (G,E)
    mean_p = jnp.mean(probs, axis=1)                              # (G,E)
    aux = e * jnp.mean(jnp.sum(frac * mean_p, axis=-1))

    cap = int(max(k, capacity_factor * n * k / e))
    cap = min(cap, n)
    # position of each token within its expert queue
    pos_in_e = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1     # (G,N,E)
    keep = mask & (pos_in_e < cap)
    disp = jax.nn.one_hot(jnp.where(keep, pos_in_e, cap), cap + 1,
                          dtype=xg.dtype)[..., :cap]              # (G,N,E,C)
    disp = disp * keep[..., None].astype(xg.dtype)

    xe = jnp.einsum("gnec,gnd->gecd", disp, xg)                   # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(xg.dtype))
    if "wg" in params:
        gate_h = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(xg.dtype))
        h = _act(cfg.act)(gate_h) * h
    else:
        h = _act(cfg.act)(h)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(xg.dtype))
    combine = disp * gates.astype(xg.dtype)[..., None]            # (G,N,E,C)
    y = jnp.einsum("gnec,gecd->gnd", combine, ye).reshape(b, t, d)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], x, cfg.act)
    return y, aux
