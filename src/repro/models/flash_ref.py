"""Blockwise online-softmax attention in pure jnp (flash-attention oracle).

Used (a) as the memory-safe attention path for long sequences (the naive
(T,T) score matrix at 32k seq would be hundreds of GB), and (b) as the
numerical oracle for the Pallas flash kernel.  Double-blocked: scan over Q
blocks (remat'd) × scan over KV blocks with running (m, l, acc) — identical
math to the TPU kernel.  Supports causal, chunked-local (llama4) masks and
GQA without materialising repeated K/V heads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_attn(q, k, v, qpos0, kpos0, causal: bool, chunk: int, scale: float,
                t_k: int):
    """One (Q-block, KV-block) tile. q (B,G,H,bq,D), k/v (B,G,bk,D).
    G = kv heads, H = q heads per kv head."""
    s = jnp.einsum("bghqd,bgkd->bghqk", q, k).astype(jnp.float32) * scale
    bq, bk = s.shape[-2], s.shape[-1]
    qpos = qpos0 + jnp.arange(bq)
    kpos = kpos0 + jnp.arange(bk)
    mask = (kpos < t_k)[None, :]            # padded keys are never attended
    mask = jnp.broadcast_to(mask, (bq, bk))
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if chunk:
        mask = mask & ((qpos[:, None] // chunk) == (kpos[None, :] // chunk))
    return jnp.where(mask, s, NEG_INF)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale: Optional[float] = None,
                        chunk: int = 0, block_q: int = 512,
                        block_k: int = 512) -> jax.Array:
    """q (B,T,H,D), k/v (B,Tk,G,D) with H % G == 0. Returns (B,T,H,D)."""
    b, t, h, d = q.shape
    tk, g = k.shape[1], k.shape[2]
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, t)
    block_k = min(block_k, tk)
    # pad to block multiples
    pq = (-t) % block_q
    pk = (-tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    # layout: (B, G, H/G, nq, bq, D)
    qb = qp.reshape(b, nq, block_q, g, h // g, d).transpose(0, 3, 4, 1, 2, 5)
    kb = kp.reshape(b, nk, block_k, g, d).transpose(0, 3, 1, 2, 4)
    vb = kb_v = vp.reshape(b, nk, block_k, g, d).transpose(0, 3, 1, 2, 4)

    def q_block(iq, qtile):
        # qtile: (B,G,H',bq,D)
        m0 = jnp.full(qtile.shape[:-1], -jnp.inf, jnp.float32)
        l0 = jnp.zeros(qtile.shape[:-1], jnp.float32)
        a0 = jnp.zeros(qtile.shape, jnp.float32)

        def kv_block(carry, ik):
            m, l, acc = carry
            ktile = jax.lax.dynamic_index_in_dim(kb, ik, 2, keepdims=False)
            vtile = jax.lax.dynamic_index_in_dim(vb, ik, 2, keepdims=False)
            s = _block_attn(qtile, ktile, vtile, iq * block_q, ik * block_k,
                            causal, chunk, scale, tk)
            mnew = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (padding): keep m finite for exp
            msafe = jnp.where(jnp.isinf(mnew), 0.0, mnew)
            p = jnp.exp(s - msafe[..., None])
            p = jnp.where(jnp.isinf(mnew)[..., None], 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - msafe))
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bghqk,bgkd->bghqd", p.astype(vtile.dtype), vtile).astype(jnp.float32)
            return (mnew, l, acc), None

        if causal and not chunk:
            nkv = jnp.minimum(nk, (iq + 1) * block_q // block_k + 1)
        else:
            nkv = nk
        iks = jnp.arange(nk)
        def guarded(carry, ik):
            do = ik < nkv if causal and not chunk else jnp.bool_(True)
            new, _ = kv_block(carry, ik)
            keep = lambda a, b: jnp.where(do, a, b)
            return jax.tree.map(keep, new, carry), None
        (m, l, acc), _ = jax.lax.scan(guarded, (m0, l0, a0), iks)
        lsafe = jnp.where(l == 0, 1.0, l)
        return (acc / lsafe[..., None]).astype(q.dtype)

    body = jax.checkpoint(q_block, prevent_cse=False, static_argnums=())

    def scan_body(_, iq):
        qtile = jax.lax.dynamic_index_in_dim(qb, iq, 3, keepdims=False)
        return None, body(iq, qtile)

    _, outs = jax.lax.scan(scan_body, None, jnp.arange(nq))
    # outs: (nq, B, G, H', bq, D) -> (B, T, H, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, d)
    return out[:, :t]
