"""Mamba2 (SSD) block — zamba2's backbone.

State-space recurrence per head h: for step t
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * (B_t ⊗ x_t)        S: (dh, n)
    y_t = S_t @ C_t + D * x_t
with data-dependent dt (softplus), scalar A per head, depthwise causal conv
on (x, B, C), and a gated RMSNorm output (SiLU(z) gate).

Reference path: `lax.scan` over time (exact).  Training perf path: the
chunked SSD Pallas kernel (`repro.kernels.mamba2_ssd`).  Decode carries
(conv_state, ssm_state) explicitly — O(1) per token, which is why the
``long_500k`` cell is trivial for this family.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rmsnorm, truncated_normal

N_GROUPS = 1  # B/C shared across heads (mamba2 default n_groups=1)


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    conv_dim = d_in + 2 * N_GROUPS * cfg.ssm_state
    return d_in, nheads, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nheads, conv_dim = dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_in + 2 * N_GROUPS * cfg.ssm_state + nheads  # z, x, B, C, dt
    return {
        "in_proj": truncated_normal(ks[0], (d, in_dim), d ** -0.5, dtype),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.3, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01))).astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": truncated_normal(ks[2], (d_in, d), d_in ** -0.5, dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, nheads, _ = dims(cfg)
    n = N_GROUPS * cfg.ssm_state
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * n], axis=-1)
    return z, xbc, dt            # (…,d_in), (…,d_in+2n), (…,nheads)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along T. xbc (B,T,C), w (K,C).  Returns (y, new_state)
    where state is the last K-1 inputs (for decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # (B, T+K-1, C)
    y = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(k))
    y = jax.nn.silu(y + b.astype(y.dtype))
    new_state = xp[:, -(k - 1):] if k > 1 else xp[:, :0]
    return y, new_state


def mamba2_scan_ref(x_h, dt, A, B, C, D, ssm_state=None):
    """Exact recurrence.  x_h (B,T,H,P); dt (B,T,H); A (H,); B/C (B,T,N);
    D (H,).  Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    bsz, t, h, p = x_h.shape
    n = B.shape[-1]
    if ssm_state is None:
        ssm_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp                         # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A)[..., None, None]     # (B,H,1,1)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]  # (B,H,P,N)
        S = decay * S + upd
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    xs = (x_h.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
          B.swapaxes(0, 1).astype(jnp.float32), C.swapaxes(0, 1).astype(jnp.float32))
    S, ys = jax.lax.scan(step, ssm_state, xs)
    y = ys.swapaxes(0, 1) + D[None, None, :, None] * x_h.astype(jnp.float32)
    return y.astype(x_h.dtype), S


def apply_mamba2(params: dict, cfg: ModelConfig, x: jax.Array, *,
                 use_kernels: bool = False,
                 state: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence (train/prefill) when state is None; single/multi-token
    stateful otherwise.  x: (B,T,D)."""
    d_in, nheads, conv_dim = dims(cfg)
    n = N_GROUPS * cfg.ssm_state
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xh, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    bsz, t = x.shape[:2]
    xh = xh.reshape(bsz, t, nheads, cfg.ssm_headdim)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    ssm_state = None if state is None else state["ssm"]
    if use_kernels and state is None:
        from repro.kernels import ops as kops
        y, S = kops.mamba2_ssd(xh, dt, A, B, C, params["D"])
    else:
        y, S = mamba2_scan_ref(xh, dt, A, B, C, params["D"], ssm_state)
    y = y.reshape(bsz, t, d_in)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z.astype(y.dtype)))
    out = y @ params["out_proj"]
    new_state = None if state is None else {"conv": new_conv, "ssm": S}
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": S}
    return out, new_state


def init_mamba2_state(cfg: ModelConfig, batch: int, n_layers: int, dtype) -> dict:
    d_in, nheads, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_layers, batch, nheads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
    }
