"""RWKV-6 (Finch) block — attention-free, data-dependent decay.

Time-mixing per head (K = V = head_dim):
    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ
with w_t = exp(-exp(w0 + LoRA(x̃_t))) the data-dependent decay (the Finch
novelty), and token-shift interpolation x̃ = lerp(x_t, x_{t-1}, μ).

Simplifications vs the full release (documented in DESIGN §8): static μ
token-shift per projection (r,k,v,w,g) instead of the dynamic ddlerp; decay
LoRA rank 64.  Channel-mixing is the standard squared-relu RWKV FFN.

Reference: `lax.scan` over time.  Perf path: chunked Pallas kernel
(`repro.kernels.rwkv6_scan`).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import truncated_normal

LORA_RANK = 64


def init_rwkv6(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), dtype),            # r,k,v,w,g shift mixes
        "wr": truncated_normal(ks[0], (d, d), s, dtype),
        "wk": truncated_normal(ks[1], (d, d), s, dtype),
        "wv": truncated_normal(ks[2], (d, d), s, dtype),
        "wg": truncated_normal(ks[3], (d, d), s, dtype),
        "wo": truncated_normal(ks[4], (d, d), s, dtype),
        "w0": jnp.full((d,), -4.0, jnp.float32),         # decay base
        "w_lora_a": truncated_normal(ks[5], (d, LORA_RANK), s, dtype),
        "w_lora_b": truncated_normal(ks[6], (LORA_RANK, d), LORA_RANK ** -0.5, dtype),
        "u": truncated_normal(ks[7], (h, dh), 0.3, jnp.float32),  # bonus
        "ln_x_scale": jnp.ones((d,), dtype),             # group-norm-ish post scale
        # channel-mix
        "mu_c": 0.5 * jnp.ones((2, d), dtype),
        "ck": truncated_normal(ks[8], (d, cfg.d_ff), s, dtype),
        "cv": truncated_normal(ks[9], (cfg.d_ff, d), cfg.d_ff ** -0.5, dtype),
        "cr": truncated_normal(ks[10], (d, d), s, dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]):
    """Returns x_{t-1} stream. x (B,T,D); last (B,D) from previous chunk."""
    if last is None:
        last = jnp.zeros_like(x[:, 0])
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def rwkv6_scan_ref(r, k, v, w, u, state=None):
    """Exact WKV recurrence. r/k/v (B,T,H,K); w (B,T,H,K) decay in (0,1);
    u (H,K).  Returns (out (B,T,H,K), final state (B,H,K,K))."""
    bsz, t, h, dk = r.shape
    if state is None:
        state = jnp.zeros((bsz, h, dk, dk), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                           # (B,H,K) each
        kv = kt[..., :, None] * vt[..., None, :]       # (B,H,K,K)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, state, xs)
    return outs.swapaxes(0, 1).astype(r.dtype), S


def apply_rwkv6_tmix(params: dict, cfg: ModelConfig, x: jax.Array, *,
                     use_kernels: bool = False,
                     state: Optional[dict] = None):
    """x (B,T,D) -> (out, new_state({'S','last'}) if state given)."""
    b, t, d = x.shape
    h = cfg.n_heads
    dk = d // h
    last = None if state is None else state["last"]
    prev, new_last = _token_shift(x, last)
    mu = params["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (prev - x) for i in range(5))
    r = (xr @ params["wr"]).reshape(b, t, h, dk)
    k = (xk @ params["wk"]).reshape(b, t, h, dk)
    v = (xv @ params["wv"]).reshape(b, t, h, dk)
    g = jax.nn.silu(xg @ params["wg"])
    dec = params["w0"] + (xw @ params["w_lora_a"] @ params["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, dk)    # data-dependent decay
    S0 = None if state is None else state["S"]
    if use_kernels and state is None:
        from repro.kernels import ops as kops
        out, S = kops.rwkv6_scan(r, k, v, w, params["u"])
    else:
        out, S = rwkv6_scan_ref(r, k, v, w, params["u"], S0)
    out = out.reshape(b, t, d)
    # normalise per head group (stand-in for RWKV's GroupNorm)
    out = out * jax.lax.rsqrt(jnp.mean(out.astype(jnp.float32) ** 2, -1,
                                       keepdims=True) + 1e-5).astype(out.dtype)
    out = out * params["ln_x_scale"].astype(out.dtype) * g
    out = out @ params["wo"]
    new_state = None if state is None else {"S": S, "last": new_last}
    return out, new_state


def apply_rwkv6_cmix(params: dict, cfg: ModelConfig, x: jax.Array,
                     state: Optional[dict] = None):
    last = None if state is None else state["last_c"]
    prev, new_last = _token_shift(x, last)
    mu = params["mu_c"].astype(x.dtype)
    xk = x + mu[0] * (prev - x)
    xr = x + mu[1] * (prev - x)
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    out = jax.nn.sigmoid(xr @ params["cr"]) * (kk @ params["cv"])
    return out, new_last


def init_rwkv6_state(cfg: ModelConfig, batch: int, n_layers: int, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dk = d // h
    return {
        "S": jnp.zeros((n_layers, batch, h, dk, dk), jnp.float32),
        "last": jnp.zeros((n_layers, batch, d), dtype),
        "last_c": jnp.zeros((n_layers, batch, d), dtype),
    }
