"""Shared layers: norms, RoPE, MLP, embeddings, chunked cross-entropy.

Pure-functional: ``init_*`` build param dicts, ``apply_*`` consume them.
Norm statistics and softmax/logsumexp run in fp32 regardless of compute dtype.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, Dh); positions: (..., T) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., T, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return out.astype(np.float32)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(key, d_model: int, d_ff: int, glu: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "wi": truncated_normal(ks[0], (d_model, d_ff), scale_in, dtype),
        "wo": truncated_normal(ks[1], (d_ff, d_model), scale_out, dtype),
    }
    if glu:
        p["wg"] = truncated_normal(ks[2], (d_model, d_ff), scale_in, dtype)
    return p


def apply_mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = x @ params["wi"]
    if "wg" in params:
        h = _act(act)(x @ params["wg"]) * h
    else:
        h = _act(act)(h)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def init_embed(key, vocab: int, d_model: int, dtype) -> dict:
    return {"tok": truncated_normal(key, (pad_vocab(vocab), d_model), 1.0, dtype)}


def embed_tokens(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["tok"].astype(compute_dtype)[tokens]


def init_head(key, d_model: int, vocab: int, dtype) -> dict:
    return {"w": truncated_normal(key, (d_model, pad_vocab(vocab)), d_model ** -0.5, dtype)}


def lm_logits(head: Optional[dict], embed: dict, x: jax.Array) -> jax.Array:
    """Head projection; tied (use embed.T) when ``head`` is None."""
    w = embed["tok"].T if head is None else head["w"]
    return x @ w.astype(x.dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean CE over all positions. logits (..., Vp) fp-any; labels (...) int."""
    logits = logits.astype(jnp.float32)
    # mask padded vocab columns
    vp = logits.shape[-1]
    if vp != vocab:
        mask = (jnp.arange(vp) < vocab)
        logits = jnp.where(mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_xent(x: jax.Array, w: jax.Array, labels: jax.Array, vocab: int,
                 chunk: int = 1024, unroll: bool = False) -> jax.Array:
    """CE of ``x @ w`` against labels without materialising (B,T,V) logits.

    x: (B, T, D); w: (D, Vp); labels: (B, T).  Scans over T in chunks so peak
    memory is (B, chunk, Vp) — required for the 131k-262k vocab archs.
    """
    b, t, d = x.shape
    n_chunks = max(1, -(-t // chunk))
    tp = n_chunks * chunk if n_chunks > 1 else t
    if tp != t:                                   # pad + mask the tail
        x = jnp.pad(x, ((0, 0), (0, tp - t), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, tp - t)))
    weight = (jnp.arange(tp) < t).astype(jnp.float32)  # (tp,)
    wc = weight.reshape(n_chunks, tp // n_chunks)
    xs = x.reshape(b, n_chunks, tp // n_chunks, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, tp // n_chunks).swapaxes(0, 1)

    def body(acc, xl):
        xc, lc, wgt = xl
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
        vp = logits.shape[-1]
        if vp != vocab:
            logits = jnp.where(jnp.arange(vp) < vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * wgt[None, :]), None

    if unroll:
        total = jnp.float32(0.0)
        for i in range(n_chunks):
            total, _ = body(total, (xs[i], ls[i], wc[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ls, wc))
    return total / (b * t)
