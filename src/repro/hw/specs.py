"""Hardware device profiles.

The paper's Table 1 compares mobile/edge/desktop devices by TFLOPS; its cost
model for choosing a pipeline split point is implicit (hand-tuned).  Here the
profiles are explicit inputs to the heterogeneous partitioner
(:mod:`repro.core.partition`) and to the roofline analysis
(:mod:`repro.analysis.roofline`).

All numbers are peak ratings.  ``flops`` is the dense-matmul peak for the
relevant dtype (fp32 for the paper's devices, bf16 for TPU), ``mem_bw`` is
HBM/DRAM bandwidth, ``link_bw`` is the inter-device link bandwidth *per
direction* for the transport that device uses (USB for phones in the paper,
ICI for TPU).
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    year: int
    flops: float           # peak FLOP/s (dtype noted in ``dtype``)
    mem_bytes: float       # usable memory per device, bytes
    mem_bw: float          # bytes/s
    link_bw: float         # bytes/s per direction on the inter-device link
    dtype: str = "fp32"
    # Thermal model (paper §4.2): sustained fraction of peak after throttling
    # and the time constant (seconds of saturated compute) to reach it.
    thermal_sustained: float = 1.0
    thermal_tau_s: float = float("inf")
    # Serving-rate model: sustained rates at thermal MINIMAL (cold), used by
    # :mod:`repro.serving.fleet` to pace each worker's engine in simulated
    # time.  ``decode_steps_per_s`` is batched decode steps (one token for
    # every active lane) per second; ``prefill_tokens_per_s`` is prompt
    # tokens prefillable per second.  0.0 = derive a flops-proportional
    # estimate (see :meth:`decode_rate` / :meth:`prefill_rate`).
    decode_steps_per_s: float = 0.0
    prefill_tokens_per_s: float = 0.0

    def decode_rate(self) -> float:
        """Batched decode steps/s (explicit rating, or a flops-scaled
        estimate calibrated so the paper's phones land near their ratings)."""
        return self.decode_steps_per_s or self.flops / 1.6e10

    def prefill_rate(self) -> float:
        """Prefill tokens/s (explicit rating or flops-scaled estimate)."""
        return self.prefill_tokens_per_s or self.flops / 7.5e7

    def derate(self, slowdown: float) -> "DeviceProfile":
        """This profile at an observed thermal ``slowdown`` (>= 1): compute
        and serving rates divided by it, memory/link untouched.  Feeding
        derated profiles back into the partition searches is how online
        rebalance (§5.2) re-cuts a split as a stage throttles."""
        s = max(slowdown, 1e-9)
        return dataclasses.replace(
            self, flops=self.flops / s,
            decode_steps_per_s=self.decode_rate() / s,
            prefill_tokens_per_s=self.prefill_rate() / s)


# --- TPU target (the production fleet) -------------------------------------
TPU_V5E = DeviceProfile(
    name="tpu-v5e",
    year=2023,
    flops=197e12,            # bf16 MXU peak per chip (spec'd for this repo)
    mem_bytes=16e9,          # 16 GB HBM
    mem_bw=819e9,            # 819 GB/s
    link_bw=50e9,            # ~50 GB/s per ICI link
    dtype="bf16",
    thermal_sustained=0.95,
    thermal_tau_s=600.0,
    decode_steps_per_s=2000.0,
    prefill_tokens_per_s=2e6,
)

# Effective wire efficiency applied to link_bw when converting collective
# payload bytes into seconds (protocol + scheduling overhead).
ICI_EFFICIENCY = 0.9

# --- Paper Table 1 devices (used by bench_devices + bench_pipeline) --------
XEON_E3_1225V3 = DeviceProfile(
    name="xeon-e3-1225v3", year=2013, flops=0.061e12, mem_bytes=32e9,
    mem_bw=25.6e9, link_bw=60e6,   # paired with Lightning-era USB2 in the paper
    decode_steps_per_s=6.0, prefill_tokens_per_s=1500.0,
)
IPHONE_11_PRO = DeviceProfile(
    name="iphone-11-pro", year=2019, flops=0.63e12, mem_bytes=2.0e9,
    mem_bw=34e9, link_bw=60e6,     # Lightning: USB 2.0, ~60 MB/s (paper §4.1.2)
    thermal_sustained=0.80, thermal_tau_s=180.0,  # paper Fig. 6: Serious ~batch 17
    decode_steps_per_s=30.0, prefill_tokens_per_s=8000.0,
)
IPHONE_16 = DeviceProfile(
    name="iphone-16", year=2024, flops=1.907e12, mem_bytes=8e9,
    mem_bw=60e9, link_bw=1.25e9,   # USB-C 3.2 Gen 2: 10 Gb/s (paper §4.1.2)
    thermal_sustained=0.85, thermal_tau_s=300.0,
    decode_steps_per_s=70.0, prefill_tokens_per_s=25000.0,
)
M2_MAX_CPU = DeviceProfile(
    name="m2-max-cpu", year=2023, flops=0.9e12, mem_bytes=32e9,
    mem_bw=400e9, link_bw=1.25e9,
    decode_steps_per_s=45.0, prefill_tokens_per_s=12000.0,
)
A18_PRO = DeviceProfile(
    name="a18-pro", year=2024, flops=2.289e12, mem_bytes=8e9,
    mem_bw=60e9, link_bw=1.25e9, thermal_sustained=0.85, thermal_tau_s=300.0,
    decode_steps_per_s=80.0, prefill_tokens_per_s=30000.0,
)

PROFILES: Dict[str, DeviceProfile] = {
    p.name: p
    for p in (TPU_V5E, XEON_E3_1225V3, IPHONE_11_PRO, IPHONE_16, M2_MAX_CPU, A18_PRO)
}


def get_profile(name: str) -> DeviceProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown device profile {name!r}; known: {sorted(PROFILES)}")
