"""RWKV-6 WKV recurrence — chunked Pallas TPU kernel.

The token-recurrent form (models/rwkv.py) is a T-step serial scan — latency
-bound on any accelerator.  This kernel uses the chunked decomposition: with
log-decay ld_t = log w_t and prefix sums La_t = Σ_{s<=t} ld_s, for a chunk
of length c

    out_t  = (r_t ⊙ e^{La_{t-1}}) S_0
           + Σ_{s<t} [(r_t ⊙ e^{La_{t-1}-La_s}) · k_s] v_s     (intra, (c,c) matmul)
           + (r_t ⊙ u ⊙ k_t) · v_t                             (bonus diagonal)
    S_c    = diag(e^{La_c}) S_0 + Σ_s (k_s ⊙ e^{La_c-La_s}) v_sᵀ

i.e. three MXU matmuls per chunk instead of c sequential rank-1 updates.
Ratios are formed in log space (safe: La is monotonically decreasing).

Grid (B*H, nC), chunk dim sequential with the (K,V) state in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _rwkv_kernel(r_ref, k_ref, v_ref, ld_ref, u_ref, o_ref, s_ref, *,
                 chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)        # (c, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # (c, V)
    ld = ld_ref[0].astype(jnp.float32)      # (c, K) log decay (<= 0)
    u = u_ref[0].astype(jnp.float32)        # (K,)

    la = jnp.cumsum(ld, axis=0)             # inclusive prefix (c, K)
    la_prev = la - ld                       # exclusive prefix La_{t-1}
    la_end = la[-1]                         # La_c

    S0 = s_ref[...]                         # (K, V)
    # inter-chunk: r_t e^{La_{t-1}} @ S0
    rin = r * jnp.exp(la_prev)
    out = jax.lax.dot_general(rin, S0, (((1,), (0,)), ((), ())))
    # intra-chunk: P[t,s] = Σ_kdim r_t e^{La_{t-1}-La_s} k_s  (s < t)
    qt = r * jnp.exp(la_prev)
    ks = k * jnp.exp(-la)
    p = jax.lax.dot_general(qt, ks, (((1,), (1,)), ((), ())))   # (c, c)
    c = p.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    p = jnp.where(si < ti, p, 0.0)
    out = out + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    # bonus diagonal
    out = out + ((r * u[None, :] * k).sum(-1, keepdims=True)) * v
    o_ref[0] = out.astype(o_ref.dtype)
    # state update
    kd = k * jnp.exp(la_end[None, :] - la)
    s_ref[...] = jnp.exp(la_end)[:, None] * S0 + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())))


def rwkv6_chunked_fwd(r: jax.Array, k: jax.Array, v: jax.Array,
                      log_w: jax.Array, u: jax.Array, *,
                      chunk: int = DEFAULT_CHUNK,
                      interpret: bool = False) -> jax.Array:
    """r/k/v (B,T,H,K); log_w (B,T,H,K) = log decay (<=0); u (H,K).
    Returns out (B,T,H,K)."""
    b, t, h, dk = r.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    def prep(a):
        a = a.transpose(0, 2, 1, 3).reshape(b * h, t, dk)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        return a
    rr, kk, vv = prep(r), prep(k), prep(v)
    ld = prep(log_w)  # pad rows get ld=0 (decay 1) — harmless, outputs dropped
    uu = jnp.tile(u, (b, 1))                 # (b*h, K), b-major
    n_c = rr.shape[1] // chunk

    out = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(b * h, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, dk), lambda g, i: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dk), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct(rr.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ld, uu)
    out = out[:, :t].reshape(b, h, t, dk).transpose(0, 2, 1, 3)
    return out
