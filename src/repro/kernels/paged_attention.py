"""Paged flash-decode — Pallas TPU kernel over a block-pooled KV cache.

Same online-softmax streaming structure as :mod:`decode_attention`, but K/V
live in a shared pool of fixed-size blocks and each lane's logical cache is
the row of physical block ids in its block table.  The table and the
per-lane positions ride in scalar-prefetch memory so the BlockSpec
index_map can translate (lane, logical block) -> physical block before the
DMA is issued: K/V tiles stream straight from the pool, with no gathered
(B, span) materialisation in HBM.  Block 0 is the sink written by idle
lanes; its positions always sit past every live ``pos`` and are masked.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, nrep
):
    b_, i = pl.program_id(0), pl.program_id(1)
    n_b = pl.num_programs(1)

    @pl.when(i == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (H, D)
    k = k_ref[0].astype(jnp.float32)  # (bs, G, D)
    v = v_ref[0].astype(jnp.float32)
    bs = k.shape[0]
    h, d = q.shape
    g = k.shape[1]
    # logical block i of this lane covers token positions [i*bs, (i+1)*bs)
    kpos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    live = kpos <= pos_ref[b_]
    qg = q.reshape(g, nrep, d)
    s = jnp.einsum("gnd,sgd->gns", qg, k) * scale  # (G, nrep, bs)
    s = jnp.where(live[None, None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
    corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum("gns,sgd->gnd", p, v)
    m_ref[...] = m_new

    @pl.when(i == n_b - 1)
    def finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[..., None]).reshape(h, d).astype(o_ref.dtype)


def paged_decode_attention_fwd(
    q: jax.Array,
    kp: jax.Array,
    vp: jax.Array,
    block_tables: jax.Array,
    pos: jax.Array,
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q (B,1,H,D); kp/vp (nb,bs,G,D) block pool; block_tables (B,max_blocks)
    int32; pos (B,) int32 last-written position.  Returns (B,1,H,D)."""
    b, _, h, d = q.shape
    bs, g = kp.shape[1], kp.shape[2]
    nrep = h // g
    scale = d**-0.5 if scale is None else scale
    max_blocks = block_tables.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, i, bt, ps: (b_, 0, 0)),
            pl.BlockSpec((1, bs, g, d), lambda b_, i, bt, ps: (bt[b_, i], 0, 0, 0)),
            pl.BlockSpec((1, bs, g, d), lambda b_, i, bt, ps: (bt[b_, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, i, bt, ps: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, nrep), jnp.float32),
            pltpu.VMEM((g, nrep), jnp.float32),
            pltpu.VMEM((g, nrep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, nrep=nrep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables, pos, q[:, 0], kp, vp)
    return out[:, None]
