"""Flash-decode — Pallas TPU kernel for the HBM-bound decode step.

One new token attends to a (span,)-long KV cache: the op is a pure KV
stream (arithmetic intensity ~1 flop/byte), so the kernel's job is to
stream K/V tiles through VMEM exactly once with online softmax.  Grid
(B, nS) with the span dimension sequential; all H q-heads ride in the tile
(q is tiny), GQA expansion happens on the score tile, never in HBM.
``valid`` masks unwritten cache slots (per-lane positions — continuous
batching).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale: float, n_s: int, nrep: int):
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (H, D)
    k = k_ref[0].astype(jnp.float32)                   # (bs, G, D)
    v = v_ref[0].astype(jnp.float32)
    live = valid_ref[0]                                # (bs,)
    # scores: (H, bs) with GQA head->group mapping via reshape
    h, d = q.shape
    bs, g, _ = k.shape
    qg = q.reshape(g, nrep, d)
    s = jnp.einsum("gnd,sgd->gns", qg, k) * scale      # (G, nrep, bs)
    s = jnp.where(live[None, None, :], s, NEG_INF)
    m_prev = m_ref[...]                                # (G, nrep)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
    corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "gns,sgd->gnd", p, v)
    m_ref[...] = m_new

    @pl.when(i_s == n_s - 1)
    def finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[..., None]).reshape(h, d).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, ck: jax.Array, cv: jax.Array,
                         valid: jax.Array, *, scale: Optional[float] = None,
                         block_s: int = DEFAULT_BLOCK_S,
                         interpret: bool = False) -> jax.Array:
    """q (B,1,H,D); ck/cv (B,S,G,D); valid (B,S) bool.  Returns (B,1,H,D)."""
    b, _, h, d = q.shape
    s_len, g = ck.shape[1], ck.shape[2]
    nrep = h // g
    scale = d ** -0.5 if scale is None else scale
    block_s = min(block_s, s_len)
    pad = (-s_len) % block_s
    if pad:
        ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    n_s = ck.shape[1] // block_s
    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, n_s=n_s, nrep=nrep),
        grid=(b, n_s),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, i: (b_, 0, 0)),
            pl.BlockSpec((1, block_s, g, d), lambda b_, i: (b_, i, 0, 0)),
            pl.BlockSpec((1, block_s, g, d), lambda b_, i: (b_, i, 0, 0)),
            pl.BlockSpec((1, block_s), lambda b_, i: (b_, i)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, i: (b_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, nrep), jnp.float32),
            pltpu.VMEM((g, nrep), jnp.float32),
            pltpu.VMEM((g, nrep, d), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, 0], ck, cv, valid)
    return out[:, None]
