"""Pure-jnp oracles for every Pallas kernel (the paper's §3.4 discipline:
two implementations must agree before an op ships — it caught MPSGraph's
dropout-scaling and broadcast-matmul bugs; these oracles serve the same role
for the TPU kernels, swept in tests/test_kernels.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

# flash attention oracle: blockwise online softmax (also the big-T model path)
from repro.models.flash_ref import flash_attention_ref  # noqa: F401
# recurrence oracles
from repro.models.rwkv import rwkv6_scan_ref  # noqa: F401
from repro.models.ssm import mamba2_scan_ref  # noqa: F401


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def decode_attention_ref(q: jax.Array, ck: jax.Array, cv: jax.Array,
                         valid: jax.Array, scale: float) -> jax.Array:
    """q (B,1,H,D); ck/cv (B,S,G,D); valid (B,S)."""
    b, _, h, d = q.shape
    g = ck.shape[2]
    nrep = h // g
    kk = jnp.broadcast_to(ck[:, :, :, None, :],
                          ck.shape[:3] + (nrep, d)).reshape(
        b, ck.shape[1], h, d)
    vv = jnp.broadcast_to(cv[:, :, :, None, :],
                          cv.shape[:3] + (nrep, d)).reshape(
        b, cv.shape[1], h, d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


def rwkv6_chunked_ref(r, k, v, log_w, u):
    """Adapter: chunked kernel signature -> recurrence oracle."""
    out, _ = rwkv6_scan_ref(r, k, v, jnp.exp(log_w), u)
    return out
