"""Fused RMSNorm — Pallas TPU kernel (memory-bound fusion: one HBM read,
one write; mean-square + rsqrt + scale fused in VMEM).

Grid: rows/block_rows; each step loads a (block_rows, D) tile.  D stays
whole (norms reduce over it) — fine up to D=8192 (command-r): tile
128×8192×4 B = 4 MB in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(x: jax.Array, scale: jax.Array, eps: float = 1e-5,
                block_rows: int = 128, interpret: bool = False) -> jax.Array:
    """x (..., D); scale (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    n = xr.shape[0]
    pad = (-n) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(xr.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:n].reshape(orig_shape)
