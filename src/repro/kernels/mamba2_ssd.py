"""Mamba2 SSD chunk scan — Pallas TPU kernel (zamba2's backbone hot path).

Chunked SSD decomposition per head (state S ∈ R^{P×N}, scalar decay per
step da_t = dt_t·A ≤ 0, La = prefix sum):

    intra:  Y[t] = Σ_{s<=t} e^{La_t - La_s} (C_t·B_s) dt_s x_s   ((c,c) matmuls)
    inter:  Y[t] += e^{La_t} (C_t · S_0ᵀ)
    state:  S_c   = e^{La_c} S_0 + Σ_s e^{La_c - La_s} dt_s (x_s ⊗ B_s)

Grid (B*H, nC), chunk-sequential with S in VMEM scratch ((P,N) fp32).
B/C are shared across heads (n_groups=1) — their index_map drops the head
coordinate, so they are DMA'd once per (batch, chunk) regardless of H.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dD_ref, o_ref, sout_ref,
                s_ref):
    ic = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ic == 0)
    def init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)          # (c, P)
    dt = dt_ref[0].astype(jnp.float32)        # (c,)
    a = a_ref[0, 0]                           # scalar A (negative)
    bmat = b_ref[0].astype(jnp.float32)       # (c, N)
    cmat = c_ref[0].astype(jnp.float32)       # (c, N)
    dcoef = dD_ref[0, 0]                      # scalar D

    da = dt * a                               # (c,) log decay per step
    la = jnp.cumsum(da)                       # inclusive
    la_end = la[-1]

    S0 = s_ref[...]                           # (P, N)
    # inter-chunk
    y = jnp.exp(la)[:, None] * jax.lax.dot_general(
        cmat, S0, (((1,), (1,)), ((), ())))   # (c, P)
    # intra-chunk: G[t,s] = e^{La_t - La_s} (C_t · B_s) dt_s, s <= t
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (c,c)
    c = cb.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    ratio = jnp.exp(la[:, None] - la[None, :])
    g = jnp.where(si <= ti, cb * ratio * dt[None, :], 0.0)
    y = y + jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())))
    y = y + dcoef * x
    o_ref[0] = y.astype(o_ref.dtype)
    # state update: S_c = e^{La_c} S0 + Σ_s e^{La_c-La_s} dt_s x_s ⊗ B_s
    w = jnp.exp(la_end - la) * dt             # (c,)
    s_ref[...] = jnp.exp(la_end) * S0 + jax.lax.dot_general(
        x * w[:, None], bmat, (((0,), (0,)), ((), ())))

    @pl.when(ic == n_c - 1)
    def emit_state():
        sout_ref[0] = s_ref[...]


def mamba2_ssd_fwd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array, *,
                   chunk: int = DEFAULT_CHUNK,
                   interpret: bool = False):
    """x (B,T,H,P); dt (B,T,H); A (H,); B/C (B,T,N) [n_groups=1]; D (H,).
    Returns (y (B,T,H,P), final state (B,H,P,N))."""
    bsz, t, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk

    xx = x.transpose(0, 2, 1, 3).reshape(bsz * h, t, p)
    dtt = dt.transpose(0, 2, 1).reshape(bsz * h, t)
    if pad:
        xx = jnp.pad(xx, ((0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, pad)))   # dt=0 -> decay 1, no update
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    aa = jnp.tile(A[None, :], (bsz, 1)).reshape(bsz * h, 1)
    dd = jnp.tile(D[None, :], (bsz, 1)).reshape(bsz * h, 1)
    n_c = xx.shape[1] // chunk

    y, s_out = pl.pallas_call(
        _ssd_kernel,
        grid=(bsz * h, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, chunk), lambda g, i: (g, i)),
            pl.BlockSpec((1, 1), lambda g, i: (g, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i, h=h: (g // h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda g, i, h=h: (g // h, i, 0)),
            pl.BlockSpec((1, 1), lambda g, i: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, p, n), lambda g, i: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xx.shape, x.dtype),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xx, dtt, aa, B, C, dd)
    y = y[:, :t].reshape(bsz, h, t, p).transpose(0, 2, 1, 3)
    return y, s_out.reshape(bsz, h, p, n)
