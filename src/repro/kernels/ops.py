"""Jit'd public entry points for the Pallas kernels.

Routing: on TPU the kernels run compiled; anywhere else (this CPU container)
they run in ``interpret=True`` mode — same kernel body, Python-evaluated —
so correctness is exercised everywhere the framework runs.

Gradients: ``flash_attention`` carries a custom VJP whose backward is the
AD of the blockwise oracle under remat (recompute-based flash backward).
The rwkv6/mamba2 chunked kernels get the same treatment (oracle-AD bwd).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba2_ssd import mamba2_ssd_fwd
from repro.kernels.paged_attention import paged_decode_attention_fwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.rwkv6_scan import rwkv6_chunked_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention (custom VJP: kernel fwd, oracle-AD bwd)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    scale: Optional[float] = None, chunk: int = 0):
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               chunk=chunk, interpret=_interpret())


def _fa_fwd(q, k, v, causal, scale, chunk):
    out = flash_attention(q, k, v, causal, scale, chunk)
    return out, (q, k, v)


def _fa_bwd(causal, scale, chunk, res, g):
    q, k, v = res
    f = lambda q, k, v: kref.flash_attention_ref(
        q, k, v, causal=causal, scale=scale, chunk=chunk)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# decode attention (no grad needed — serving only)
# ---------------------------------------------------------------------------

def decode_attention(q, ck, cv, valid, scale: float):
    return decode_attention_fwd(q, ck, cv, valid, scale=scale,
                                interpret=_interpret())


def paged_decode_attention(q, kp, vp, block_tables, pos, scale: float):
    """Flash-decode over a block-pooled KV cache (serving only, no grad)."""
    return paged_decode_attention_fwd(q, kp, vp, block_tables, pos,
                                      scale=scale, interpret=_interpret())


# ---------------------------------------------------------------------------
# rwkv6 chunked scan
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _rwkv6(r, k, v, log_w, u):
    return rwkv6_chunked_fwd(r, k, v, log_w, u, interpret=_interpret())


def _rwkv6_f(r, k, v, log_w, u):
    return _rwkv6(r, k, v, log_w, u), (r, k, v, log_w, u)


def _rwkv6_b(res, g):
    r, k, v, log_w, u = res
    _, vjp = jax.vjp(lambda *a: kref.rwkv6_chunked_ref(*a), r, k, v, log_w, u)
    return vjp(g)


_rwkv6.defvjp(_rwkv6_f, _rwkv6_b)


def rwkv6_scan(r, k, v, w, u):
    """Model-facing signature: w is the DECAY in (0,1) (models/rwkv.py);
    the kernel wants log-decay.  Returns (out, final_state=None marker)."""
    log_w = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    out = _rwkv6(r, k, v, log_w, u)
    return out, None


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _ssd(x, dt, A, B, C, D):
    return mamba2_ssd_fwd(x, dt, A, B, C, D, interpret=_interpret())


def _ssd_f(x, dt, A, B, C, D):
    return _ssd(x, dt, A, B, C, D), (x, dt, A, B, C, D)


def _ssd_b(res, g):
    # gradient flows through y only; the final state is consumed at decode
    # time (no training path) — its cotangent is dropped
    gy, _gs = g
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(lambda *a: kref.mamba2_scan_ref(*a)[0], x, dt, A, B, C, D)
    return vjp(gy)


_ssd.defvjp(_ssd_f, _ssd_b)


def mamba2_ssd(x, dt, A, B, C, D):
    """Returns (y, final_state)."""
    return _ssd(x, dt, A, B, C, D)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-5):
    return rmsnorm_fwd(x, scale, eps, interpret=_interpret())
