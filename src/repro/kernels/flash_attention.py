"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (B, H, nQ, nK) with the K dimension iterated sequentially
(innermost); online-softmax running (m, l, acc) live in VMEM scratch across
the nK steps and the normalised tile is written once at ik == nK-1.  GQA is
free: the K/V BlockSpec index_map folds the q-head -> kv-head mapping, so
repeated heads are never materialised.  Causal and chunked-local (llama4)
masks are applied in-tile; fully-masked tiles are skipped via a cheap
mask-aware branch (pl.when) that leaves the accumulators untouched.

Block shapes default to (128, 512): q tile rows hit the MXU 128-lane dim,
K tile of 512 keeps the (bq, bk) f32 score tile at 256 KB and the whole
working set (q + k + v + scores + acc) ~1.3 MB << 64 MB VMEM while long
enough to amortise the HBM -> VMEM DMA.

Backward is recompute-based (custom_vjp in ops.py: the blockwise jnp oracle
is AD-differentiated under remat) — fwd-kernel-only is the deliberate
scope: training hot-path fwd runs the kernel, bwd reuses XLA fusion.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, chunk: int, block_q: int,
               block_k: int, n_k: int, t_q: int, t_k: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    live = (qpos < t_q) & (kpos < t_k)
    if causal:
        live &= kpos <= qpos
    if chunk:
        live &= (qpos // chunk) == (kpos // chunk)

    # whole-tile skip: cheapest necessary-condition checks (static per tile)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isinf(m_new)[:, None], 0.0, p)
        corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    if causal:
        pl.when(ik * block_k <= (iq + 1) * block_q - 1)(compute)
    elif chunk:
        # tiles fully outside the chunk band contribute nothing
        pl.when((ik * block_k) // chunk <= ((iq + 1) * block_q - 1) // chunk)(compute)
    else:
        compute()

    @pl.when(ik == n_k - 1)
    def finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale: Optional[float] = None,
                        chunk: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jax.Array:
    """q (B,T,H,D); k/v (B,Tk,G,D), H % G == 0.  Returns (B,T,H,D)."""
    b, t, h, d = q.shape
    tk, g = k.shape[1], k.shape[2]
    nrep = h // g
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, max(8, t))
    block_k = min(block_k, max(128, tk)) if tk >= 128 else tk
    # kernel-friendly layout (B,H,T,D)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    pq = (-t) % block_q
    pk = (-tk) % block_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    n_q = qt.shape[2] // block_q
    n_k = kt.shape[2] // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, chunk=chunk,
        block_q=block_q, block_k=block_k, n_k=n_k, t_q=t, t_k=tk)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, nrep=nrep: (b_, h_ // nrep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, nrep=nrep: (b_, h_ // nrep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, qt.shape[2], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :t].transpose(0, 2, 1, 3)
