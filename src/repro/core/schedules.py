"""Pipeline schedule accounting (paper Fig. 3).

A schedule is a table of per-(tick, stage) work items.  Two schedules:

* ``gpipe``    — forward sweep then backward sweep (AD-reversed).
* ``hybrid``   — the paper's hybrid GPipe/1F1B: the LAST stage fuses its
  forward + loss + its own backward in one tick (the MPSGraph static-graph
  constraint turned into a feature); the backward sweep covers stages
  0..S-2 only and overlaps with the tail of the forward sweep (1F1B-style).

Work-unit convention: fwd = 1, bwd = 2, fused f+b = 3.  These tables drive
``benchmarks/bench_schedules.py`` (tick counts, bubble fractions) and
document what the shard_map runtime executes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

FWD, BWD, FUSED, IDLE = "F", "B", "FB", "."


@dataclasses.dataclass(frozen=True)
class Tick:
    stage_ops: Tuple[str, ...]         # op per stage at this tick
    mb: Tuple[Optional[int], ...]      # microbatch index per stage (fwd work)


def gpipe_table(n_stages: int, n_micro: int) -> List[Tick]:
    s, m = n_stages, n_micro
    ticks: List[Tick] = []
    for t in range(m + s - 1):                       # forward sweep
        ops, mbs = [], []
        for st in range(s):
            mb = t - st
            ok = 0 <= mb < m
            ops.append(FWD if ok else IDLE)
            mbs.append(mb if ok else None)
        ticks.append(Tick(tuple(ops), tuple(mbs)))
    for t in range(m + s - 1):                       # backward sweep (reversed)
        ops, mbs = [], []
        for st in range(s):
            mb = t - (s - 1 - st)
            ok = 0 <= mb < m
            ops.append(BWD if ok else IDLE)
            mbs.append(mb if ok else None)
        ticks.append(Tick(tuple(ops), tuple(mbs)))
    return ticks


def hybrid_table(n_stages: int, n_micro: int) -> List[Tick]:
    """Paper's hybrid: last stage runs FUSED F+B; other stages run FWD for
    microbatch t-s and BWD for the cotangent arriving from the right
    (1F1B interleave).  Ticks: M + 2S - 2."""
    s, m = n_stages, n_micro
    ticks: List[Tick] = []
    for t in range(m + 2 * s - 2):
        ops, mbs = [], []
        for st in range(s):
            fwd_mb = t - st
            fwd_ok = (0 <= fwd_mb < m) and st < s            # inject window
            if st == s - 1:
                ops.append(FUSED if fwd_ok else IDLE)
                mbs.append(fwd_mb if fwd_ok else None)
                continue
            # backward for mb b arrives at stage st at tick b + (2s - 2 - st)
            bwd_mb = t - (2 * s - 2 - st)
            bwd_ok = 0 <= bwd_mb < m
            if fwd_ok and bwd_ok:
                ops.append(FWD + BWD)
            elif fwd_ok:
                ops.append(FWD)
            elif bwd_ok:
                ops.append(BWD)
            else:
                ops.append(IDLE)
            mbs.append(fwd_mb if fwd_ok else None)
        ticks.append(Tick(tuple(ops), tuple(mbs)))
    return ticks


_COST = {FWD: 1.0, BWD: 2.0, FUSED: 3.0, FWD + BWD: 3.0, IDLE: 0.0}


def schedule_stats(table: List[Tick], n_stages: int, n_micro: int) -> dict:
    """Wall-clock model: each tick costs max over stages of its work units."""
    per_tick = [max(_COST[o] for o in tk.stage_ops) for tk in table]
    wall = sum(per_tick)
    busy = sum(_COST[o] for tk in table for o in tk.stage_ops)
    ideal = 3.0 * n_micro                      # per stage: M fwd + M bwd units
    return {
        "ticks": len(table),
        "wall_units": wall,
        "busy_units": busy,
        "ideal_units": ideal * n_stages,
        "bubble_fraction": 1.0 - (ideal / wall) if wall else 0.0,
        "utilisation": busy / (wall * n_stages) if wall else 0.0,
    }


def render(table: List[Tick]) -> str:
    """ASCII rendering (paper Fig. 3 style), stages as rows."""
    s = len(table[0].stage_ops)
    rows = []
    for st in range(s):
        cells = [f"{tk.stage_ops[st]:>3}" for tk in table]
        rows.append(f"stage{st}: " + " ".join(cells))
    return "\n".join(rows)


def verify_dataflow(table: List[Tick], n_stages: int, n_micro: int,
                    schedule: str) -> None:
    """Invariants: every mb visits every stage in order; fwd precedes bwd."""
    seen_fwd = {}
    for t, tk in enumerate(table):
        for st, mb in enumerate(tk.mb):
            if mb is not None and (FWD in tk.stage_ops[st] or
                                   tk.stage_ops[st] == FUSED):
                seen_fwd[(st, mb)] = t
    for mb in range(n_micro):
        for st in range(n_stages):
            assert (st, mb) in seen_fwd, f"mb {mb} never fwd at stage {st}"
            if st:
                assert seen_fwd[(st, mb)] == seen_fwd[(st - 1, mb)] + 1, \
                    f"mb {mb} skipped a tick between stages {st-1}->{st}"
