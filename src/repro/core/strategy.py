"""Strategy selection per (arch × shape): which distribution path runs a cell.

* ``pp_shardmap`` — the paper's pipeline (shard_map + ppermute), for training
  shapes of uniform-block small/mid archs (fits when params/S ≤ HBM with DP
  replication over "data").
* ``gspmd_tp``    — jit GSPMD TP("model") × DP/FSDP("data","pod"); all
  serving shapes, enc-dec, and big-vocab archs.
* ``gspmd_pp``    — stacked-stage scan pipeline in jit (PP on "data" × TP on
  "model"); training shapes of the MoE giants.

``auto`` resolves per the table; configs/CLI can override.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig

# archs whose TRAIN cells run the paper's shard_map pipeline by default
PP_TRAIN_ARCHS = {
    "granite-8b", "mistral-nemo-12b", "rwkv6-1.6b", "internvl2-1b", "zamba2-7b",
}
# MoE giants: PP×TP stacked pipeline for training
PP_STACKED_TRAIN_ARCHS = {"grok-1-314b", "llama4-scout-17b-a16e"}


def resolve(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig) -> str:
    if rcfg.strategy != "auto":
        return rcfg.strategy
    if shape.kind == "train":
        if cfg.arch_id in PP_TRAIN_ARCHS:
            return "pp_shardmap"
        if cfg.arch_id in PP_STACKED_TRAIN_ARCHS:
            return "gspmd_pp"
        return "gspmd_tp"
    return "gspmd_tp"


def wants_fsdp(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """FSDP over "data" for params only when the TP-sharded weights exceed
    ~6 GB/device.  §Perf iteration A1: with grad accumulation, FSDP
    all-gathers weights EVERY microbatch (measured 552 GB wire/dev for
    command-r train) — ZeRO-1 moments (always on) give the memory win
    without the per-microbatch gather, so the FSDP threshold is high."""
    if shape.kind != "train":
        return False
    return cfg.total_params() * 2 / 16 > 6e9
