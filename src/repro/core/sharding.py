"""Logical-axis sharding rules -> NamedSharding/PartitionSpec.

Params get logical axis names derived from their tree path (MaxText-style);
a per-strategy rule table maps logical names to mesh axes.  Rules silently
fall back to replication when a dimension is not divisible by the mesh axis
size — divisibility is checked against real shapes so the dry-run never
emits an invalid sharding.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# logical axes from tree paths
# ---------------------------------------------------------------------------

# (path-fragment, ndim) -> logical axes per dim.  First match wins; "*" in a
# fragment matches any single path component.  Leading "layers" dims for
# stacked leaves are added automatically.
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    ("embed/tok",        ("vocab", "embed")),
    ("head/w",           ("embed", "vocab")),
    ("attn/wq",          ("embed", "q")),
    ("attn/wk",          ("embed", "kv")),
    ("attn/wv",          ("embed", "kv")),
    ("attn/wo",          ("q", "embed")),
    ("attn/bq",          ("q",)),
    ("attn/bk",          ("kv",)),
    ("attn/bv",          ("kv",)),
    ("xattn/wq",         ("embed", "q")),
    ("xattn/wk",         ("embed", "kv")),
    ("xattn/wv",         ("embed", "kv")),
    ("xattn/wo",         ("q", "embed")),
    ("moe/router",       ("embed", "experts")),
    ("moe/wi",           ("experts", "embed", "mlp")),
    ("moe/wg",           ("experts", "embed", "mlp")),
    ("moe/wo",           ("experts", "mlp", "embed")),
    ("moe/shared/wi",    ("embed", "mlp")),
    ("moe/shared/wg",    ("embed", "mlp")),
    ("moe/shared/wo",    ("mlp", "embed")),
    ("mlp/wi",           ("embed", "mlp")),
    ("mlp/wg",           ("embed", "mlp")),
    ("mlp/wo",           ("mlp", "embed")),
    ("mamba/in_proj",    ("embed", "ssm_in")),
    ("mamba/out_proj",   ("ssm_in", "embed")),
    ("mamba/conv_w",     (None, "ssm_conv")),
    ("mamba/conv_b",     ("ssm_conv",)),
    ("rwkv/wr",          ("embed", "q")),
    ("rwkv/wk",          ("embed", "q")),
    ("rwkv/wv",          ("embed", "q")),
    ("rwkv/wg",          ("embed", "q")),
    ("rwkv/wo",          ("q", "embed")),
    ("rwkv/ck",          ("embed", "mlp")),
    ("rwkv/cv",          ("mlp", "embed")),
    ("rwkv/cr",          ("embed", "q")),
    ("rwkv/w_lora_a",    ("embed", None)),
    ("rwkv/w_lora_b",    (None, "embed")),
)

# strategy -> {logical axis: mesh axis}
RULE_TABLES: Dict[str, Dict[str, Any]] = {
    # TP over "model", optional FSDP over "data" on the "embed" dim.
    "gspmd_tp": {
        "vocab": "model", "q": "model", "kv": "model", "mlp": "model",
        "experts": "model", "ssm_in": "model", "ssm_conv": "model",
        "embed": None,           # flipped to "data" when fsdp=True
        "layers": None,
    },
    # stacked-stage pipeline in jit: stage axis on "data", TP on "model".
    "gspmd_pp": {
        "stage": "data",
        "vocab": "model", "q": "model", "kv": "model", "mlp": "model",
        "experts": "model", "ssm_in": "model", "ssm_conv": "model",
        "embed": None, "layers": None,
    },
}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def logical_axes_for(path: str, ndim: int,
                     leading: Tuple[Optional[str], ...] = ()) -> Tuple:
    """Logical axes for one param leaf; unknown leaves replicate."""
    for frag, axes in _PARAM_RULES:
        if path.endswith(frag) or (frag + "/") in path or ("/" + frag) in path:
            want = len(axes) + len(leading)
            if ndim == want:
                return tuple(leading) + tuple(axes)
            if ndim == len(axes):
                return tuple(axes)
            # stacked with extra leading dims (e.g. experts handled in rule)
            extra = ndim - len(axes)
            if extra > 0:
                return tuple(leading[:extra]) + (None,) * max(0, extra - len(leading)) + tuple(axes)
    return (None,) * ndim


def param_logical_tree(params: Any, stacked_prefix: str = "blocks",
                       leading: Tuple[Optional[str], ...] = ("layers",)) -> Any:
    """Pytree of logical-axis tuples matching ``params``.

    Leaves under ``stacked_prefix`` (or ``enc_blocks``/``dec_blocks``) get the
    ``leading`` axes prepended (the stacked layer dim).
    """
    def fn(path, leaf):
        p = _path_str(path)
        stacked = any(p.startswith(pref) for pref in
                      (stacked_prefix, "enc_blocks", "dec_blocks"))
        lead = leading if stacked else ()
        return logical_axes_for(p, np.ndim(leaf), lead)

    return jax.tree_util.tree_map_with_path(fn, params)


def spec_for(logical: Tuple, shape: Tuple[int, ...], rules: Dict[str, Any],
             mesh: Mesh) -> P:
    """PartitionSpec from logical axes; replicates non-divisible dims."""
    out = []
    used = set()
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if axis is None or axis in used:
            out.append(None)
            continue
        size = np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))])
        if dim % int(size) == 0:
            out.append(axis)
            used.add(axis)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(params_shape: Any, mesh: Mesh, strategy: str,
                    fsdp: bool = False, extra_rules: Optional[dict] = None) -> Any:
    """NamedSharding tree for a params (ShapeDtypeStruct) tree."""
    rules = dict(RULE_TABLES[strategy])
    if fsdp:
        rules["embed"] = "data"
    if extra_rules:
        rules.update(extra_rules)
    logical = param_logical_tree(params_shape)

    def fn(leaf, log):
        return NamedSharding(mesh, spec_for(log, leaf.shape, rules, mesh))

    return jax.tree.map(fn, params_shape, logical)


def batch_shardings(batch_specs: Any, mesh: Mesh,
                    batch_axes: Tuple[str, ...] = ("pod", "data")) -> Any:
    """Shard dim-0 (batch) of every input over the data axes present."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)

    def fn(leaf):
        if np.ndim(leaf) == 0:
            return NamedSharding(mesh, P())
        dp = int(np.prod([mesh.shape[a] for a in axes]))
        if leaf.shape[0] % dp == 0:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(fn, batch_specs)


def cache_shardings(cache_specs: Any, mesh: Mesh, cfg) -> Any:
    """KV caches: layer dim replicated, batch dim over data axes, head/state
    dims over "model" when divisible.  Cache leaves are (L, B, ...)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    tp = mesh.shape.get("model", 1)

    def fn(path, leaf):
        if np.ndim(leaf) < 2:
            return NamedSharding(mesh, P())
        spec: list = [None] * np.ndim(leaf)
        # find the batch dim: first dim equal to a multiple of dp after layers
        bdim = 1 if np.ndim(leaf) >= 2 else 0
        if leaf.shape[bdim] % dp == 0 and leaf.shape[bdim] > 0:
            spec[bdim] = axes
        # shard the largest trailing dim over model if divisible
        best, best_size = None, 0
        for i in range(bdim + 1, np.ndim(leaf)):
            if leaf.shape[i] % tp == 0 and leaf.shape[i] > best_size and leaf.shape[i] >= tp:
                best, best_size = i, leaf.shape[i]
        if best is not None:
            spec[best] = "model"
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(fn, cache_specs)


# ---------------------------------------------------------------------------
# activation sharding hints (set at trace time by the step builders)
# ---------------------------------------------------------------------------

_ACT_HINTS: Dict[str, Any] = {}


def set_activation_hints(**kw) -> None:
    """Register NamedShardings for named activation sites (e.g. "residual").
    Trace-time: the step builders set these before jit-tracing; model code
    applies them via :func:`constrain`."""
    _ACT_HINTS.update(kw)


def clear_activation_hints() -> None:
    _ACT_HINTS.clear()


def constrain(name: str, x):
    s = _ACT_HINTS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
