"""Calibrate effective device rates from the paper's OWN measurements.

Finding (recorded in EXPERIMENTS.md): the paper's Table-1 TFLOPS ratings are
inconsistent with its own timings — the 2013 Xeon is rated 0.061 TFLOPS yet
sustains ResNet-34 training at ~13.1 s/batch-128 ≈ 0.21 TFLOP/s of model
FLOPs.  So the heterogeneous cost model is calibrated against the paper's
measured baselines (appendix A.1), and the *held-out* pairs validate it:

    calibrated on:  desktop_alone, mac_alone, desktop+iPhone11, desktop+iPhone16
    held out:       mac+iPhone16 (train), desktop+iPhone11 (inference)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.partition import (SplitPlan, pipeline_batch_seconds,
                                  single_device_seconds, split_blocks)
from repro.hw.specs import (DeviceProfile, IPHONE_11_PRO, IPHONE_16,
                            M2_MAX_CPU, XEON_E3_1225V3)

# Paper appendix A.1 mean per-batch times (ms), batch 128, microbatch 16 (M=8)
PAPER_MS = {
    "desktop_alone": 13104.75,
    "desktop_iph11": 10162.54,
    "desktop_iph16": 7308.26,
    "mac_alone": 9008.52,
    "mac_iph16": 6719.06,
    # inference (10 batches of 128)
    "desktop_alone_infer": 4399.81,
    "desktop_iph11_infer": 2810.50,
}
N_MICRO = 8
MB = 16                                     # microbatch size


def resnet_costs(batch: int = MB):
    import jax

    from repro.configs.resnet34 import CONFIG
    from repro.models.resnet import block_costs, init_resnet

    meta, params = init_resnet(CONFIG, jax.random.key(0))
    return block_costs(CONFIG, meta, params, batch)


def _effective(profile: DeviceProfile, rate: float) -> DeviceProfile:
    return dataclasses.replace(profile, flops=rate)


def calibrate_host(costs, measured_ms: float) -> float:
    """Single-device rate from the alone baseline (train: 3x fwd flops)."""
    flops = 3.0 * sum(f for f, _ in costs) * N_MICRO
    return flops / (measured_ms / 1e3)


def calibrate_phone(costs, host: DeviceProfile, phone: DeviceProfile,
                    measured_ms: float) -> float:
    """1-D search for the phone's effective rate that reproduces the
    measured 2-stage pipeline batch time."""
    target = measured_ms / 1e3

    def predict(rate: float) -> float:
        plan = split_blocks(costs, [host, _effective(phone, rate)],
                            efficiency=1.0)
        return pipeline_batch_seconds(plan, N_MICRO)

    rates = np.geomspace(1e9, 5e12, 400)
    errs = [abs(predict(r) - target) for r in rates]
    return float(rates[int(np.argmin(errs))])


def calibrated_profiles() -> Dict[str, DeviceProfile]:
    costs = resnet_costs()
    xeon_rate = calibrate_host(costs, PAPER_MS["desktop_alone"])
    mac_rate = calibrate_host(costs, PAPER_MS["mac_alone"])
    xeon = _effective(XEON_E3_1225V3, xeon_rate)
    mac = _effective(M2_MAX_CPU, mac_rate)
    iph11 = _effective(IPHONE_11_PRO,
                       calibrate_phone(costs, xeon, IPHONE_11_PRO,
                                       PAPER_MS["desktop_iph11"]))
    iph16 = _effective(IPHONE_16,
                       calibrate_phone(costs, xeon, IPHONE_16,
                                       PAPER_MS["desktop_iph16"]))
    return {"xeon": xeon, "mac": mac, "iphone11": iph11, "iphone16": iph16}


def reproduction_table() -> List[dict]:
    """Predicted vs paper-measured times for every §4.1 setup.  Held-out
    rows are marked (they were NOT used for calibration)."""
    costs = resnet_costs()
    profs = calibrated_profiles()
    rows = []

    def add(name, predicted_s, held_out):
        measured = PAPER_MS[name] / 1e3
        rows.append(dict(setup=name, predicted_s=round(predicted_s, 3),
                         paper_s=round(measured, 3),
                         rel_err=round(abs(predicted_s - measured) / measured, 3),
                         held_out=held_out))

    add("desktop_alone",
        single_device_seconds(costs, profs["xeon"], N_MICRO, 1.0), False)
    add("mac_alone",
        single_device_seconds(costs, profs["mac"], N_MICRO, 1.0), False)
    for name, host, phone in [("desktop_iph11", "xeon", "iphone11"),
                              ("desktop_iph16", "xeon", "iphone16")]:
        plan = split_blocks(costs, [profs[host], profs[phone]], efficiency=1.0)
        add(name, pipeline_batch_seconds(plan, N_MICRO), False)
    # HELD OUT: mac + iPhone16 (train)
    plan = split_blocks(costs, [profs["mac"], profs["iphone16"]], efficiency=1.0)
    add("mac_iph16", pipeline_batch_seconds(plan, N_MICRO), True)
    # HELD OUT: desktop + iPhone11 (inference; fwd-only costs)
    add("desktop_alone_infer",
        single_device_seconds(costs, profs["xeon"], N_MICRO, 1.0, train=False),
        True)
    plan = split_blocks(costs, [profs["xeon"], profs["iphone11"]],
                        efficiency=1.0, train=False)
    add("desktop_iph11_infer",
        pipeline_batch_seconds(plan, N_MICRO), True)
    return rows
