"""GSPMD stacked-stage pipeline (beyond-paper scalability path).

The shard_map pipeline puts stages on "model"; the MoE giants additionally
need tensor/expert parallelism *within* a stage.  This variant runs the
pipeline entirely inside jit: stage-stacked weights (S, L/S, ...) sharded on
the 16-way "data" axis, TP/EP on "model", DP on "pod".  The per-tick shift
of the stage buffer (concat of [inject, y[:-1]] on the stage-sharded dim)
lowers to a CollectivePermute — same wire pattern as the manual ppermute,
but every stage-internal op remains GSPMD-sharded (praxis-style pipelining).

Backward = AD through the tick scan (GPipe schedule); MoE aux-losses
accumulate naturally through the scan carry (this is why MoE archs live here
rather than in the manual pipeline).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import sharding as sh
from repro.core.partition import PipelinePlan, plan_pipeline
from repro.models import blocks as B
from repro.models.api import build_model
from repro.models.common import embed_tokens, rmsnorm, chunked_xent
from repro.models.lm import head_weight
from repro.optim import adamw

AUX_COEF = 0.01


def _stack_for_stages(params: dict, plan: PipelinePlan) -> dict:
    s, lps = plan.n_stages, plan.layers_per_stage

    def fix(a):
        pad = plan.slots - a.shape[0]
        if pad:
            a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
        return a.reshape((s, lps) + a.shape[1:])

    out = dict(params)
    out["blocks"] = jax.tree.map(fix, params["blocks"])
    return out


def _unstack(params_pp: dict, plan: PipelinePlan, n_layers: int) -> dict:
    out = dict(params_pp)
    out["blocks"] = jax.tree.map(
        lambda a: a.reshape((plan.slots,) + a.shape[2:])[:n_layers],
        params_pp["blocks"])
    return out


def make_gspmd_pp_train_step(cfg: ModelConfig, shape: ShapeConfig,
                             rcfg: RunConfig, mesh,
                             opt_cfg: Optional[adamw.AdamWConfig] = None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    model = build_model(cfg, rcfg)
    cdt = jnp.dtype(rcfg.compute_dtype)
    uk = rcfg.use_kernels

    stage_axis = mesh.shape["data"]
    plan = plan_pipeline(cfg.n_layers, stage_axis,
                         rcfg.microbatches, "gpipe", candidates=(stage_axis,))
    S, lps = plan.n_stages, plan.layers_per_stage
    dp = mesh.shape.get("pod", 1)
    b_dp = shape.global_batch // dp        # per-pod batch (for picking M)
    m = rcfg.microbatches or min(b_dp, 2 * S)
    while b_dp % m:
        m -= 1
    b_mb = shape.global_batch // m         # GLOBAL microbatch rows; the pod
    #                                        axis shards this dim (arrays in
    #                                        jit are global-shaped)
    p_front = cfg.frontend_seq if cfg.frontend else 0
    t_tok = shape.seq_len - p_front
    t_total = shape.seq_len
    n_ticks = m + S - 1

    def stage_fn(bp_stage, x, stage_idx):
        """One stage's lps layers. bp_stage: (lps, ...); x: (b_mb, T, D)."""
        def body(carry, inp):
            x, aux = carry
            bp, i = inp
            gidx = stage_idx * lps + i

            def live(x):
                return B.block_train(cfg, bp, x, gidx, uk)

            x, a = jax.lax.cond(gidx < cfg.n_layers, live,
                                lambda x: (x, B.ZERO), x)
            return (x, aux + a), None

        fn = jax.checkpoint(body, prevent_cse=False) if rcfg.remat else body
        (x, aux), _ = jax.lax.scan(fn, (x, B.ZERO),
                                   (bp_stage, jnp.arange(lps)))
        return x, aux

    def buf_constraint(buf):
        return jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, P("data",
                                       "pod" if "pod" in mesh.shape else None,
                                       None, "model")))

    def loss_fn(params, batch):
        tokens = batch["tokens"]                       # global (B, T)
        tokens = tokens.reshape(m, b_mb, t_tok)
        fr = None
        if p_front:
            fr = batch["frontend"].reshape(m, b_mb, p_front, cfg.d_model)
        w = head_weight(cfg, params, cdt)
        stage_ids = jnp.arange(S)

        def embed_mb(t):
            tc = jnp.clip(t, 0, m - 1)
            x = embed_tokens(params["embed"], tokens[tc], cdt)
            if p_front:
                x = jnp.concatenate([fr[tc].astype(cdt), x], axis=1)
            return x

        buf0 = buf_constraint(jnp.zeros((S, b_mb, t_total, cfg.d_model), cdt))

        def tick(carry, t):
            buf, loss, aux = carry
            y, a = jax.vmap(stage_fn)(params["blocks"], buf, stage_ids)
            y = buf_constraint(y)
            # only stages with live microbatches contribute aux (bubble ticks
            # compute on garbage and must not pollute the load-balance loss)
            live = ((t - stage_ids) >= 0) & ((t - stage_ids) < m)
            aux = aux + jnp.sum(a * live.astype(a.dtype))
            # last-stage output -> loss for microbatch t-(S-1)
            mb_l = t - (S - 1)
            lvalid = (mb_l >= 0) & (mb_l < m)
            mb_lc = jnp.clip(mb_l, 0, m - 1)
            h = rmsnorm(params["final_ln"], y[S - 1])
            tok_mb = tokens[mb_lc]
            if p_front:
                hh = h[:, p_front - 1: p_front + t_tok - 1]
                labels = tok_mb
            else:
                hh, labels = h[:, : t_tok - 1], tok_mb[:, 1:]
            ce = chunked_xent(hh, w, labels, cfg.vocab_size)
            loss = loss + jnp.where(lvalid, ce, 0.0) / m
            # shift: new stage-0 input is the next microbatch's embedding
            inject = embed_mb(t + 1)
            buf = jnp.concatenate([inject[None], y[:-1]], axis=0)
            buf = buf_constraint(buf)
            return (buf, loss, aux), None

        buf0 = buf0.at[0].set(embed_mb(0))
        (_, loss, aux), _ = jax.lax.scan(
            tick, (buf0, jnp.float32(0.0), jnp.float32(0.0)),
            jnp.arange(n_ticks))
        aux = aux / m                       # mean over microbatches
        return loss + AUX_COEF * aux, {"ce": loss, "aux": aux}

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        new_p, new_o, stats = adamw.update(opt_cfg, grads, opt_state, params)
        return new_p, new_o, dict(metrics, loss=loss, **stats)

    # ---- specs & shardings ----
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pp_shape = jax.eval_shape(functools.partial(_stack_for_stages, plan=plan),
                              params_shape)
    opt_shape = jax.eval_shape(adamw.init, pp_shape)
    batch_specs = model.input_specs(shape)

    # logical rules: stacked blocks get leading ("stage","layers")
    logical = sh.param_logical_tree(pp_shape, leading=("stage", "layers"))
    rules = dict(sh.RULE_TABLES["gspmd_pp"])

    def shard_of(leaf, log):
        return NamedSharding(mesh, sh.spec_for(log, leaf.shape, rules, mesh))

    p_shard = jax.tree.map(shard_of, pp_shape, logical)
    opt_shard = {"m": p_shard, "v": p_shard, "step": NamedSharding(mesh, P())}
    b_shard = jax.tree.map(
        lambda a: NamedSharding(
            mesh, P("pod" if "pod" in mesh.shape else None))
        if np.ndim(a) else NamedSharding(mesh, P()), batch_specs)
    metrics_shape = jax.eval_shape(train_step, pp_shape, opt_shape,
                                   batch_specs)[2]
    out_shardings = (p_shard, opt_shard,
                     jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                  metrics_shape))
    return dict(
        fn=train_step,
        args=(pp_shape, opt_shape, batch_specs),
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=out_shardings,
        donate_argnums=(0, 1),
        meta={"strategy": "gspmd_pp", "S": S, "M": m,
              "layers_per_stage": lps, "n_pad_layers": plan.n_pad,
              "layers_multiplier": lps,
              "tick_multiplier": n_ticks},
        model=model,
        plan=plan,
        to_pipeline=functools.partial(_stack_for_stages, plan=plan),
        from_pipeline=functools.partial(_unstack, plan=plan,
                                        n_layers=cfg.n_layers),
    )
