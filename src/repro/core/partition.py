"""Heterogeneous cost-model stage partitioner.

The paper hand-tunes its 2-stage split ("right before the 4th residual block
of layer 3" for the Xeon+iPhone-11 pair, "the entire layer 3" for the
iPhone 16).  This module makes that choice a cost model:

* :func:`split_blocks` — given per-block (flops, boundary_bytes) and a list of
  device profiles (compute rate, link bandwidth), choose cut points that
  minimise the pipeline's bottleneck stage time (compute + boundary transfer).
  Reproduces the paper's split decisions from its own device numbers
  (validated in tests/benchmarks).

* :func:`split_decode` — the SERVING-mode search: decode is sequential per
  token (token t+1 needs token t), so the objective is the *sum* of stage
  step times plus boundary-frame transfers, not the pipelined bottleneck —
  and the binding constraint is each stage fitting its device's
  ``mem_bytes`` (the whole reason to split a decode model at all).

* :func:`plan_pipeline` — homogeneous-TPU planning for the shard_map
  pipeline: stage count S (divisor of the model-axis), replica factor R,
  layers-per-stage with padding, and the schedule's tick/bubble accounting.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.hw.specs import DeviceProfile


# ---------------------------------------------------------------------------
# heterogeneous split (paper §4.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SplitPlan:
    cuts: Tuple[int, ...]              # block index where each next stage starts
    stage_seconds: Tuple[float, ...]   # per-microbatch compute time per stage
    comm_seconds: Tuple[float, ...]    # boundary transfer time after stage i
    bottleneck: float                  # max(stage+comm) — steady-state tick

    @property
    def throughput(self) -> float:
        return 1.0 / self.bottleneck


def _stage_time(flops: float, dev: DeviceProfile, efficiency: float) -> float:
    return flops / (dev.flops * efficiency)


def split_blocks(costs: Sequence[Tuple[float, float]],
                 devices: Sequence[DeviceProfile],
                 efficiency: float = 0.5,
                 train: bool = True) -> SplitPlan:
    """Exhaustive search over cut points (n_blocks choose n_stages-1).

    costs: per-block (flops_fwd, boundary_bytes).  Training multiplies block
    compute by 3 (fwd+bwd) and boundary traffic by 2 (activation + gradient).
    """
    n = len(costs)
    s = len(devices)
    assert 1 <= s <= n
    fmul = 3.0 if train else 1.0
    bmul = 2.0 if train else 1.0

    best: Optional[SplitPlan] = None
    for cuts in itertools.combinations(range(1, n), s - 1):
        bounds = (0,) + cuts + (n,)
        stage_t, comm_t = [], []
        for i in range(s):
            f = sum(c[0] for c in costs[bounds[i]:bounds[i + 1]]) * fmul
            stage_t.append(_stage_time(f, devices[i], efficiency))
            if i < s - 1:
                link = min(devices[i].link_bw, devices[i + 1].link_bw)
                comm_t.append(bmul * costs[bounds[i + 1] - 1][1] / link)
        tick = max(st + (comm_t[i] if i < s - 1 else 0.0)
                   for i, st in enumerate(stage_t))
        plan = SplitPlan(cuts, tuple(stage_t), tuple(comm_t), tick)
        if best is None or plan.bottleneck < best.bottleneck:
            best = plan
    return best


def pipeline_batch_seconds(plan: SplitPlan, n_micro: int) -> float:
    """Steady-state batch time: fill/drain + M ticks of the bottleneck."""
    ramp = sum(plan.stage_seconds) + sum(plan.comm_seconds) - plan.bottleneck
    return ramp + n_micro * plan.bottleneck


def single_device_seconds(costs: Sequence[Tuple[float, float]],
                          dev: DeviceProfile, n_micro: int,
                          efficiency: float = 0.5, train: bool = True) -> float:
    fmul = 3.0 if train else 1.0
    return n_micro * _stage_time(sum(c[0] for c in costs) * fmul, dev, efficiency)


# ---------------------------------------------------------------------------
# decode-mode split (serving; paper §4.3 memory wall + §4.1 topology)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeSplitPlan:
    """A serving split: where to cut, what each step costs, what fits where.

    Unlike :class:`SplitPlan` (training, microbatch-pipelined, bottleneck
    objective), decode steps of one continuous batch are strictly
    sequential — ``step_seconds`` is the SUM of stage compute plus every
    boundary-frame flight, i.e. the per-token latency of the split pair.
    """
    cuts: Tuple[int, ...]              # block index where each next stage starts
    stage_seconds: Tuple[float, ...]   # decode-step compute per stage
    comm_seconds: Tuple[float, ...]    # boundary frame flight after stage i
    stage_mem_bytes: Tuple[float, ...]
    fits: Tuple[bool, ...]             # stage_mem <= device.mem_bytes per stage

    @property
    def step_seconds(self) -> float:
        return sum(self.stage_seconds) + sum(self.comm_seconds)

    @property
    def feasible(self) -> bool:
        return all(self.fits)

    @property
    def steps_per_s(self) -> float:
        return 1.0 / self.step_seconds


def split_decode(costs: Sequence[Tuple[float, float, float]],
                 devices: Sequence[DeviceProfile],
                 stage_fixed_mem: Optional[Sequence[float]] = None
                 ) -> DecodeSplitPlan:
    """Exhaustive decode-mode cut search from serving rates + memory.

    costs: per-block ``(share, boundary_bytes, mem_bytes)`` —

    * ``share``: the block's fraction of a FULL-model decode step (shares
      sum to 1), so a stage holding shares ``s`` on a device rated
      ``decode_steps_per_s = r`` for the full model costs ``s / r``
      seconds per token;
    * ``boundary_bytes``: wire bytes of the activation frame crossing the
      link if the NEXT stage starts after this block (per decode step);
    * ``mem_bytes``: resident bytes the block pins on its stage (params +
      its KV/state share).

    ``stage_fixed_mem[i]`` adds per-stage constants (embedding table on
    stage 0, final-norm/head on the last, runtime overheads).

    Feasible plans (every stage within its device's ``mem_bytes``) win
    over infeasible ones; within a class the lowest per-token
    ``step_seconds`` wins — so when the model fits nowhere whole, the
    search trades link time for a cut that fits, and when memory is no
    object it degenerates to "no benefit from splitting" honestly (the
    unsplit latency is always <= any split's, which callers can check by
    passing one device).
    """
    n = len(costs)
    s = len(devices)
    assert 1 <= s <= n
    fixed = tuple(stage_fixed_mem) if stage_fixed_mem is not None \
        else (0.0,) * s
    if len(fixed) != s:
        raise ValueError(f"stage_fixed_mem has {len(fixed)} entries "
                         f"for {s} stages")

    best: Optional[DecodeSplitPlan] = None
    best_key = None
    for cuts in itertools.combinations(range(1, n), s - 1):
        bounds = (0,) + cuts + (n,)
        stage_t, comm_t, mem, fits = [], [], [], []
        for i in range(s):
            blocks = costs[bounds[i]:bounds[i + 1]]
            stage_t.append(sum(c[0] for c in blocks)
                           / devices[i].decode_rate())
            m = sum(c[2] for c in blocks) + fixed[i]
            mem.append(m)
            fits.append(m <= devices[i].mem_bytes)
            if i < s - 1:
                link = min(devices[i].link_bw, devices[i + 1].link_bw)
                comm_t.append(costs[bounds[i + 1] - 1][1] / link)
        plan = DecodeSplitPlan(cuts, tuple(stage_t), tuple(comm_t),
                               tuple(mem), tuple(fits))
        # feasible first; then fastest per-token step; then the spare
        # headroom tie-break (prefer the cut leaving the most slack)
        key = (not plan.feasible, plan.step_seconds,
               -min(devices[i].mem_bytes - mem[i] for i in range(s)))
        if best is None or key < best_key:
            best, best_key = plan, key
    return best


# ---------------------------------------------------------------------------
# homogeneous plan for the shard_map pipeline (TPU fleet)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    n_stages: int                     # S
    replicas: int                     # R = model_axis // S (extra DP inside model axis)
    layers_per_stage: int             # ceil(L / S)
    n_pad: int                        # no-op layer slots (masked at runtime)
    n_micro: int                      # M
    schedule: str                     # gpipe | hybrid

    @property
    def slots(self) -> int:
        return self.n_stages * self.layers_per_stage

    def ticks(self) -> int:
        m, s = self.n_micro, self.n_stages
        if self.schedule == "hybrid":
            # fused last-stage F+B: fwd stream M+S-1, bwd stream ends S-2 later
            return m + 2 * s - 2
        return 2 * (m + s - 1)

    def bubble_fraction(self) -> float:
        """Idle fraction of the schedule (work-units idle / total slots)."""
        m, s = self.n_micro, self.n_stages
        # fwd+bwd work per mb per stage = 3 units; GPipe & hybrid both idle
        # 3*(S-1) unit-slots at ramp-up+down (paper Fig.3: same total, spread)
        total = 3.0 * (m + s - 1) * s
        busy = 3.0 * m * s
        return 1.0 - busy / total


def plan_pipeline(n_layers: int, model_axis: int, n_micro: int = 0,
                  schedule: str = "hybrid",
                  candidates: Sequence[int] = (16, 8, 4, 2),
                  max_pad_frac: float = 0.2) -> PipelinePlan:
    """Choose S: prefer the LARGEST stage count whose padding waste stays
    under ``max_pad_frac`` — more stages = fewer layers (weights + Adam
    moments) per device, and HBM is the binding constraint before bubble
    fraction is (EXPERIMENTS §Perf records the bubble cost of this choice).
    Falls back to the minimum-padding S when none meets the threshold."""
    feasible = []
    for s in candidates:
        if s > model_axis or model_axis % s:
            continue
        lps = -(-n_layers // s)
        pad = s * lps - n_layers
        m = n_micro or max(2 * s, 4)
        feasible.append(PipelinePlan(s, model_axis // s, lps, pad, m, schedule))
    if not feasible:
        raise ValueError(f"no stage count from {candidates} divides model axis "
                         f"{model_axis}")
    under = [p for p in feasible
             if p.n_pad / max(n_layers, 1) <= max_pad_frac]
    if under:
        return max(under, key=lambda p: p.n_stages)
    return min(feasible, key=lambda p: (p.n_pad, -p.n_stages))
