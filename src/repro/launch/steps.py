"""Step builders: jit-able train/serve steps with full sharding annotations.

Each builder returns (fn, arg_specs, in_shardings, out_shardings, donate)
ready for ``jax.jit(...).lower(*arg_specs)`` — the dry-run consumes exactly
this; real runs call the same jitted function with concrete arrays.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import sharding as sh
from repro.core import strategy as strat
from repro.launch.mesh import data_axes, dp_degree
from repro.models.api import Model, build_model
from repro.optim import adamw


def _params_shape(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def _metrics_sharding(mesh):
    return NamedSharding(mesh, P())


def _logits_sharding(mesh, batch: int):
    dp = dp_degree(mesh)
    if batch % dp == 0:
        return NamedSharding(mesh, P(data_axes(mesh)))
    return NamedSharding(mesh, P())


def pick_grad_accum(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Bound per-microbatch tokens/device to ~8k (activation memory)."""
    dp = dp_degree(mesh)
    local_seqs = max(1, shape.global_batch // dp)
    tokens_dev = local_seqs * shape.seq_len
    target = max(1, tokens_dev // 8192)
    accum = 1
    for k in range(1, local_seqs + 1):
        if local_seqs % k == 0 and k <= target:
            accum = k
    return accum


# ---------------------------------------------------------------------------
# gspmd_tp / gspmd_pp train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig,
                    mesh, opt_cfg: adamw.AdamWConfig = None,
                    strategy: str = None):
    strategy = strategy or strat.resolve(cfg, shape, rcfg)
    if strategy == "pp_shardmap":
        from repro.core import pipeline as pp
        return pp.make_pp_train_step(cfg, shape, rcfg, mesh, opt_cfg)
    if strategy == "gspmd_pp":
        from repro.core import pipeline_gspmd as gpp
        return gpp.make_gspmd_pp_train_step(cfg, shape, rcfg, mesh, opt_cfg)
    return _make_tp_train_step(cfg, shape, rcfg, mesh, opt_cfg)


def _make_tp_train_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig,
                        mesh, opt_cfg=None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    model = build_model(cfg, rcfg)
    fsdp = rcfg.fsdp or strat.wants_fsdp(cfg, shape)
    accum = rcfg.grad_accum if rcfg.grad_accum > 1 else pick_grad_accum(cfg, shape, mesh)
    daxes = data_axes(mesh)
    if rcfg.seq_shard:
        from repro.core.sharding import set_activation_hints
        set_activation_hints(residual=NamedSharding(
            mesh, P(daxes, "model", None)))

    def constrain_batch(b):
        return jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, P(daxes))) if np.ndim(a) else a, b)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
                batch)

            def mb_step(carry, mb):
                gacc, lacc = carry
                mb = constrain_batch(mb)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(
                mb_step, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda a: a[-1], ms)
        new_params, new_opt, stats = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    # --- specs & shardings ---------------------------------------------------
    params_shape = _params_shape(model)
    opt_shape = jax.eval_shape(adamw.init, params_shape)
    batch_specs = model.input_specs(shape)

    p_shard = sh.param_shardings(params_shape, mesh, "gspmd_tp", fsdp=fsdp)
    # ZeRO-1: moments take fsdp-style sharding regardless (sharded over data)
    m_shard = sh.param_shardings(params_shape, mesh, "gspmd_tp",
                                 fsdp=rcfg.zero1 or fsdp)
    opt_shard = {"m": m_shard, "v": m_shard,
                 "step": NamedSharding(mesh, P())}
    b_shard = sh.batch_shardings(batch_specs, mesh)
    metrics_shape = jax.eval_shape(
        lambda p, o, b: train_step(p, o, b)[2], params_shape, opt_shape,
        batch_specs)
    out_shardings = (p_shard, opt_shard,
                     jax.tree.map(lambda _: _metrics_sharding(mesh), metrics_shape))
    return dict(
        fn=train_step,
        args=(params_shape, opt_shape, batch_specs),
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=out_shardings,
        donate_argnums=(0, 1),
        meta={"strategy": "gspmd_tp", "fsdp": fsdp, "accum": accum,
              "seq_shard": rcfg.seq_shard,
              "layers_multiplier": 1 if rcfg.unroll_layers else cfg.n_layers,
              "accum_multiplier": accum},
        model=model,
    )


# ---------------------------------------------------------------------------
# serve steps (prefill + decode) — gspmd_tp for every family
# ---------------------------------------------------------------------------

def _serve_fsdp(cfg: ModelConfig, mesh) -> bool:
    """Serving params: shard over "data" too when a model-axis-only shard
    would exceed ~4 GB/device (grok/llama4/yi/command-r)."""
    return cfg.total_params() * 2 / mesh.shape["model"] > 4e9


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig, mesh):
    model = build_model(cfg, rcfg)

    def prefill(params, batch):
        return model.prefill(params, batch, shape.seq_len)

    params_shape = _params_shape(model)
    batch_specs = model.input_specs(shape)
    p_shard = sh.param_shardings(params_shape, mesh, "gspmd_tp",
                                 fsdp=_serve_fsdp(cfg, mesh))
    b_shard = sh.batch_shardings(batch_specs, mesh)
    out_shape = jax.eval_shape(prefill, params_shape, batch_specs)
    logits_shard = _logits_sharding(mesh, shape.global_batch)
    cache_shard = sh.cache_shardings(out_shape[1], mesh, cfg)
    return dict(
        fn=prefill,
        args=(params_shape, batch_specs),
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(),
        meta={"strategy": "gspmd_tp",
              "layers_multiplier": 1 if rcfg.unroll_layers else cfg.n_layers},
        model=model,
    )


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig, mesh):
    model = build_model(cfg, rcfg)

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    params_shape = _params_shape(model)
    specs = model.input_specs(shape)
    cache_specs, token_specs = specs["cache"], specs["tokens"]
    p_shard = sh.param_shardings(params_shape, mesh, "gspmd_tp",
                                 fsdp=_serve_fsdp(cfg, mesh))
    c_shard = sh.cache_shardings(cache_specs, mesh, cfg)
    t_shard = sh.batch_shardings(token_specs, mesh)
    out_shape = jax.eval_shape(decode, params_shape, cache_specs, token_specs)
    logits_shard = _logits_sharding(mesh, shape.global_batch)
    return dict(
        fn=decode,
        args=(params_shape, cache_specs, token_specs),
        in_shardings=(p_shard, c_shard, t_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
        meta={"strategy": "gspmd_tp",
              "layers_multiplier": 1 if rcfg.unroll_layers else cfg.n_layers},
        model=model,
    )


def make_step(cfg: ModelConfig, shape: ShapeConfig, rcfg: RunConfig, mesh,
              strategy: str = None):
    """Dispatch on the shape kind: train_step / prefill / decode."""
    if shape.kind == "train":
        return make_train_step(cfg, shape, rcfg, mesh, strategy=strategy)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, rcfg, mesh)
    return make_decode_step(cfg, shape, rcfg, mesh)
