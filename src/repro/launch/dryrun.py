import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory / cost / collective artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>[__<strategy>].json
and feed EXPERIMENTS.md §Dry-run / §Roofline via benchmarks/roofline_report.py.

NOTE the XLA_FLAGS line above MUST precede any jax import (device count locks
on first backend init) — that is why it is the first statement of the module.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (ARCH_IDS, SHAPES, RunConfig, get_config, get_shape,
                           shape_applicable)
from repro.core import strategy as strat
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# archs whose layer loop we unroll for exact HLO accounting (dense/moe attn
# models; SSM/hybrid inner time-scans can't unroll — they use the analytic
# column of the roofline instead: see DESIGN.md §9)
UNROLL_OK = {"granite-8b", "mistral-nemo-12b", "yi-34b", "command-r-35b",
             "whisper-small", "internvl2-1b", "llama4-scout-17b-a16e",
             "grok-1-314b"}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             strategy: str = None, unroll: bool = None,
             seq_shard: bool = False,
             out_dir: Path = ART_DIR, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        cell.update(status="skip", reason=why)
        _save(cell, out_dir)
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    if unroll is None:
        unroll = arch in UNROLL_OK
    rcfg = RunConfig(unroll_layers=unroll, seq_shard=seq_shard)
    t0 = time.time()
    try:
        built = make_step(cfg, shape, rcfg, mesh, strategy=strategy)
        cell["strategy"] = built["meta"]["strategy"]
        with mesh:
            # repro-lint: allow[R001] dry-run measures compile cost; one fresh program per cell is the point
            jitted = jax.jit(built["fn"],
                             in_shardings=built["in_shardings"],
                             out_shardings=built["out_shardings"],
                             donate_argnums=built["donate_argnums"])
            lowered = jitted.lower(*built["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        from repro.analysis.hlo import parse_collectives
        colls = parse_collectives(hlo, n_devices=mesh.size)
        cell.update(
            status="ok",
            unrolled=unroll,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_per_device": ma.argument_size_in_bytes
                    + ma.temp_size_in_bytes + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes,
            },
            cost={
                "flops_per_device": ca.get("flops", 0.0),
                "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
            },
            collectives={
                "wire_bytes_per_device": colls.wire_bytes,
                "payload_bytes_per_device": colls.payload_bytes,
                "by_kind": {k: {"count": c, "wire_bytes": b}
                            for k, (c, b) in colls.by_kind().items()},
                "n_ops": len(colls.ops),
            },
            meta=built["meta"],
            hlo_bytes=len(hlo),
        )
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name} "
                  f"({cell['strategy']}): COMPILED in {t_compile:.0f}s — "
                  f"peak/dev {cell['memory']['peak_bytes_per_device']/1e9:.2f} GB, "
                  f"{ca.get('flops', 0)/1e9:.1f} GFLOP/dev, "
                  f"{colls.wire_bytes/1e6:.1f} MB wire/dev "
                  f"({len(colls.ops)} collective ops)")
            print("  memory_analysis:", ma)
            ck = {k: v for k, v in ca.items() if "flops" in k or k == "bytes accessed"}
            print("  cost_analysis:", ck)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: FAILED — {e}")
    _save(cell, out_dir)
    return cell


def _save(cell: dict, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    parts = [cell["arch"], cell["shape"], cell["mesh"]]
    if cell.get("strategy"):
        parts.append(cell["strategy"])
    if cell.get("meta", {}).get("seq_shard"):
        parts.append("seqshard")
    path = out_dir / ("__".join(parts) + ".json")
    path.write_text(json.dumps(cell, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--strategy", default=None,
                    choices=[None, "pp_shardmap", "gspmd_tp", "gspmd_pp"])
    ap.add_argument("--unroll", type=int, default=-1,
                    help="-1 auto, 0 off, 1 on")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    unroll = None if args.unroll < 0 else bool(args.unroll)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                cell = run_cell(arch, shp, mp, strategy=args.strategy,
                                unroll=unroll, seq_shard=args.seq_shard)
                st = cell["status"]
                n_ok += st == "ok"
                n_fail += st == "error"
                n_skip += st == "skip"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
