"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --steps 200 --reduced --schedule hybrid [--strategy pp_shardmap]

``--reduced`` runs the smoke-sized config on local devices (CPU-feasible);
full configs target the production mesh (real fleet or the dry-run).
Fault tolerance: checkpoints every --ckpt-every, auto-resume from --ckpt-dir.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, RunConfig, ShapeConfig, get_config,
                           reduced_config)
from repro.data.synthetic import DataConfig, FrontendPipeline, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--layers", type=int, default=0,
                    help="override n_layers on the reduced config")
    ap.add_argument("--schedule", choices=["gpipe", "hybrid"], default="hybrid")
    ap.add_argument("--strategy", default="single",
                    choices=["single", "pp_shardmap", "gspmd_tp", "gspmd_pp"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False, schedule=args.schedule,
                     use_kernels=args.use_kernels)
    model = build_model(cfg, rcfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps, weight_decay=0.01)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.key(0)))))
    print(f"[train] {cfg.arch_id}: {n_params/1e6:.2f}M params, "
          f"strategy={args.strategy}")

    if args.strategy == "single":
        # repro-lint: allow[R001] launcher entry point: one training program per process run, nothing to share
        @jax.jit
        def step_fn(params, opt, batch):
            (loss, m), g = jax.value_and_grad(
                lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
            p2, o2, st = adamw.update(opt_cfg, g, opt, params)
            return p2, o2, dict(loss=loss, **st)

        def init_state():
            p = model.init(jax.random.key(0))
            return p, adamw.init(p)
    else:
        from repro.launch.mesh import make_host_mesh
        from repro.launch.steps import make_train_step
        mesh = make_host_mesh()
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        built = make_train_step(cfg, shape, rcfg, mesh, opt_cfg,
                                strategy=args.strategy)
        # repro-lint: allow[R001] launcher entry point: one training program per process run, nothing to share
        jitted = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                         out_shardings=built["out_shardings"])

        def step_fn(params, opt, batch):
            return jitted(params, opt, batch)

        def init_state():
            p = model.init(jax.random.key(0))
            if "to_pipeline" in built:
                p = built["to_pipeline"](p)
            return p, adamw.init(p)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=rcfg.seed)
    if cfg.frontend and cfg.family != "audio":
        pipe = FrontendPipeline(dcfg, cfg.frontend_seq, cfg.d_model)
    elif cfg.family == "audio":
        pipe = FrontendPipeline(dcfg, cfg.frontend_seq, cfg.d_model,
                                key="frames")
    else:
        pipe = TokenPipeline(dcfg)

    def data_iter(start):
        def gen():
            s = start
            while True:
                b = pipe.batch(s)
                yield {k: jnp.asarray(v) for k, v in b.items()}
                s += 1
        return iter(gen())

    tr = Trainer(TrainerConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir, log_every=10),
                 step_fn, init_state, data_iter)
    out = tr.run()
    losses = out["losses"]
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {len(losses)} steps")
    return out


if __name__ == "__main__":
    main()
