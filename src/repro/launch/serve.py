"""Serving launcher: continuous-batching engine + optional async tools.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
        --requests 8 --max-new 16 [--tools]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, RunConfig, get_config, reduced_config
from repro.models.api import build_model
from repro.offload.tools import ToolExecutor
from repro.offload.vectordb import VectorDB
from repro.serving.engine import ServeEngine
from repro.serving.tool_loop import run_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--tools", action="store_true",
                    help="run the paper's §4.3 agent scenario instead")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    rcfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, args.max_batch, args.max_len)

    if args.tools:
        db = VectorDB(n_docs=20_000, dim=128)
        ex = ToolExecutor(n_workers=3)
        ex.register("vector_db_begin_search",
                    lambda query, k: db.search_text(query, int(k)),
                    simulated_seconds=0.5)
        tr = run_scenario(engine, ex,
                          ["google search engine", "apple ipod",
                           "microsoft windows"], async_tools=True)
        print(f"[serve] agent scenario: total {tr.total:.2f}s, "
              f"tool_wait {tr.time_in('tool_wait'):.2f}s "
              f"(tools ran fully overlapped)")
        for seg in tr.timeline():
            print(f"  {seg['kind']:10s} {seg['start']:6.2f}-{seg['end']:6.2f}s"
                  f" {seg['label']}")
        return

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size, size=8 + i % 5),
                      max_new=args.max_new)
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {engine.steps} engine steps, "
          f"{args.max_batch} lanes)")
    for r in done[:3]:
        ttft = (r.first_token_t - r.submitted_t) * 1e3
        print(f"  req{r.rid}: ttft={ttft:.0f}ms tokens={r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
