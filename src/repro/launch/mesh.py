"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Single-pod: (16, 16) ("data", "model") = 256 chips.
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips — the "pod"
axis carries only gradient all-reduce / batch split (slowest links, least
traffic; DESIGN §3).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def compat_make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax; older releases use
    plain Auto axes implicitly."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = None,
                   axes: Tuple[str, ...] = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples).

    Defaults to putting all local devices on "model" (1×N)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
        axes = ("data", "model")
    return compat_make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_degree(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
