"""Split-tool async offload (paper §3.6/§4.3).

The paper splits a tool into two interfaces — ``begin_*`` starts the call on
the iOS worker, ``retrieve_*`` returns the oldest not-yet-retrieved result
(FIFO) — so the LRM keeps reasoning while tools run.  Here the "iOS worker"
is an offload executor (thread pool standing in for the device; requests and
results cross the boundary through the wire codec, same as the paper's TCP
socket), and the FIFO semantics are exactly the paper's.
"""

from __future__ import annotations

import collections
import dataclasses
import io
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Deque, Dict, List, Optional

import numpy as np

from repro.wire import codec


@dataclasses.dataclass
class ToolEvent:
    name: str
    begin_t: float
    end_t: Optional[float] = None
    retrieved_t: Optional[float] = None

    @property
    def run_seconds(self) -> float:
        return (self.end_t or time.perf_counter()) - self.begin_t


class ToolExecutor:
    """FIFO begin/retrieve tool offload onto a worker pool.

    ``register(name, fn, simulated_seconds=...)`` — the simulated delay is the
    paper's Task.sleep trick (§3.6: the real search took ~10 ms, inflated to
    5 s for visibility).
    """

    def __init__(self, n_workers: int = 2, wire: bool = True):
        self.pool = ThreadPoolExecutor(max_workers=n_workers,
                                       thread_name_prefix="offload")
        self.tools: Dict[str, Callable] = {}
        self.delays: Dict[str, float] = {}
        self.fifo: Deque[Future] = collections.deque()
        self.events: List[ToolEvent] = []
        self.wire = wire
        self._lock = threading.Lock()

    def register(self, name: str, fn: Callable, simulated_seconds: float = 0.0):
        self.tools[name] = fn
        self.delays[name] = simulated_seconds

    # -- the two interfaces the LRM sees (paper A.3) -----------------------
    def begin(self, name: str, **kwargs) -> ToolEvent:
        """vector_db_begin_search-style: enqueue, return immediately."""
        fn = self.tools[name]
        delay = self.delays[name]
        ev = ToolEvent(name=name, begin_t=time.perf_counter())

        payload = codec.dumps({k: np.asarray(v) for k, v in kwargs.items()
                               if isinstance(v, (np.ndarray, int, float))}) \
            if self.wire else None

        def work():
            kw = kwargs
            if payload is not None:
                decoded = codec.loads(payload)       # worker-side decode
                kw = {**kwargs, **{k: decoded[k] for k in decoded}}
            out = fn(**kw)
            if delay:
                time.sleep(delay)                    # paper's Task.sleep
            ev.end_t = time.perf_counter()
            return codec.dumps({"result": np.asarray(out)}) if (
                self.wire and isinstance(out, np.ndarray)) else out

        fut = self.pool.submit(work)
        with self._lock:
            self.fifo.append(fut)
            self.events.append(ev)
        return ev

    def retrieve(self, timeout: Optional[float] = None) -> Any:
        """vector_db_retrieve_search_result: oldest not-yet-retrieved (FIFO)."""
        with self._lock:
            if not self.fifo:
                raise LookupError("no pending tool call (FIFO empty)")
            fut = self.fifo.popleft()
        out = fut.result(timeout=timeout)
        for ev in self.events:                      # mark earliest unretrieved
            if ev.retrieved_t is None and ev.end_t is not None:
                ev.retrieved_t = time.perf_counter()
                break
        if self.wire and isinstance(out, bytes):
            out = codec.loads(out)["result"]
        return out

    def pending(self) -> int:
        with self._lock:
            return len(self.fifo)

    def shutdown(self):
        self.pool.shutdown(wait=False, cancel_futures=True)
