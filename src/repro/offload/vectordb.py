"""Mock vector database (paper §3.6): dot-product top-k over a synthetic
document embedding matrix (stand-in for the 100k AG-News × all-MiniLM-L6-v2
corpus the paper used — offline container, DESIGN §8.6)."""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


class VectorDB:
    def __init__(self, n_docs: int = 100_000, dim: int = 384, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.embeddings = rng.standard_normal((n_docs, dim)).astype(np.float32)
        self.embeddings /= np.linalg.norm(self.embeddings, axis=1, keepdims=True)
        self.dim = dim

    def encode(self, query: str) -> np.ndarray:
        """Deterministic mock text encoder."""
        rng = np.random.default_rng(abs(hash(query)) % (2 ** 32))
        v = rng.standard_normal(self.dim).astype(np.float32)
        return v / np.linalg.norm(v)

    def search(self, query_vec: np.ndarray, k: int = 5) -> np.ndarray:
        """Returns (k, 2) array of [doc_id, score] — the paper's tool output."""
        scores = self.embeddings @ np.asarray(query_vec, np.float32).ravel()
        idx = np.argpartition(scores, -k)[-k:]
        idx = idx[np.argsort(-scores[idx])]
        return np.stack([idx.astype(np.float32), scores[idx]], axis=1)

    def search_text(self, query: str, k: int = 5) -> np.ndarray:
        return self.search(self.encode(query), k)
