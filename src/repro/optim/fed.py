"""Federated aggregation: sample-weighted fed-avg over wire-framed updates.

The training plane (:mod:`repro.serving.train_plane`) ships each
participant's local model delta as ONE fed frame per round.  This module
owns both ends of that exchange:

* **Frame codec** — a magic-byte-versioned envelope around the repo's
  tensor wire protocol (:mod:`repro.wire.codec`).  Two modes:

  - ``int8_ef`` — the delta runs through :mod:`repro.optim.compress`
    (per-leaf absmax int8 + error feedback), the ``{"q", "s"}`` pytree is
    wire-framed, and the whole tensor stream is DEFLATE-compressed.
    Quantised gradient deltas are heavy-tailed (most entries sit in a few
    low int8 bins), so entropy coding stacks a further ~2x on int8's 2x —
    that is where the bench's >= 3x-vs-bf16 wire cut comes from.
  - ``bf16`` — the uncompressed baseline: the delta cast to bfloat16 and
    wire-framed raw (the "bf16 all-reduce" yardstick the A/B measures
    against).

  Frame layout (little-endian)::

      u8[4]  magic     b"FEDR"
      u8     version   1
      u8     mode      1 = int8_ef (DEFLATE payload), 2 = bf16 (raw)
      u32    raw_len   decompressed payload length (mode 1; 0 for mode 2)
      u8[]   payload   wire-codec pytree stream (per-tensor magic + CRC)

* **Aggregation** — :func:`fed_avg` applies sample-weighted averaging in
  FIXED sorted-participant-name order, so the reduction is bit-
  deterministic regardless of the sim-time order deliveries landed in
  (two seeded replays must produce the identical aggregated tree).
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compress
from repro.wire import codec

FED_MAGIC = b"FEDR"
FED_VERSION = 1
MODE_INT8_EF = 1
MODE_BF16 = 2
_HDR = struct.Struct("<4sBBI")   # magic, version, mode, raw_len


class FedWireError(ValueError):
    pass


def _np_bf16():
    import ml_dtypes  # ships with jax

    return np.dtype(ml_dtypes.bfloat16)


def encode_update(delta: Any, *, mode: str = "int8_ef",
                  error: Optional[Any] = None,
                  topk_frac: Optional[float] = 0.5) -> Tuple[bytes, Any]:
    """Encode one participant's model delta as a fed frame.

    Returns ``(frame_bytes, new_error_tree)``.  ``error`` is the
    participant's persistent error-feedback state (``int8_ef`` mode only;
    pass the previous round's return value, or None for round zero).
    ``topk_frac`` sparsifies the int8 stream (error feedback carries the
    dropped mass — see :func:`repro.optim.compress.compress_tree`); the
    default keeps the top half, which is what pushes the DEFLATEd frame
    past the bench's >= 3x-vs-bf16 wire gate.  ``bf16`` mode carries no
    residual — ``new_error`` is returned unchanged so callers can thread
    one code path."""
    if mode == "int8_ef":
        if error is None:
            error = compress.init_error(delta)
        q, s, new_error = compress.compress_tree(delta, error,
                                                 topk_frac=topk_frac)
        raw = codec.dumps({"q": q, "s": s})
        payload = zlib.compress(raw, 6)
        frame = _HDR.pack(FED_MAGIC, FED_VERSION, MODE_INT8_EF,
                          len(raw)) + payload
        return frame, new_error
    if mode == "bf16":
        bf16 = _np_bf16()
        tree = jax.tree.map(lambda x: np.asarray(x).astype(bf16), delta)
        payload = codec.dumps(tree)
        frame = _HDR.pack(FED_MAGIC, FED_VERSION, MODE_BF16, 0) + payload
        return frame, error
    raise ValueError(f"unknown fed frame mode {mode!r}")


def decode_update(frame: bytes) -> Any:
    """Decode a fed frame back to a float32 delta tree (the coordinator
    aggregates what was actually DELIVERED over the wire — dequantised
    int8 or bf16-rounded values, never the sender's exact floats)."""
    if len(frame) < _HDR.size:
        raise FedWireError(f"fed frame truncated at {len(frame)} bytes")
    magic, version, mode, raw_len = _HDR.unpack_from(frame)
    if magic != FED_MAGIC:
        raise FedWireError(f"bad fed magic {magic!r}")
    if version != FED_VERSION:
        raise FedWireError(f"unsupported fed frame version {version}")
    payload = frame[_HDR.size:]
    if mode == MODE_INT8_EF:
        raw = zlib.decompress(payload)
        if len(raw) != raw_len:
            raise FedWireError(f"fed payload length {len(raw)} != header "
                               f"raw_len {raw_len}")
        tree = codec.loads(raw)
        return compress.decompress_tree(tree["q"], tree["s"])
    if mode == MODE_BF16:
        tree = codec.loads(payload)
        return jax.tree.map(lambda x: jnp.asarray(np.asarray(x),
                                                  jnp.float32), tree)
    raise FedWireError(f"unknown fed frame mode {mode}")


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One delivered participant contribution: who, how many samples the
    delta was computed from, and the frame exactly as it crossed the
    link."""
    name: str
    samples: int
    frame: bytes


def fed_avg(updates: Sequence[ClientUpdate]) -> Any:
    """Sample-weighted average of the DELIVERED deltas, reduced in fixed
    sorted-name order (bit-deterministic: delivery order is sim-schedule
    dependent, the reduction must not be).  Returns None when nothing was
    delivered — a fully-failed round applies no update."""
    ups = sorted(updates, key=lambda u: u.name)
    if not ups:
        return None
    names = [u.name for u in ups]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate participant names in round: {names}")
    total = float(sum(u.samples for u in ups))
    if total <= 0:
        raise ValueError("fed_avg needs a positive total sample count")
    acc = None
    for u in ups:
        delta = decode_update(u.frame)
        w = jnp.float32(u.samples / total)
        scaled = jax.tree.map(lambda d: d.astype(jnp.float32) * w, delta)
        acc = scaled if acc is None else jax.tree.map(
            lambda a, b: a + b, acc, scaled)
    return acc


def apply_update(params: Any, avg_delta: Any) -> Any:
    """``params + avg_delta`` leaf-wise (cast back to each leaf's dtype)."""
    if avg_delta is None:
        return params
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        params, avg_delta)


def tree_delta(new: Any, old: Any) -> Any:
    """``new - old`` leaf-wise in float32 (the per-round local delta)."""
    return jax.tree.map(
        lambda n, o: n.astype(jnp.float32) - o.astype(jnp.float32),
        new, old)


def frame_sizes(delta: Any) -> Tuple[int, int]:
    """(int8_ef bytes, bf16 bytes) for one encoding of ``delta`` — the
    wire A/B the bench reports without shipping anything."""
    f_int8, _ = encode_update(delta, mode="int8_ef")
    f_bf16, _ = encode_update(delta, mode="bf16")
    return len(f_int8), len(f_bf16)


__all__: List[str] = [
    "FED_MAGIC", "FED_VERSION", "MODE_INT8_EF", "MODE_BF16", "FedWireError",
    "ClientUpdate", "encode_update", "decode_update", "fed_avg",
    "apply_update", "tree_delta", "frame_sizes",
]
