"""AdamW with bf16 params + fp32 moments, global-norm clipping, schedules.

ZeRO-1 comes from SHARDING, not code: moment trees get fsdp-style shardings
(see ``repro.core.sharding``), so XLA reduce-scatters gradients into the
moment shards and all-gathers updated params — the standard GSPMD encoding
of sharded optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # cosine | linear | const


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "const":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return cfg.lr * warm * decay


def init(params: Any) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads: Any, opt_state: Dict[str, Any],
           params: Any) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:          # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step + 1}, \
        {"grad_norm": gnorm, "lr": lr}
