"""int8 gradient compression with error feedback — for the slow cross-pod
links (DESIGN §3: the "pod" axis carries one gradient sync per step; int8
quarters its wire vs bf16 all-reduce).

Scheme: per-leaf absmax scaling to int8; the quantisation residual is FED
BACK into the next step's gradient (error feedback — Karimireddy et al.
2019 — restores convergence of biased compressors).  The codec is pure-jnp
(jit-able inside the train step); integration point is the pod-axis sync in
the pipeline/DP paths: quantise -> exchange int8+scale -> dequantise+mean.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 values, fp32 scale).  scale = absmax/127 (0-safe).

    Non-finite entries (NaN / ±inf — a diverged or overflowed step) are
    treated as zero: one bad entry must not blow the absmax scale to inf
    (which would quantise every OTHER entry to 0 and poison the error-
    feedback residual with NaN forever after)."""
    g32 = g.astype(jnp.float32)
    g32 = jnp.where(jnp.isfinite(g32), g32, 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Any, error: Any,
                  topk_frac: Optional[float] = None) -> Tuple[Any, Any, Any]:
    """Quantise (grads + error-feedback); returns (q_tree, scale_tree,
    new_error_tree).  new_error = (g + e) - deq(q).

    With ``topk_frac`` in (0, 1], only the top-k largest-magnitude entries
    per leaf survive quantisation; the rest are zeroed BEFORE the residual
    is taken, so error feedback carries the dropped mass into the next
    round (sparsified SGD with memory — Stich et al. 2018).  The zeros
    make the int8 stream highly entropy-codable on the wire.

    The residual is computed from the SANITISED corrected gradient (non-
    finite entries zeroed, matching :func:`quantize`): error feedback must
    carry quantisation error forward, never NaN/inf — a single diverged
    step would otherwise contaminate every future round through ``e``."""
    if topk_frac is not None and not 0.0 < topk_frac <= 1.0:
        raise ValueError(f"topk_frac must be in (0, 1], got {topk_frac}")

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        corrected = jnp.where(jnp.isfinite(corrected), corrected, 0.0)
        kept = corrected
        if topk_frac is not None and topk_frac < 1.0:
            flat = jnp.abs(corrected).ravel()
            k = max(1, int(round(topk_frac * flat.size)))
            thr = jax.lax.top_k(flat, k)[0][-1]
            kept = jnp.where(jnp.abs(corrected) >= thr, corrected, 0.0)
        q, s = quantize(kept)
        new_e = corrected - dequantize(q, s)
        return q, s, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    qs = treedef.unflatten([o[0] for o in out])
    ss = treedef.unflatten([o[1] for o in out])
    es = treedef.unflatten([o[2] for o in out])
    return qs, ss, es


def decompress_tree(qs: Any, ss: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda q, s: dequantize(q, s, dtype), qs, ss)


def init_error(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def wire_bytes_saved(grads: Any) -> Tuple[int, int]:
    """(bf16 bytes, int8+scale bytes) for the synced tree — the 'pod' link
    saving this codec buys (reported by bench_wire)."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    leaves = len(jax.tree.leaves(grads))
    return 2 * n, n + 4 * leaves
