"""Serving metrics: per-request latency accounting + engine-level counters.

Definitions (standard serving vocabulary):

* **TTFT** — time to first token: ``first_token_t - submitted_t`` (includes
  queueing delay, which is the whole point of measuring it per policy).
* **TPOT** — time per output token after the first:
  ``(done_t - first_token_t) / (n_tokens - 1)``.
* **tokens/s** — generated tokens over the engine's active wall-clock.
* **queue depth / slot utilisation** — step-weighted means sampled once per
  engine step, i.e. what the engine actually saw while running.

``MetricsCollector`` is pure bookkeeping (no jax); the engine feeds it
events and asks for a :class:`EngineSnapshot` — a frozen, structured view
suitable for logging, benches, and assertions in tests.

SLO accounting (fleet/scale plane) lives here too: :class:`SLOClass`
declares a traffic class's TTFT/TPOT targets, :func:`slo_report` folds
per-request outcomes into an :class:`SLOReport` with per-class p50/p99
latencies and **attainment** — the fraction of *offered* requests that
completed within their class targets.  Requests the system never served
(admission-shed, capacity-rejected, deadline-expired) count as misses:
shedding load keeps served latency pretty, but attainment is measured
against everything the users asked for.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    count: int
    mean: float
    p50: float
    p95: float
    max: float

    @classmethod
    def of(cls, xs: List[float]) -> "LatencyStats":
        return cls(count=len(xs), mean=_mean(xs),
                   p50=_percentile(xs, 0.50), p95=_percentile(xs, 0.95),
                   max=max(xs) if xs else float("nan"))


@dataclasses.dataclass(frozen=True)
class EngineSnapshot:
    """One structured reading of the engine's counters; see module docstring
    for the latency definitions."""
    completed: int
    rejected: int
    expired: int
    steps: int
    generated_tokens: int
    wall_s: float
    tokens_per_s: float
    ttft: LatencyStats
    tpot: LatencyStats
    queue_wait: LatencyStats
    queue_depth_mean: float
    queue_depth_now: int
    slot_utilization: float            # mean fraction of busy lanes per step
    busy_lanes_mean: float             # sustained concurrency (lanes/step)
    prefill_dispatches: int
    prefill_requests: int
    prefill_batch_mean: float          # requests amortised per dispatch
    prefill_tokens: int                # padded tokens actually prefilled
    # paged-KV accounting (all zero on a dense-layout engine)
    preemptions: int                   # lanes evicted on block exhaustion
    resumes: int                       # preempted requests re-admitted
    kv_blocks_total: int
    kv_blocks_peak: int                # high-watermark blocks in use
    kv_block_utilization: float        # step-weighted mean in_use fraction
    # prefix-cache accounting (zero unless EngineConfig.prefix_cache)
    prefix_lookups: int                # admissions that queried the cache
    prefix_hit_tokens: int             # context tokens served from cache
    prefix_query_tokens: int           # context tokens looked up
    prefix_hit_rate: float             # token-weighted hits / lookups
    prefix_hit_series: Tuple[float, ...]   # per-admission hit fraction
    prefill_skipped: int               # fully-cached prompts: no prefill
    cow_splits: int                    # shared blocks privatised on write
    kv_shared_blocks_peak: int         # high-watermark refcount>=2 blocks
    cache_evictions: int               # cached free blocks reclaimed
    # speculative-decoding accounting (zero on non-speculative engines)
    spec_rounds: int = 0               # draft->verify rounds run
    spec_drafted_tokens: int = 0       # draft proposals shipped to verify
    spec_accepted_tokens: int = 0      # proposals the target agreed with
    spec_acceptance_rate: float = 0.0  # accepted / drafted (token-weighted)
    spec_accepted_series: Tuple[int, ...] = ()  # accepted count per round

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# SLO accounting (fleet / scale plane)
# ---------------------------------------------------------------------------
# terminal request outcomes, as used by slo_report's ``outcome`` array
OUTCOME_DONE = 0        # completed: latencies are valid
OUTCOME_SHED = 1        # admission controller rejected at submit (predicted miss)
OUTCOME_REJECTED = 2    # capacity reject: every eligible queue was full
OUTCOME_EXPIRED = 3     # deadline passed while still queued


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One traffic class's service-level objective (targets in seconds).
    ``ttft_s`` also feeds predicted-TTFT admission control when a request
    carries no explicit deadline; ``inf`` disables a bound."""
    name: str
    ttft_s: float = float("inf")
    tpot_s: float = float("inf")


class _NanEq:
    """Field-wise equality that treats NaN == NaN as true.  SLO reports
    carry NaN for undefined stats (percentiles of an empty class, served
    attainment with zero completions); determinism tests compare whole
    snapshots, and two bit-identical runs must compare equal even where a
    stat is undefined."""

    @staticmethod
    def _eq(a, b) -> bool:
        if isinstance(a, tuple) and isinstance(b, tuple):
            return (len(a) == len(b)
                    and all(_NanEq._eq(x, y) for x, y in zip(a, b)))
        return bool(a == b) or (a != a and b != b)

    def __eq__(self, other):
        if other.__class__ is not self.__class__:
            return NotImplemented
        return self._eq(dataclasses.astuple(self), dataclasses.astuple(other))


@dataclasses.dataclass(frozen=True, eq=False)
class ClassSLOReport(_NanEq):
    """SLO outcome for one traffic class.  ``attainment`` is met/offered
    (unserved requests are misses); ``served_attainment`` is met/completed
    (how the served ones fared)."""
    name: str
    offered: int
    completed: int
    shed: int
    rejected: int
    expired: int
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    met: int
    attainment: float
    served_attainment: float


@dataclasses.dataclass(frozen=True, eq=False)
class SLOReport(_NanEq):
    """Fleet-wide SLO rollup: per-class reports + offered-weighted totals.
    ``goodput_tokens_per_s`` counts only tokens of SLO-met requests — the
    throughput users actually experienced within target."""
    classes: Tuple[ClassSLOReport, ...]
    offered: int
    completed: int
    shed: int
    rejected: int
    expired: int
    met: int
    attainment: float
    served_attainment: float
    goodput_tokens_per_s: float
    tokens_per_s: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def slo_report(specs: Sequence[SLOClass], class_ids: Sequence[int],
               ttft_s: Sequence[float], tpot_s: Sequence[float],
               tokens: Sequence[int], outcome: Sequence[int],
               span_s: float) -> SLOReport:
    """Fold per-request outcomes into an :class:`SLOReport`.

    Parallel arrays, one entry per *offered* request: its class id, TTFT
    and TPOT in seconds (ignored unless ``outcome == OUTCOME_DONE``; TPOT
    may be NaN for single-token requests and then counts as met), generated
    tokens, and terminal outcome (``OUTCOME_*``).  ``span_s`` is the span
    the token rates are normalised over (sim or wall seconds).
    """
    n = len(class_ids)
    reports: List[ClassSLOReport] = []
    tot_met = tot_done = tot_shed = tot_rej = tot_exp = 0
    good_tokens = all_tokens = 0
    for cid, spec in enumerate(specs):
        idx = [i for i in range(n) if class_ids[i] == cid]
        done = [i for i in idx if outcome[i] == OUTCOME_DONE]
        shed = sum(1 for i in idx if outcome[i] == OUTCOME_SHED)
        rej = sum(1 for i in idx if outcome[i] == OUTCOME_REJECTED)
        exp = sum(1 for i in idx if outcome[i] == OUTCOME_EXPIRED)
        ttfts = [float(ttft_s[i]) for i in done]
        tpots = [float(tpot_s[i]) for i in done
                 if tpot_s[i] == tpot_s[i]]          # drop NaN (n_tokens == 1)
        met = 0
        for i in done:
            ok_ttft = float(ttft_s[i]) <= spec.ttft_s
            tp = float(tpot_s[i])
            ok_tpot = (tp != tp) or tp <= spec.tpot_s
            if ok_ttft and ok_tpot:
                met += 1
                good_tokens += int(tokens[i])
            all_tokens += int(tokens[i])
        offered = len(idx)
        reports.append(ClassSLOReport(
            name=spec.name, offered=offered, completed=len(done),
            shed=shed, rejected=rej, expired=exp,
            ttft_p50=_percentile(ttfts, 0.50), ttft_p99=_percentile(ttfts, 0.99),
            tpot_p50=_percentile(tpots, 0.50), tpot_p99=_percentile(tpots, 0.99),
            met=met,
            attainment=met / offered if offered else float("nan"),
            served_attainment=met / len(done) if done else float("nan")))
        tot_met += met
        tot_done += len(done)
        tot_shed += shed
        tot_rej += rej
        tot_exp += exp
    offered = sum(r.offered for r in reports)
    return SLOReport(
        classes=tuple(reports), offered=offered, completed=tot_done,
        shed=tot_shed, rejected=tot_rej, expired=tot_exp, met=tot_met,
        attainment=tot_met / offered if offered else float("nan"),
        served_attainment=tot_met / tot_done if tot_done else float("nan"),
        goodput_tokens_per_s=good_tokens / span_s if span_s > 0 else 0.0,
        tokens_per_s=all_tokens / span_s if span_s > 0 else 0.0)


class MetricsCollector:
    def __init__(self, n_slots: int, n_blocks: int = 0):
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.queue_wait: List[float] = []
        self.completed = 0
        self.generated_tokens = 0
        self.steps = 0
        self._depth_sum = 0
        self._busy_sum = 0
        self._blocks_sum = 0
        self.preemptions = 0
        self.resumes = 0
        self.prefill_dispatches = 0
        self.prefill_requests = 0
        self.prefill_tokens = 0
        self.prefix_lookups = 0
        self.prefix_hit_tokens = 0
        self.prefix_query_tokens = 0
        self.prefix_hit_series: List[float] = []
        self.prefill_skipped = 0
        self.spec_rounds = 0
        self.spec_drafted_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_accepted_series: List[int] = []
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------------
    def on_prefill(self, n_requests: int, n_tokens: int = 0) -> None:
        self.prefill_dispatches += 1
        self.prefill_requests += n_requests
        self.prefill_tokens += n_tokens

    def on_admit(self, req, now: float) -> None:
        self.queue_wait.append(now - req.submitted_t)
        if self._t_first is None:
            self._t_first = now

    def on_preempt(self, req) -> None:
        self.preemptions += 1

    def on_prefix_lookup(self, hit_tokens: int, query_tokens: int) -> None:
        self.prefix_lookups += 1
        self.prefix_hit_tokens += hit_tokens
        self.prefix_query_tokens += query_tokens
        self.prefix_hit_series.append(
            hit_tokens / query_tokens if query_tokens else 0.0)

    def on_prefill_skip(self) -> None:
        self.prefill_skipped += 1

    def on_spec_round(self, drafted: int, accepted: int) -> None:
        """One speculative round: ``drafted`` proposals were verified,
        ``accepted`` of them matched the target's own samples."""
        self.spec_rounds += 1
        self.spec_drafted_tokens += drafted
        self.spec_accepted_tokens += accepted
        self.spec_accepted_series.append(accepted)

    def on_resume(self, req, now: float) -> None:
        self.resumes += 1
        if self._t_first is None:
            self._t_first = now

    def on_step(self, queue_depth: int, busy_slots: int, now: float,
                blocks_in_use: int = 0) -> None:
        self.steps += 1
        self._depth_sum += queue_depth
        self._busy_sum += busy_slots
        self._blocks_sum += blocks_in_use
        self._t_last = now

    def on_finish(self, req, now: float) -> None:
        self.completed += 1
        n = len(req.out_tokens)
        self.generated_tokens += n
        if req.first_token_t is not None:
            self.ttft.append(req.first_token_t - req.submitted_t)
            if n > 1 and req.done_t is not None:
                self.tpot.append((req.done_t - req.first_token_t) / (n - 1))
        self._t_last = now

    # ------------------------------------------------------------------
    def snapshot(self, *, queue_depth_now: int = 0, rejected: int = 0,
                 expired: int = 0, kv_blocks_peak: int = 0,
                 kv_shared_blocks_peak: int = 0, cow_splits: int = 0,
                 cache_evictions: int = 0) -> EngineSnapshot:
        wall = 0.0
        if self._t_first is not None and self._t_last is not None:
            wall = max(self._t_last - self._t_first, 0.0)
        return EngineSnapshot(
            completed=self.completed,
            rejected=rejected,
            expired=expired,
            steps=self.steps,
            generated_tokens=self.generated_tokens,
            wall_s=wall,
            tokens_per_s=self.generated_tokens / wall if wall > 0 else float("nan"),
            ttft=LatencyStats.of(self.ttft),
            tpot=LatencyStats.of(self.tpot),
            queue_wait=LatencyStats.of(self.queue_wait),
            queue_depth_mean=self._depth_sum / self.steps if self.steps else 0.0,
            queue_depth_now=queue_depth_now,
            slot_utilization=(self._busy_sum / (self.steps * self.n_slots)
                              if self.steps else 0.0),
            busy_lanes_mean=self._busy_sum / self.steps if self.steps else 0.0,
            prefill_dispatches=self.prefill_dispatches,
            prefill_requests=self.prefill_requests,
            prefill_batch_mean=(self.prefill_requests / self.prefill_dispatches
                                if self.prefill_dispatches else 0.0),
            prefill_tokens=self.prefill_tokens,
            preemptions=self.preemptions,
            resumes=self.resumes,
            kv_blocks_total=self.n_blocks,
            kv_blocks_peak=kv_blocks_peak,
            kv_block_utilization=(
                self._blocks_sum / (self.steps * self.n_blocks)
                if self.steps and self.n_blocks else 0.0),
            prefix_lookups=self.prefix_lookups,
            prefix_hit_tokens=self.prefix_hit_tokens,
            prefix_query_tokens=self.prefix_query_tokens,
            prefix_hit_rate=(self.prefix_hit_tokens / self.prefix_query_tokens
                             if self.prefix_query_tokens else 0.0),
            prefix_hit_series=tuple(self.prefix_hit_series),
            prefill_skipped=self.prefill_skipped,
            cow_splits=cow_splits,
            kv_shared_blocks_peak=kv_shared_blocks_peak,
            cache_evictions=cache_evictions,
            spec_rounds=self.spec_rounds,
            spec_drafted_tokens=self.spec_drafted_tokens,
            spec_accepted_tokens=self.spec_accepted_tokens,
            spec_acceptance_rate=(
                self.spec_accepted_tokens / self.spec_drafted_tokens
                if self.spec_drafted_tokens else 0.0),
            spec_accepted_series=tuple(self.spec_accepted_series),
        )
