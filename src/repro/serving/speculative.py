"""Speculative decoding: draft k tokens cheap, verify them in one dispatch.

The paper's fleet pairs a fast-but-throttling phone with a slow-but-steady
host — exactly the rate asymmetry speculative decoding converts into
wall-clock speedup: a small DRAFT model proposes ``k`` tokens per round,
the TARGET model verifies the whole proposal in ONE scanned multi-token
forward (:meth:`CacheBackend.verify_step`), and accepted tokens commit in
bulk.  :class:`SpecEngine` subclasses the plain
:class:`~repro.serving.engine.ServeEngine`, so admission, scheduling,
preemption, prefix caching and metrics are shared — only the decode round
differs.

**Coupled acceptance = bit-for-bit the baseline stream.**  At window
position ``j`` the target's logits are *exactly* the logits the plain
engine would have produced for that decode step (the verify window is a
``lax.scan`` of the single-step body — bitwise identical by construction,
see :func:`repro.models.lm.lm_decode_window`), and the emitted token is
sampled from them through the lane's frozen PRNG stream by the same
:class:`~repro.serving.sampling.Sampler`.  The drafted token only decides
whether the round CONTINUES past ``j`` (continue iff the target's own
sample equals the proposal).  The emitted stream is therefore identical
to the non-speculative engine's for greedy AND stochastic targets; the
draft controls only how many tokens each round commits.  Each lane
consumes exactly one PRNG split per emitted token (masked sampling), so
preempt/resume stays token-identical mid-round.

**Cache discipline.**  Both engines keep the invariant *cache content =
stream[:-1]* between rounds (stream = prompt + generated; the newest
token is fed, not yet written).  A round:

1. draft catch-up: a width-1 verify window feeds ``stream[-1]`` (writes
   it, logits propose t1), then ``k`` single draft steps feed t1..tk
   (the last step only writes tk; its logits are discarded unsampled);
2. the drafted row crosses to the target as a REAL wire-codec frame
   (charged against the fleet link budget; skipped when colocated);
3. target verify: width k+1 window over ``[stream[-1], t1..tk]``;
4. coupled acceptance emits ``n`` tokens (1 <= n <= k+1);
5. both sides ``rollback(slot, (k+1) - n)`` — dense/paged retreat the
   write position, recurrent backends replay the kept prefix from a
   pre-round stash — restoring the invariant exactly;
6. the emitted row + advanced PRNG key cross back as the sync frame.

The draft :class:`Sampler` copies the target's full lane state at every
round start, so a perfectly-aligned draft proposes exactly what the
target will sample (acceptance 1.0) even stochastically.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.backends import Reservation, make_backend
from repro.serving.engine import (EngineConfig, Request, ServeEngine,
                                  _shared_prefill_jits)
from repro.serving.sampling import Sampler, SamplingParams, resolve_sampling
from repro.serving.scheduler import SchedulerConfig
from repro.wire import codec


@dataclasses.dataclass
class SpecReport:
    """What one :meth:`SpecEngine.step_paced` round did — the fleet's
    charging input (compute per side, frame bytes per direction)."""
    n_active: int = 0              # lanes that ran the round
    spec_k: int = 0
    emitted_tokens: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    d2t_frame_bytes: int = 0       # drafted tokens, draft -> target
    t2d_frame_bytes: int = 0       # emitted row + PRNG sync, target -> draft
    draft_prefill_tokens: int = 0  # draft-side catch-up prefills this round
    target_prefill_tokens: int = 0 # target-side admission prefills this round


class SpecEngine(ServeEngine):
    """A ServeEngine whose decode step is a draft->verify round.

    ``colocated=True`` models the degraded-fleet fallback: draft and
    target share one worker, so the token exchange never touches the
    link (frame bytes report 0) and the fleet charges draft compute to
    the target member.  The decode MECHANICS are identical either way.
    """

    def __init__(self, model: Model, params, draft_model: Model, draft_params,
                 max_batch: int, max_len: int, *, spec_k: int = 3,
                 colocated: bool = False, eos_id: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets=None, max_prefill_batch: int = 8,
                 config: Optional[EngineConfig] = None, clock=None):
        super().__init__(model, params, max_batch, max_len, eos_id=eos_id,
                         scheduler=scheduler, prefill_buckets=prefill_buckets,
                         max_prefill_batch=max_prefill_batch, config=config,
                         clock=clock)
        if int(draft_model.cfg.vocab_size) != self.vocab:
            raise ValueError(
                f"draft vocab {draft_model.cfg.vocab_size} != target vocab "
                f"{self.vocab}: acceptance compares token ids")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_k = spec_k
        self.colocated = colocated
        self.draft_model = draft_model
        self.draft_params = draft_params
        # the draft side never needs paging (its lanes mirror the target's
        # admission): dense lanes, or pooled recurrent state for
        # recurrent-family drafts
        dkind = draft_model.decode_state.kind
        self.draft_backend = make_backend(
            draft_model, max_batch, max_len,
            EngineConfig(backend="recurrent" if dkind == "recurrent"
                         else "dense"))
        self.draft_sampler = Sampler(max_batch)
        self._draft_ready = [False] * max_batch
        self._draft_prefill1, _ = _shared_prefill_jits(draft_model, max_len)

    # ------------------------------------------------------------------
    # surface overrides
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        sampling = resolve_sampling(sampling, extra)
        if extra:
            raise TypeError(
                f"SpecEngine takes no extra model inputs (the draft side "
                f"prefills pure token streams); got {sorted(extra)}")
        return super().submit(prompt, max_new, sampling=sampling,
                              priority=priority, deadline_s=deadline_s)

    def feasible(self, req: Request) -> bool:
        return not req.extra and super().feasible(req)

    def preempt(self, slot: int, requeue: bool = True) -> Request:
        req = super().preempt(slot, requeue=requeue)
        self._release_draft(slot)
        return req

    def forget_lane(self, slot: int) -> Request:
        req = super().forget_lane(slot)
        self._release_draft(slot)
        return req

    # ------------------------------------------------------------------
    # draft-lane upkeep
    # ------------------------------------------------------------------
    def _release_draft(self, slot: int) -> None:
        self._draft_ready[slot] = False
        self.draft_backend.release(slot)

    def _sync_draft_lanes(self) -> int:
        """Bring the draft cache of every newly-(re)admitted lane up to the
        invariant (content = stream[:-1]); returns prefilled token count."""
        n_tokens = 0
        for slot, req in enumerate(self.slots):
            if req is None or self._draft_ready[slot]:
                continue
            pre = self._prefill_tokens(req)[:-1]
            if len(pre) == 0:
                self.draft_backend.reset_lane(slot)
            else:
                _, cache = self._draft_prefill1(
                    self.draft_params, {"tokens": jnp.asarray(pre[None])})
                self.draft_backend.prefill_paste(
                    slot, cache, 0, len(pre), len(pre), Reservation())
                n_tokens += len(pre)
            self._draft_ready[slot] = True
        return n_tokens

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------
    def step(self) -> int:
        self.step_paced()
        return self.active()

    def step_paced(self) -> SpecReport:
        """Admit, then run one draft->verify round. Returns the charging
        report (``n_active == 0`` = nothing ran: idle tick)."""
        rep = SpecReport(spec_k=self.spec_k)
        pf0 = self.metrics.prefill_tokens
        self._prepare_lanes()
        self._admit()
        self._prepare_lanes()
        rep.target_prefill_tokens = self.metrics.prefill_tokens - pf0
        rep.draft_prefill_tokens = self._sync_draft_lanes()
        if self.active() == 0:
            return rep
        k = self.spec_k
        w = k + 1
        b = self.max_batch

        # ---- draft phase: catch-up window + k single steps ------------
        self.draft_sampler.copy_state_from(self.sampler)
        active = np.asarray([s is not None for s in self.slots])
        last = np.zeros((b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None:
                seq = self._prefill_tokens(req)
                last[i, 0] = seq[-1]
        # width-1 verify window (not a bare step): recurrent draft
        # backends stash the pre-round state here, which rollback replays
        d_logits = self.draft_backend.verify_step(self.draft_params, last,
                                                  active)
        drafted = np.zeros((b, k), np.int32)
        drafted[:, 0] = self.draft_sampler.sample(
            # repro-lint: allow[R004] one batched draft-logits transfer per round
            np.asarray(d_logits)[:, 0, :self.vocab], mask=active)
        for j in range(1, k + 1):
            # step j writes t_j; its logits propose t_{j+1}.  The last
            # step only writes (the draft must hold t_k in case the whole
            # proposal is accepted) — its logits go unsampled, so no lane
            # consumes a PRNG split for a token that doesn't exist.
            step_logits = self.draft_backend.step(
                self.draft_params, drafted[:, j - 1:j], active)
            if j < k:
                drafted[:, j] = self.draft_sampler.sample(
                    # repro-lint: allow[R004] one batched transfer per draft step
                    np.asarray(step_logits)[:, :self.vocab], mask=active)

        # ---- drafted tokens cross the wire (draft -> target) ----------
        if not self.colocated:
            rows = np.flatnonzero(active)
            rids = np.asarray([self.slots[i].rid for i in rows], np.int64)
            buf = codec.dumps({"rids": rids, "toks": drafted[rows]})
            rep.d2t_frame_bytes = len(buf)
            rx = codec.loads(buf)       # honest round-trip: use decoded data
            drafted[rows] = rx["toks"]

        # ---- target verify: reserve W writes, then one scanned window -
        window = np.zeros((b, w), np.int32)
        window[:, 0] = last[:, 0]
        window[:, 1:] = drafted
        for slot in range(b):
            if self.slots[slot] is None:
                continue
            # the window writes W positions starting at the lane's current
            # one; reserve them all (paged grows / COW-splits per position)
            while not self.backend.append_tokens(slot, window[slot]):
                victim = self._pick_victim()
                self.preempt(victim)
                if victim == slot:
                    break
        active = np.asarray([s is not None for s in self.slots])
        if not active.any():
            return rep
        # repro-lint: allow[R004] the round's one verify-logits transfer to the host sampler
        w_logits = np.asarray(
            self.backend.verify_step(self.params, window, active))

        # ---- coupled acceptance ---------------------------------------
        limit = np.asarray([req.max_new - len(req.out_tokens) if req else 1
                            for req in self.slots])
        emitted, n_emitted, n_acc = self.sampler.accept(
            w_logits[:, :, :self.vocab], drafted, active, limit,
            eos_id=self.eos_id)
        rep.n_active = int(active.sum())
        # only drafts the acceptance loop could ever reach count toward the
        # rate: proposals past a lane's remaining budget are unverifiable
        rep.drafted_tokens = int(np.minimum(k, limit)[active].sum())
        rep.accepted_tokens = int(n_acc[active].sum())
        rep.emitted_tokens = int(n_emitted[active].sum())
        self.metrics.on_spec_round(rep.drafted_tokens, rep.accepted_tokens)

        # ---- commit + rollback (rollback BEFORE release so the prefix
        # cache registers exactly the content the lane really holds) ----
        now = self._now()
        busy = int(active.sum())
        for i, req in enumerate(self.slots):
            if req is None or not active[i]:
                continue
            n = int(n_emitted[i])  # repro-lint: allow[R004] n_emitted is host numpy from Sampler.accept; dtype cast, not a sync
            self.backend.rollback(i, w - n)
            self.draft_backend.rollback(i, w - n)
            req.out_tokens.extend(emitted[i])
            if req.first_token_t is None:
                req.first_token_t = now
            if (len(req.out_tokens) >= req.max_new
                    or emitted[i][-1] == self.eos_id):
                req.done_t = now
                self.slots[i] = None
                self.lane_sampling.clear_lane(i)
                self.backend.release(i, tokens=self._cache_tokens(req))
                self._release_draft(i)
                self.finished.append(req)
                self.metrics.on_finish(req, now)

        # ---- emitted row + PRNG state sync back (target -> draft) -----
        if not self.colocated:
            rows = np.flatnonzero(active)
            em = np.full((len(rows), w), -1, np.int32)
            for r, i in enumerate(rows):
                em[r, :len(emitted[i])] = emitted[i]
            buf = codec.dumps({
                "emitted": em, "n_emitted": n_emitted[rows],
                "keys": self.sampler.lanes.key[rows]})
            rep.t2d_frame_bytes = len(buf)
            codec.loads(buf)
        self.steps += 1
        self.metrics.on_step(self.scheduler.depth, busy, now,
                             blocks_in_use=self.backend.blocks_in_use)
        return rep
