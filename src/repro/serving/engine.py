"""Serving engine: continuous-batching KV-cache decode with batched prefill.

Slots: a fixed max_batch of cache lanes; queued requests are admitted into
free lanes by a pluggable :mod:`scheduler` policy, decode advances every
active lane one token per step, finished lanes free immediately (continuous
batching).  Works for every decoder-only family and whisper (enc-dec)
through the Model protocol.

KV memory comes in two layouts:

* **dense** (default) — one ``max_len``-wide cache lane per slot; admission
  capacity is ``max_batch`` regardless of how short requests actually are.
* **paged** (``EngineConfig.kv_blocks``) — a shared pool of fixed-size KV
  blocks (:mod:`repro.serving.block_manager`); lanes hold per-request block
  tables, admission allocates just the blocks a prompt needs, decode grows
  tables one block at a time, and when the pool is exhausted the engine
  PREEMPTS the most recently admitted lane (LIFO / recompute policy): its
  blocks are released and the request is requeued carrying its generated
  tokens and sampler state, so on re-admission it prefills prompt+generated
  in one shot and resumes token-identically.  Families whose decode state
  is not a position-addressed K/V cache (ssm / rwkv / hybrid / enc-dec)
  have no ``decode_step_paged`` hook and silently fall back to dense lanes.

Prefill is **bucketed and batched**: prompts are right-padded to a small set
of length buckets and several admissions share ONE jitted
``model.prefill_ragged`` dispatch (exact for full-causal-attention configs —
see :func:`repro.models.lm.lm_prefill_ragged`), whose per-lane caches are
then pasted into their decode lanes.  Families where padding would perturb
the state (ssm / rwkv / hybrid / enc-dec), and requests carrying extra
model inputs, fall back to the per-request exact-length prefill.

Decoding is per-request :class:`~repro.serving.sampling.SamplingParams`
(greedy / temperature / top-k / top-p, seeded per-lane PRNG streams), and a
:class:`~repro.serving.metrics.MetricsCollector` keeps TTFT / TPOT /
throughput / utilisation / preemption / block accounting;
``metrics_snapshot()`` returns the structured reading.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.block_manager import BlockManager
from repro.serving.metrics import EngineSnapshot, MetricsCollector
from repro.serving.sampling import (GREEDY, LaneSampling, SamplingParams,
                                    sample_tokens)
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide knobs (model- and policy-independent).

    ``pad_id`` fills the right-pad region of bucketed prefill batches.  The
    padded positions are causally masked out of every real token, so any id
    inside the vocab is CORRECT — but it must be configurable so that
    vocabularies where 0 is a live token can pick an unambiguous filler for
    logging/debugging, instead of a hardcoded module constant.

    ``kv_blocks`` switches the KV cache to the paged layout: a pool of that
    many usable ``kv_block_size``-token blocks shared by all lanes (plus an
    internal sink block).  ``watermark_frac`` of the pool is held back from
    admission as headroom for decode-time growth — 0 admits greedily and
    relies purely on preemption; a small reserve (e.g. 0.05) trades a
    little admission capacity for fewer preemptions under pressure.
    """
    pad_id: int = 0
    kv_blocks: Optional[int] = None
    kv_block_size: int = 16
    watermark_frac: float = 0.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new: int = 16
    extra: dict = dataclasses.field(default_factory=dict)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    sampling: SamplingParams = GREEDY
    priority: int = 0
    deadline_s: Optional[float] = None
    admitted_t: Optional[float] = None
    preemptions: int = 0
    # PRNG counter frozen at preemption so a stochastic request resumes on
    # exactly the sample stream it would have continued on
    saved_key: Optional[np.ndarray] = None


def default_buckets(max_len: int, smallest: int = 16) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to max_len."""
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int, max_len: int,
                 eos_id: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 max_prefill_batch: int = 8,
                 config: Optional[EngineConfig] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.config = config or EngineConfig()
        # logit width is pad_vocab(vocab); the pad columns carry real random
        # head weights, so sampling must be restricted to the true vocab
        self.vocab = int(model.cfg.vocab_size)
        self.scheduler = AdmissionScheduler(scheduler)
        self.buckets = tuple(sorted(prefill_buckets)) if prefill_buckets \
            else default_buckets(max_len)
        if self.buckets[-1] > max_len:
            raise ValueError(
                f"prefill bucket {self.buckets[-1]} exceeds max_len "
                f"{max_len}: prefilling past the cache span would drop "
                f"real prompt K/V")
        self.max_prefill_batch = max(1, min(max_prefill_batch, max_batch))
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.lane_sampling = LaneSampling.empty(max_batch)
        self._rid = 0
        self.steps = 0
        self.finished: List[Request] = []

        # KV layout: paged pool when configured AND the family supports it
        self.paged = (self.config.kv_blocks is not None
                      and model.decode_step_paged is not None)
        if self.paged:
            bs = self.config.kv_block_size
            self.blocks: Optional[BlockManager] = BlockManager(
                self.config.kv_blocks, bs, self.config.watermark_frac)
            self.max_blocks_per_lane = -(-max_len // bs)
            self.cache = model.init_paged_cache(max_batch,
                                                self.config.kv_blocks, bs)
            self.block_tables = np.zeros(
                (max_batch, self.max_blocks_per_lane), np.int32)
            self._lane_blocks: List[List[int]] = [[] for _ in range(max_batch)]
            self._lane_pos = np.zeros((max_batch,), np.int64)
            self._reserved: Dict[int, List[int]] = {}     # rid -> admit blocks
            self._decode_paged = jax.jit(model.decode_step_paged,
                                         donate_argnums=1)
        else:
            self.blocks = None
            self.cache = model.init_cache(max_batch, max_len)

        self.metrics = MetricsCollector(
            n_slots=max_batch,
            n_blocks=self.blocks.n_blocks if self.paged else 0)

        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._prefill1 = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        if model.prefill_ragged is not None:
            self._prefill_n = jax.jit(
                lambda p, toks, lens: model.prefill_ragged(
                    p, {"tokens": toks}, lens, max_len))
        else:
            self._prefill_n = None

        if self.paged:
            def paste_paged(cache, src_layers, src_lane, flat_idx, dst_slot,
                            length):
                """Scatter lane ``src_lane`` of a prefill cache into this
                lane's allocated pool blocks.  ``flat_idx`` (width,) maps
                prefill positions to flattened pool slots; positions past
                the real context point at the sink block."""
                def fix(pool, src):
                    nl = pool.shape[0]
                    flat = pool.reshape((nl, -1) + pool.shape[3:])
                    piece = jax.lax.dynamic_index_in_dim(
                        src, src_lane, axis=1, keepdims=False)
                    piece = jax.lax.slice_in_dim(
                        piece, 0, flat_idx.shape[0], axis=1)
                    flat = flat.at[:, flat_idx].set(piece.astype(flat.dtype))
                    return flat.reshape(pool.shape)
                layers = {"k": fix(cache["layers"]["k"], src_layers["k"]),
                          "v": fix(cache["layers"]["v"], src_layers["v"])}
                pos = cache["pos"].at[dst_slot].set(length)
                return {"layers": layers, "pos": pos}

            self._paste_paged = jax.jit(paste_paged, donate_argnums=0)
        else:
            # Locate each cache leaf's lane axis ONCE by diffing the shapes
            # of two abstract caches that differ only in batch (-1 = no lane
            # axis, e.g. scalars shared across lanes).
            s_a = jax.eval_shape(lambda: model.init_cache(max_batch, max_len))
            s_b = jax.eval_shape(
                lambda: model.init_cache(max_batch + 1, max_len))

            def lane_axis(a, b):
                for ax, (da, db) in enumerate(zip(a.shape, b.shape)):
                    if da != db:
                        return ax
                return -1

            self._lane_ax = jax.tree.map(lane_axis, s_a, s_b)

            def paste(cache, src_cache, src_lane, dst_slot):
                """Copy lane ``src_lane`` of a prefill cache into decode lane
                ``dst_slot``.  Lane indices are traced, so every admission
                reuses one compile per source-batch shape."""
                def fix(ax, dst, src):
                    if ax < 0:
                        return dst
                    piece = jax.lax.dynamic_index_in_dim(src, src_lane,
                                                         axis=ax,
                                                         keepdims=True)
                    idx = tuple(dst_slot if i == ax else 0
                                for i in range(dst.ndim))
                    return jax.lax.dynamic_update_slice(
                        dst, piece.astype(dst.dtype), idx)
                return jax.tree.map(fix, self._lane_ax, cache, src_cache)

            self._paste = jax.jit(paste, donate_argnums=0)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        """Queue a request; returns its rid, or None if admission control
        rejected it (queue at max_queue)."""
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new, extra,
                      submitted_t=time.perf_counter(),
                      sampling=sampling or GREEDY, priority=priority,
                      deadline_s=deadline_s)
        if not self.scheduler.push(req, req.submitted_t):
            return None
        return rid

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after a preemption — every
        token generated so far, so the request resumes where it left off."""
        if not req.out_tokens:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.out_tokens, np.int32)])

    def _ctx_len(self, req: Request) -> int:
        """Cache positions the prefill will occupy (frontend rows included)."""
        n = len(req.prompt) + len(req.out_tokens)
        fe = req.extra.get("frontend")
        if fe is not None:
            n += fe.shape[0]
        return n

    def _bucket_len(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        # past the largest bucket: pad to max_len rather than compiling a
        # fresh prefill executable per distinct prompt length
        return self.max_len

    def _flat_idx(self, blocks: List[int], n_ctx: int,
                  width: int) -> np.ndarray:
        """Flattened pool slots for prefill positions 0..width-1: real
        context goes to the lane's blocks, pad tail to the sink (block 0)."""
        bs = self.blocks.block_size
        i = np.arange(width)
        phys = (i % bs).astype(np.int64)               # sink for the tail
        real = i < n_ctx
        ids = np.asarray(blocks, np.int64)
        phys[real] = ids[i[real] // bs] * bs + i[real] % bs
        return phys

    def _reserve_blocks(self, batch: List[Request]) -> List[Request]:
        """Allocate each admission's prompt blocks up front; spill whatever
        doesn't fit back to the queue (allocate-on-admit)."""
        admitted: List[Request] = []
        # blocks a request may need at any (re-)admission; watermark
        # included, else a request could pass feasibility yet never pass
        # can_admit — livelocking itself and everything queued behind it
        usable = self.blocks.n_blocks - self.blocks.watermark_blocks
        for i, req in enumerate(batch):
            n_ctx = self._ctx_len(req)
            # feasibility is judged on the FINAL footprint: the context
            # plus every token the request may still generate (>= n_ctx).
            # A request admitted on prompt size alone but over-budget at
            # completion would generate half its tokens and then die in a
            # preempt/reject loop; one past max_len could resume with more
            # context than the prefill cache span holds.  Unlike the dense
            # layout (which lossily CLAMPS writes past max_len), paged
            # mode rejects such requests up front.
            final = n_ctx - len(req.out_tokens) + req.max_new - 1
            if final > self.max_len or self.blocks.blocks_needed(final) > usable:
                self.scheduler.reject(req)
                continue
            need = self.blocks.blocks_needed(n_ctx)
            if not self.blocks.can_admit(need):
                for r in batch[i:]:
                    self.scheduler.requeue(r)
                break
            self._reserved[req.rid] = self.blocks.allocate(need)
            admitted.append(req)
        return admitted

    def _admit_group(self, reqs: List[Request], slots: List[int],
                     logits: jax.Array, group_cache, now: float,
                     widths: List[int]) -> None:
        """Sample all first tokens in ONE dispatch, then paste each lane.
        ``widths[j]`` is the prefill width request j was padded to (its
        bucket length, or its exact context length on the fallback path)."""
        ls = self.lane_sampling
        for req, slot in zip(reqs, slots):
            ls.set_lane(slot, req.sampling)
            if req.saved_key is not None:     # resume: continue the stream
                ls.key[slot] = req.saved_key
        idx = np.asarray(slots)
        toks, new_kd = sample_tokens(logits[:, :self.vocab],
                                     jnp.asarray(ls.temperature[idx]),
                                     jnp.asarray(ls.top_k[idx]),
                                     jnp.asarray(ls.top_p[idx]),
                                     jnp.asarray(ls.key[idx]))
        toks, new_kd = np.asarray(toks), np.asarray(new_kd)
        t_first = time.perf_counter()
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            ls.key[slot] = new_kd[j]
            n_ctx = self._ctx_len(req)
            tok = int(toks[j])
            req.out_tokens.append(tok)
            if req.admitted_t is None:
                req.first_token_t = t_first
                self.metrics.on_admit(req, now)
            else:
                self.metrics.on_resume(req, now)
            req.admitted_t = now
            req.saved_key = None
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                # finished at admission: never occupies a decode lane
                req.done_t = t_first
                ls.clear_lane(slot)
                if self.paged:
                    self.blocks.release(self._reserved.pop(req.rid))
                self.finished.append(req)
                self.metrics.on_finish(req, t_first)
                continue
            if self.paged:
                blocks = self._reserved.pop(req.rid)
                flat = self._flat_idx(blocks, n_ctx, widths[j])
                self.cache = self._paste_paged(
                    self.cache, group_cache["layers"], jnp.int32(j),
                    jnp.asarray(flat), jnp.int32(slot), jnp.int32(n_ctx))
                self._lane_blocks[slot] = blocks
                self.block_tables[slot, :] = 0
                self.block_tables[slot, :len(blocks)] = blocks
                self._lane_pos[slot] = n_ctx
            else:
                self.cache = self._paste(self.cache, group_cache,
                                         jnp.int32(j), jnp.int32(slot))
            self.slots[slot] = req

    def _admit(self) -> None:
        # loop: requests that finish AT admission (max_new=1 / instant EOS)
        # leave their lane idle — refill it this round, not next step
        while self._admit_once():
            pass

    def _admit_once(self) -> bool:
        """One admission round; True if a lane freed up again (re-admit)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        now = time.perf_counter()
        batch = self.scheduler.pop(len(free), now)
        if self.paged and batch:
            batch = self._reserve_blocks(batch)
        if not batch:
            return False
        n_done_before = len(self.finished)

        # split into batched-eligible vs exact-length fallback
        batched: List[Request] = []
        fallback: List[Request] = []
        for req in batch:
            ok = (self._prefill_n is not None and not req.extra
                  and self._ctx_len(req) <= self.max_len)
            (batched if ok else fallback).append(req)

        # group eligible requests by padded bucket length, then chunk each
        # group to the prefill batch limit -> one dispatch per chunk
        groups = {}
        for req in batched:
            groups.setdefault(self._bucket_len(self._ctx_len(req)),
                              []).append(req)
        for blen, reqs in sorted(groups.items()):
            for i in range(0, len(reqs), self.max_prefill_batch):
                chunk = reqs[i:i + self.max_prefill_batch]
                toks = np.full((len(chunk), blen), self.config.pad_id,
                               np.int32)
                lens = np.zeros((len(chunk),), np.int32)
                for j, req in enumerate(chunk):
                    seq = self._prefill_tokens(req)
                    toks[j, :len(seq)] = seq
                    lens[j] = len(seq)
                logits, group_cache = self._prefill_n(
                    self.params, jnp.asarray(toks), jnp.asarray(lens))
                self.metrics.on_prefill(len(chunk))
                slots = [free.pop(0) for _ in chunk]
                self._admit_group(chunk, slots, logits, group_cache, now,
                                  widths=[blen] * len(chunk))
        for req in fallback:
            seq = self._prefill_tokens(req)
            b = {"tokens": jnp.asarray(seq[None])}
            for k, v in req.extra.items():
                b[k] = jnp.asarray(v[None])
            logits, one_cache = self._prefill1(self.params, b)
            self.metrics.on_prefill(1)
            self._admit_group([req], [free.pop(0)], logits, one_cache, now,
                              widths=[self._ctx_len(req)])

        return (len(self.finished) > n_done_before
                and self.scheduler.depth > 0)

    # ------------------------------------------------------------------
    # paged growth / preemption
    # ------------------------------------------------------------------
    def _pick_victim(self) -> int:
        """LIFO (recompute) policy: preempt the most recently admitted lane
        — it has the least decode work to throw away and re-prefill, and
        old requests can't be starved by a stream of newer ones."""
        cands = [i for i, r in enumerate(self.slots) if r is not None]
        return max(cands,
                   key=lambda i: (self.slots[i].admitted_t,
                                  self.slots[i].rid))

    def _preempt(self, slot: int) -> None:
        req = self.slots[slot]
        req.preemptions += 1
        req.saved_key = self.lane_sampling.key[slot].copy()
        self.blocks.release(self._lane_blocks[slot])
        self._lane_blocks[slot] = []
        self.block_tables[slot, :] = 0
        self._lane_pos[slot] = 0
        self.slots[slot] = None
        self.lane_sampling.clear_lane(slot)
        self.scheduler.requeue(req)
        self.metrics.on_preempt(req)

    def _grow_lanes(self) -> None:
        """Grow-on-decode: before a step, every active lane whose next write
        position crosses into an unallocated block gets one; exhaustion
        preempts victims (possibly the needy lane itself) until it frees."""
        bs = self.blocks.block_size
        for slot in range(self.max_batch):
            if self.slots[slot] is None:
                continue
            bidx = int(self._lane_pos[slot]) // bs
            if bidx >= self.max_blocks_per_lane:
                continue                  # saturated: dense-path clamp
            if bidx < len(self._lane_blocks[slot]):
                continue
            blk = self.blocks.allocate_one()
            while blk is None:
                victim = self._pick_victim()
                self._preempt(victim)
                if victim == slot:
                    break
                blk = self.blocks.allocate_one()
            if self.slots[slot] is None:  # lane preempted itself
                continue
            self._lane_blocks[slot].append(blk)
            self.block_tables[slot, bidx] = blk

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> int:
        """Admit + one decode step for all active lanes. Returns #active."""
        if self.paged:
            # grow RUNNING lanes before admission takes the last free
            # blocks — else a fresh admission pays a whole prefill only to
            # be the LIFO victim of an older lane's growth this same step
            self._grow_lanes()
        self._admit()
        if self.paged:
            # second pass covers lanes admitted above whose context ends
            # exactly on a block boundary (first write needs a new block)
            self._grow_lanes()
        if self.active() == 0:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                toks[i, 0] = req.out_tokens[-1]
        if self.paged:
            logits, self.cache = self._decode_paged(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.block_tables))
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        ls = self.lane_sampling
        nxt, new_kd = sample_tokens(logits[:, :self.vocab],
                                    jnp.asarray(ls.temperature),
                                    jnp.asarray(ls.top_k),
                                    jnp.asarray(ls.top_p),
                                    jnp.asarray(ls.key))
        ls.key[:] = np.asarray(new_kd)
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        busy = self.active()          # before the finish-scan frees lanes
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.paged:
                self._lane_pos[i] += 1
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                req.done_t = now
                self.slots[i] = None                # lane freed immediately
                ls.clear_lane(i)
                if self.paged:
                    self.blocks.release(self._lane_blocks[i])
                    self._lane_blocks[i] = []
                    self.block_tables[i, :] = 0
                    self._lane_pos[i] = 0
                self.finished.append(req)
                self.metrics.on_finish(req, now)
        self.steps += 1
        self.metrics.on_step(self.scheduler.depth, busy, now,
                             blocks_in_use=(self.blocks.in_use
                                            if self.paged else 0))
        return self.active()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            # step() admits first, so one call per iteration does both
            if self.step() == 0 and not self.scheduler.depth:
                break
        return self.finished

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Waiting requests in current admission order."""
        return self.scheduler.peek_order()

    def reset_stats(self) -> None:
        """Drop finished/rejected/expired records and metrics counters —
        e.g. after a jit warm-up pass — without touching lanes or queue."""
        self.finished.clear()
        self.scheduler.rejected.clear()
        self.scheduler.expired.clear()
        self.scheduler.rejected_total = 0
        self.scheduler.expired_total = 0
        self.steps = 0
        self.metrics = MetricsCollector(
            n_slots=self.max_batch,
            n_blocks=self.blocks.n_blocks if self.paged else 0)
        if self.paged:
            self.blocks.peak_in_use = self.blocks.in_use

    def metrics_snapshot(self) -> EngineSnapshot:
        return self.metrics.snapshot(
            queue_depth_now=self.scheduler.depth,
            rejected=self.scheduler.rejected_total,
            expired=self.scheduler.expired_total,
            kv_blocks_peak=self.blocks.peak_in_use if self.paged else 0)
