"""Serving engine: continuous-batching KV-cache decode with batched prefill.

Slots: a fixed max_batch of cache lanes; queued requests are admitted into
free lanes by a pluggable :mod:`scheduler` policy, decode advances every
active lane one token per step, finished lanes free immediately (continuous
batching).  Works for every decoder-only family and whisper (enc-dec)
through the Model protocol.

Prefill is **bucketed and batched**: prompts are right-padded to a small set
of length buckets and several admissions share ONE jitted
``model.prefill_ragged`` dispatch (exact for full-causal-attention configs —
see :func:`repro.models.lm.lm_prefill_ragged`), whose per-lane caches are
then pasted into their decode lanes.  Families where padding would perturb
the state (ssm / rwkv / hybrid / enc-dec), and requests carrying extra
model inputs, fall back to the per-request exact-length prefill.

Decoding is per-request :class:`~repro.serving.sampling.SamplingParams`
(greedy / temperature / top-k / top-p, seeded per-lane PRNG streams), and a
:class:`~repro.serving.metrics.MetricsCollector` keeps TTFT / TPOT /
throughput / utilisation accounting; ``metrics_snapshot()`` returns the
structured reading.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.metrics import EngineSnapshot, MetricsCollector
from repro.serving.sampling import (GREEDY, LaneSampling, SamplingParams,
                                    sample_tokens)
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig

PAD_ID = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new: int = 16
    extra: dict = dataclasses.field(default_factory=dict)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    sampling: SamplingParams = GREEDY
    priority: int = 0
    deadline_s: Optional[float] = None
    admitted_t: Optional[float] = None


def default_buckets(max_len: int, smallest: int = 16) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to max_len."""
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int, max_len: int,
                 eos_id: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 max_prefill_batch: int = 8):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        # logit width is pad_vocab(vocab); the pad columns carry real random
        # head weights, so sampling must be restricted to the true vocab
        self.vocab = int(model.cfg.vocab_size)
        self.scheduler = AdmissionScheduler(scheduler)
        self.buckets = tuple(sorted(prefill_buckets)) if prefill_buckets \
            else default_buckets(max_len)
        if self.buckets[-1] > max_len:
            raise ValueError(
                f"prefill bucket {self.buckets[-1]} exceeds max_len "
                f"{max_len}: prefilling past the cache span would drop "
                f"real prompt K/V")
        self.max_prefill_batch = max(1, min(max_prefill_batch, max_batch))
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.cache = model.init_cache(max_batch, max_len)
        self.lane_sampling = LaneSampling.empty(max_batch)
        self._rid = 0
        self.steps = 0
        self.finished: List[Request] = []
        self.metrics = MetricsCollector(n_slots=max_batch)

        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._prefill1 = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        if model.prefill_ragged is not None:
            self._prefill_n = jax.jit(
                lambda p, toks, lens: model.prefill_ragged(
                    p, {"tokens": toks}, lens, max_len))
        else:
            self._prefill_n = None

        # Locate each cache leaf's lane axis ONCE by diffing the shapes of
        # two abstract caches that differ only in batch (-1 = no lane axis,
        # e.g. scalars shared across lanes).
        s_a = jax.eval_shape(lambda: model.init_cache(max_batch, max_len))
        s_b = jax.eval_shape(lambda: model.init_cache(max_batch + 1, max_len))

        def lane_axis(a, b):
            for ax, (da, db) in enumerate(zip(a.shape, b.shape)):
                if da != db:
                    return ax
            return -1

        self._lane_ax = jax.tree.map(lane_axis, s_a, s_b)

        def paste(cache, src_cache, src_lane, dst_slot):
            """Copy lane ``src_lane`` of a prefill cache into decode lane
            ``dst_slot``.  Lane indices are traced, so every admission
            reuses one compile per source-batch shape."""
            def fix(ax, dst, src):
                if ax < 0:
                    return dst
                piece = jax.lax.dynamic_index_in_dim(src, src_lane, axis=ax,
                                                     keepdims=True)
                idx = tuple(dst_slot if i == ax else 0
                            for i in range(dst.ndim))
                return jax.lax.dynamic_update_slice(
                    dst, piece.astype(dst.dtype), idx)
            return jax.tree.map(fix, self._lane_ax, cache, src_cache)

        self._paste = jax.jit(paste, donate_argnums=0)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        """Queue a request; returns its rid, or None if admission control
        rejected it (queue at max_queue)."""
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new, extra,
                      submitted_t=time.perf_counter(),
                      sampling=sampling or GREEDY, priority=priority,
                      deadline_s=deadline_s)
        if not self.scheduler.push(req, req.submitted_t):
            return None
        return rid

    def _bucket_len(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        # past the largest bucket: pad to max_len rather than compiling a
        # fresh prefill executable per distinct prompt length
        return self.max_len

    def _admit_group(self, reqs: List[Request], slots: List[int],
                     logits: jax.Array, group_cache, now: float) -> None:
        """Sample all first tokens in ONE dispatch, then paste each lane."""
        ls = self.lane_sampling
        for req, slot in zip(reqs, slots):
            ls.set_lane(slot, req.sampling)
        idx = np.asarray(slots)
        toks, new_kd = sample_tokens(logits[:, :self.vocab],
                                     jnp.asarray(ls.temperature[idx]),
                                     jnp.asarray(ls.top_k[idx]),
                                     jnp.asarray(ls.top_p[idx]),
                                     jnp.asarray(ls.key[idx]))
        toks, new_kd = np.asarray(toks), np.asarray(new_kd)
        t_first = time.perf_counter()
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            ls.key[slot] = new_kd[j]
            tok = int(toks[j])
            req.out_tokens.append(tok)
            req.first_token_t = t_first
            req.admitted_t = now
            self.metrics.on_admit(req, now)
            if req.max_new <= 1 or tok == self.eos_id:
                # finished at admission: never occupies a decode lane
                req.done_t = t_first
                ls.clear_lane(slot)
                self.finished.append(req)
                self.metrics.on_finish(req, t_first)
                continue
            self.cache = self._paste(self.cache, group_cache,
                                     jnp.int32(j), jnp.int32(slot))
            self.slots[slot] = req

    def _admit(self) -> None:
        # loop: requests that finish AT admission (max_new=1 / instant EOS)
        # leave their lane idle — refill it this round, not next step
        while self._admit_once():
            pass

    def _admit_once(self) -> bool:
        """One admission round; True if a lane freed up again (re-admit)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        now = time.perf_counter()
        batch = self.scheduler.pop(len(free), now)
        if not batch:
            return False
        n_done_before = len(self.finished)

        # split into batched-eligible vs exact-length fallback
        batched: List[Request] = []
        fallback: List[Request] = []
        for req in batch:
            ok = (self._prefill_n is not None and not req.extra
                  and len(req.prompt) <= self.max_len)
            (batched if ok else fallback).append(req)

        # group eligible requests by padded bucket length, then chunk each
        # group to the prefill batch limit -> one dispatch per chunk
        groups = {}
        for req in batched:
            groups.setdefault(self._bucket_len(len(req.prompt)),
                              []).append(req)
        for blen, reqs in sorted(groups.items()):
            for i in range(0, len(reqs), self.max_prefill_batch):
                chunk = reqs[i:i + self.max_prefill_batch]
                toks = np.full((len(chunk), blen), PAD_ID, np.int32)
                lens = np.zeros((len(chunk),), np.int32)
                for j, req in enumerate(chunk):
                    toks[j, :len(req.prompt)] = req.prompt
                    lens[j] = len(req.prompt)
                logits, group_cache = self._prefill_n(
                    self.params, jnp.asarray(toks), jnp.asarray(lens))
                self.metrics.on_prefill(len(chunk))
                slots = [free.pop(0) for _ in chunk]
                self._admit_group(chunk, slots, logits, group_cache, now)

        for req in fallback:
            b = {"tokens": jnp.asarray(req.prompt[None])}
            for k, v in req.extra.items():
                b[k] = jnp.asarray(v[None])
            logits, one_cache = self._prefill1(self.params, b)
            self.metrics.on_prefill(1)
            self._admit_group([req], [free.pop(0)], logits, one_cache, now)

        return (len(self.finished) > n_done_before
                and self.scheduler.depth > 0)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> int:
        """Admit + one decode step for all active lanes. Returns #active."""
        self._admit()
        if self.active() == 0:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                toks[i, 0] = req.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        ls = self.lane_sampling
        nxt, new_kd = sample_tokens(logits[:, :self.vocab],
                                    jnp.asarray(ls.temperature),
                                    jnp.asarray(ls.top_k),
                                    jnp.asarray(ls.top_p),
                                    jnp.asarray(ls.key))
        ls.key[:] = np.asarray(new_kd)
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        busy = self.active()          # before the finish-scan frees lanes
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                req.done_t = now
                self.slots[i] = None                # lane freed immediately
                ls.clear_lane(i)
                self.finished.append(req)
                self.metrics.on_finish(req, now)
        self.steps += 1
        self.metrics.on_step(self.scheduler.depth, busy, now)
        return self.active()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            # step() admits first, so one call per iteration does both
            if self.step() == 0 and not self.scheduler.depth:
                break
        return self.finished

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Waiting requests in current admission order."""
        return self.scheduler.peek_order()

    def reset_stats(self) -> None:
        """Drop finished/rejected/expired records and metrics counters —
        e.g. after a jit warm-up pass — without touching lanes or queue."""
        self.finished.clear()
        self.scheduler.rejected.clear()
        self.scheduler.expired.clear()
        self.scheduler.rejected_total = 0
        self.scheduler.expired_total = 0
        self.steps = 0
        self.metrics = MetricsCollector(n_slots=self.max_batch)

    def metrics_snapshot(self) -> EngineSnapshot:
        return self.metrics.snapshot(
            queue_depth_now=self.scheduler.depth,
            rejected=self.scheduler.rejected_total,
            expired=self.scheduler.expired_total)
