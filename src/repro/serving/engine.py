"""Serving engine: continuous-batching KV-cache decode.

Slots: a fixed max_batch of cache lanes; requests are admitted into free
slots (prefill computes a batch-1 cache that is pasted into the lane),
decode advances every active lane one token per step, finished lanes free
immediately (continuous batching).  Works for every decoder-only family and
whisper (enc-dec) through the Model protocol.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models.api import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new: int = 16
    extra: dict = dataclasses.field(default_factory=dict)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int, max_len: int,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.cache = model.init_cache(max_batch, max_len)
        self.positions = jnp.zeros((max_batch,), jnp.int32)
        self._rid = 0
        self.steps = 0
        self.finished: List[Request] = []

        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._prefill1 = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))

        def paste(cache, one_cache, slot):
            """Insert a batch-1 cache into lane ``slot``."""
            def fix(dst, src):
                if np.ndim(dst) == 0 or dst.shape == src.shape:
                    return dst
                # find the lane dim: first dim where dst==max_batch, src==1
                for ax in range(src.ndim):
                    if src.shape[ax] == 1 and dst.shape[ax] == self.max_batch:
                        idx = [0] * src.ndim
                        idx[ax] = slot
                        return jax.lax.dynamic_update_slice(
                            dst, src.astype(dst.dtype), tuple(idx))
                return dst
            # note: "pos" is (max_batch,) vs (1,) and is pasted per-lane by
            # the same rule as every other cache leaf
            return jax.tree.map(fix, cache, one_cache)

        self._paste = jax.jit(paste, static_argnums=2, donate_argnums=0)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16, **extra) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new,
                                  extra, submitted_t=time.perf_counter()))
        return rid

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            batch = {"tokens": jnp.asarray(req.prompt[None])}
            for k, v in req.extra.items():
                batch[k] = jnp.asarray(v[None])
            logits, one_cache = self._prefill1(self.params, batch)
            tok = int(jnp.argmax(logits[0]))
            req.out_tokens.append(tok)
            req.first_token_t = time.perf_counter()
            self.cache = self._paste(self.cache, one_cache, slot)
            self.slots[slot] = req

    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> int:
        """Admit + one decode step for all active lanes. Returns #active."""
        self._admit()
        if self.active() == 0:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out_tokens:
                toks[i, 0] = req.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                req.done_t = now
                self.slots[i] = None                # lane freed immediately
                self.finished.append(req)
        self.steps += 1
        return self.active()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            self._admit()
            if self.active() == 0 and not self.queue:
                break
            self.step()
        return self.finished
