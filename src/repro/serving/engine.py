"""Serving engine: continuous-batching decode over a pluggable CacheBackend.

Slots: a fixed max_batch of cache lanes; queued requests are admitted into
free lanes by a pluggable :mod:`scheduler` policy, decode advances every
active lane one token per step, finished lanes free immediately (continuous
batching).  Works for every decoder-only family and whisper (enc-dec)
through the Model protocol.

Decode state lives behind ONE object — a
:class:`~repro.serving.backends.CacheBackend` — and the engine speaks only
its protocol (``alloc / prefill_paste / step / snapshot / release /
token_footprint``).  Which backend an engine gets is decided once by
:func:`~repro.serving.backends.make_backend`:

* **dense** — one ``max_len``-wide cache lane per slot.
* **paged** (``EngineConfig.kv_blocks``) — a shared pool of fixed-size KV
  blocks; admission allocates just the blocks a prompt needs, decode grows
  tables one block at a time, and exhaustion PREEMPTS the most recently
  admitted lane (LIFO / recompute policy), which later resumes
  token-identically.  With ``EngineConfig.prefix_cache`` the pool becomes
  content-addressed: full prompt blocks are shared copy-on-write across
  lanes, admission charges only unique blocks, and a fully-cached prompt
  skips its prefill dispatch outright.
* **recurrent** — ssm / rwkv / hybrid families get pooled
  constant-footprint state lanes; preemption snapshots the (small,
  fixed-size) state host-side and resumes with zero recompute.

Prefill is **bucketed and batched**: prompts are right-padded to a small set
of length buckets and several admissions share ONE jitted batched-prefill
dispatch (exact for full-causal-attention configs — see
:func:`repro.models.lm.lm_prefill_padded`), whose per-lane caches are then
pasted into their decode lanes.  Families where padding would perturb the
state (ssm / rwkv / hybrid / enc-dec), and requests carrying extra model
inputs, fall back to the per-request exact-length prefill.

Decoding is per-request :class:`~repro.serving.sampling.SamplingParams`
(greedy / temperature / top-k / top-p, seeded per-lane PRNG streams), and a
:class:`~repro.serving.metrics.MetricsCollector` keeps TTFT / TPOT /
throughput / utilisation / preemption / block / prefix-cache accounting;
``metrics_snapshot()`` returns the structured reading.

The engine is **externally paceable**: it never owns a run loop beyond the
convenience :meth:`ServeEngine.run_until_drained` — a caller (the fleet)
decides how many :meth:`ServeEngine.step` calls a worker gets per unit of
(simulated) time.  Three hooks exist for fleet-level control: ``inject``
admits an externally-built Request (fleet routing), ``preempt(slot,
requeue=False)`` releases a lane token-identically and *returns* the
request instead of requeueing it locally (lane migration), and
``pull_queued`` empties the local queue (backlog re-routing).
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.backends import INFEASIBLE, Reservation, make_backend
from repro.serving.metrics import EngineSnapshot, MetricsCollector
from repro.serving.sampling import (GREEDY, Sampler, SamplingParams,
                                    resolve_sampling)
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide knobs (model- and policy-independent).

    ``pad_id`` fills the right-pad region of bucketed prefill batches.  The
    padded positions are causally masked out of every real token, so any id
    inside the vocab is CORRECT — but it must be configurable so that
    vocabularies where 0 is a live token can pick an unambiguous filler for
    logging/debugging, instead of a hardcoded module constant.

    ``kv_blocks`` switches eligible families to the paged backend: a pool
    of that many usable ``kv_block_size``-token blocks shared by all lanes
    (plus an internal sink block).  ``watermark_frac`` of the pool is held
    back from admission as headroom for decode-time growth — 0 admits
    greedily and relies purely on preemption.

    ``prefix_cache`` (paged only) turns on refcounted copy-on-write prompt
    sharing: identical prompt prefixes are admitted against the SAME
    physical blocks, and fully-cached prompts skip prefill.

    ``backend`` forces a cache layout (``"dense" | "paged" | "recurrent"``)
    instead of the automatic choice — chiefly for tests and A/B benches.
    """
    pad_id: int = 0
    kv_blocks: Optional[int] = None
    kv_block_size: int = 16
    watermark_frac: float = 0.0
    prefix_cache: bool = False
    backend: Optional[str] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    max_new: int = 16
    extra: dict = dataclasses.field(default_factory=dict)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    sampling: SamplingParams = GREEDY
    priority: int = 0
    deadline_s: Optional[float] = None
    admitted_t: Optional[float] = None
    preemptions: int = 0
    # PRNG counter frozen at preemption so a stochastic request resumes on
    # exactly the sample stream it would have continued on
    saved_key: Optional[np.ndarray] = None
    # backend state snapshot (recurrent lanes): resume without recompute
    saved_state: Optional[Any] = None
    # (out_len, backend.state_version, value) — memoized admission
    # footprint, so a queued request isn't re-hashed every engine step
    fp_memo: Optional[Tuple[int, int, int]] = None


def default_buckets(max_len: int, smallest: int = 16) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to max_len."""
    out, b = [], smallest
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


@functools.lru_cache(maxsize=64)
def _shared_prefill_jits(model: Model, max_len: int):
    """One jitted (single, batched) prefill pair per (model, max_len).

    jax.jit caches are per wrapper object, and a fleet builds one engine
    per worker from the SAME model — per-instance wrappers would re-trace
    and re-compile identical prefill programs once per worker.  Model is
    frozen/hashable and holds no params, so caching it is cheap."""
    one = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    batched = model.decode_state.batched_prefill
    many = None
    if batched is not None:
        many = jax.jit(
            lambda p, toks, lens: batched(p, {"tokens": toks}, lens, max_len))
    return one, many


class ServeEngine:
    def __init__(self, model: Model, params, max_batch: int, max_len: int,
                 eos_id: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 max_prefill_batch: int = 8,
                 config: Optional[EngineConfig] = None,
                 clock=None):
        self.model = model
        self.params = params
        # the engine's notion of "now" for queue waits, deadlines and
        # latency stamps.  Standalone engines run on the wall clock; a
        # simulated fleet passes its SIM clock so Request.deadline_s is
        # evaluated against simulated seconds, not host wall time
        self._now = clock or time.perf_counter
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.config = config or EngineConfig()
        # logit width is pad_vocab(vocab); the pad columns carry real random
        # head weights, so sampling must be restricted to the true vocab
        self.vocab = int(model.cfg.vocab_size)
        self.scheduler = AdmissionScheduler(scheduler)
        self.buckets = tuple(sorted(prefill_buckets)) if prefill_buckets \
            else default_buckets(max_len)
        if self.buckets[-1] > max_len:
            raise ValueError(
                f"prefill bucket {self.buckets[-1]} exceeds max_len "
                f"{max_len}: prefilling past the cache span would drop "
                f"real prompt K/V")
        self.max_prefill_batch = max(1, min(max_prefill_batch, max_batch))
        self.slots: List[Optional[Request]] = [None] * max_batch
        # the Sampler owns the per-lane filter + PRNG state; lane_sampling
        # aliases its SoA arrays (pre-Sampler code paths mutate in place)
        self.sampler = Sampler(max_batch)
        self.lane_sampling = self.sampler.lanes
        self._rid = 0
        self.steps = 0
        self.finished: List[Request] = []

        # ALL decode state (layout, growth, sharing, snapshots) lives here
        self.backend = make_backend(model, max_batch, max_len, self.config)
        self.metrics = MetricsCollector(n_slots=max_batch,
                                        n_blocks=self.backend.n_blocks)

        self._prefill1, self._prefill_n = _shared_prefill_jits(model, max_len)

    # ------------------------------------------------------------------
    # clock
    # ------------------------------------------------------------------
    @property
    def clock(self) -> Callable[[], float]:
        """The engine's time source (wall ``time.perf_counter`` by default,
        a sim clock when constructed with ``clock=``).  Drivers pace by this
        so sim-time engines are never slept against wall time."""
        return self._now

    def now(self) -> float:
        """Current time on the engine's clock (seconds)."""
        return self._now()

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        """Queue a request; returns its rid, or None if admission control
        rejected it (queue at max_queue).

        ``sampling`` (a :class:`SamplingParams`) is the single decode-policy
        argument; loose ``temperature``/``top_k``/``top_p``/``seed`` kwargs
        are accepted as a DEPRECATED shim (see
        :func:`repro.serving.sampling.resolve_sampling`) — remaining
        ``extra`` kwargs stay model inputs as before."""
        sampling = resolve_sampling(sampling, extra)
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new, extra,
                      submitted_t=self._now(),
                      sampling=sampling or GREEDY, priority=priority,
                      deadline_s=deadline_s)
        if not self.scheduler.push(req, req.submitted_t):
            return None
        return rid

    def inject(self, req: Request, *, force: bool = False) -> bool:
        """Admit an externally-built Request (fleet routing / migration).

        ``force`` bypasses ``max_queue`` — a migrated request already owes a
        client tokens and must never be dropped at the door.  The footprint
        memo is invalidated: it was computed against another engine's
        backend state (versions are per-backend and can collide)."""
        req.fp_memo = None
        # keep locally-generated rids unique if submit() and inject() mix
        self._rid = max(self._rid, req.rid + 1)
        if force:
            self.scheduler.requeue(req)
            return True
        return self.scheduler.push(req, self._now())

    def pull_queued(self) -> List[Request]:
        """Remove and return every queued request (fleet-level re-routing
        of a drained worker's backlog).  Active lanes are untouched."""
        return self.scheduler.take_all()

    def feasible(self, req: Request) -> bool:
        """True if this engine's backend could EVER admit the request —
        the side-effect-free alloc-INFEASIBLE predicate.  Fleet migration
        checks it before moving a mid-flight request here, because a
        request that has already produced tokens must never be dropped by
        the destination's admission control."""
        return self.backend.fits(self._ctx_len(req), self._final_len(req))

    def lane_cost(self, slot: int) -> Tuple[int, int]:
        """(recompute_tokens, footprint) of an active lane — the fleet's
        cost-aware migration victim ordering.  Backends whose snapshots
        restore for free (recurrent) cost zero recompute; everything else
        pays a re-prefill of the lane's full context."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"lane {slot} is idle: no cost to report")
        recompute = 0 if self.backend.snapshot_free else self._ctx_len(req)
        return recompute, self._footprint(req)

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        """Tokens to prefill: the prompt, plus — after a preemption — every
        token generated so far, so the request resumes where it left off."""
        if not req.out_tokens:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.out_tokens, np.int32)])

    def _cache_tokens(self, req: Request) -> Optional[np.ndarray]:
        """Token content backing the request's cache positions, or None
        when positions aren't pure tokens (frontend rows / extra inputs) —
        such requests can neither hit nor feed the prefix cache."""
        if req.extra:
            return None
        return self._prefill_tokens(req)

    def _ctx_len(self, req: Request) -> int:
        """Cache positions the prefill will occupy (frontend rows included)."""
        n = len(req.prompt) + len(req.out_tokens)
        fe = req.extra.get("frontend")
        if fe is not None:
            n += fe.shape[0]
        return n

    def _final_len(self, req: Request) -> int:
        """Positions held at completion: context + every still-to-come
        token except the last (which is sampled but never written)."""
        return self._ctx_len(req) - len(req.out_tokens) + req.max_new - 1

    def _footprint(self, req: Request) -> int:
        """Admission footprint, memoized against the backend's state
        version — without this, footprint-aware pops would re-hash every
        queued prompt (prefix-cache match) on every engine step."""
        ver = self.backend.state_version
        out_len = len(req.out_tokens)
        m = req.fp_memo
        if m is not None and m[0] == out_len and m[1] == ver:
            return m[2]
        v = self.backend.token_footprint(self._ctx_len(req), req.max_new,
                                         self._cache_tokens(req))
        req.fp_memo = (out_len, ver, v)
        return v

    def _bucket_len(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        # past the largest bucket: pad to max_len rather than compiling a
        # fresh prefill executable per distinct prompt length
        return self.max_len

    def _admit_group(self, items: List[Tuple[Request, Reservation]],
                     slots: List[int], logits, group_cache, now: float,
                     widths: List[int]) -> None:
        """Sample all first tokens in ONE dispatch, then paste each lane.
        ``widths[j]`` is the prefill width request j was padded to (its
        bucket length, or its exact context length on the fallback path)."""
        ls = self.lane_sampling
        for (req, _), slot in zip(items, slots):
            ls.set_lane(slot, req.sampling)
            if req.saved_key is not None:     # resume: continue the stream
                ls.key[slot] = req.saved_key
        toks = self.sampler.sample(logits[:, :self.vocab],
                                   lanes=np.asarray(slots))
        t_first = self._now()
        for j, ((req, res), slot) in enumerate(zip(items, slots)):
            n_ctx = self._ctx_len(req)
            tok = int(toks[j])
            req.out_tokens.append(tok)
            if req.admitted_t is None:
                req.first_token_t = t_first
                self.metrics.on_admit(req, now)
            else:
                self.metrics.on_resume(req, now)
            req.admitted_t = now
            req.saved_key = None
            # paste EVERY admission — even one that finishes right here —
            # so blocks the reservation registered in the prefix cache
            # hold real content before anyone prefix-matches them
            self.backend.prefill_paste(slot, group_cache, j, n_ctx,
                                       widths[j], res)
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                # finished at admission: never occupies a decode lane
                req.done_t = t_first
                ls.clear_lane(slot)
                self.backend.release(slot, tokens=self._cache_tokens(req))
                self.finished.append(req)
                self.metrics.on_finish(req, t_first)
                continue
            self.slots[slot] = req

    def _admit(self) -> None:
        # loop: requests that finish AT admission (max_new=1 / instant EOS)
        # leave their lane idle — refill it this round, not next step
        while self._admit_once():
            pass

    def _admit_once(self) -> bool:
        """One admission round; True if a lane freed up again (re-admit)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        now = self._now()
        batch = self.scheduler.pop(
            len(free), now, footprint=self._footprint,
            budget=self.backend.budget_tokens,
            capacity=self.backend.capacity_tokens)
        if not batch:
            return False
        n_done_before = len(self.finished)

        # reserve capacity per request (allocate-on-admit): reject what can
        # never fit, spill what can't fit NOW back to the queue
        held: List[Tuple[Request, Reservation]] = []
        for i, req in enumerate(batch):
            res = self.backend.alloc(self._ctx_len(req), self._final_len(req),
                                     self._cache_tokens(req))
            if res is INFEASIBLE:
                self.scheduler.reject(req)
                continue
            if res is None:
                for r in batch[i:]:
                    self.scheduler.requeue(r)
                break
            held.append((req, res))

        # split: snapshot restores and full cache hits skip prefill wholly;
        # the rest go through batched-bucketed or exact-length prefill
        batched: List[Tuple[Request, Reservation]] = []
        fallback: List[Tuple[Request, Reservation]] = []
        for req, res in held:
            if res.n_lookup:
                self.metrics.on_prefix_lookup(res.n_cached, res.n_lookup)
            if req.saved_state is not None:
                # restore() is side-effect-free when it declines, so the
                # slot is only consumed on success
                if self.backend.restore(free[0], req.saved_state):
                    self._resume_lane(req, free.pop(0), now)
                    continue
                req.saved_state = None      # backend can't use it: recompute
            if res.full_hit:
                slot = free.pop(0)
                self.backend.activate(slot, res, self._ctx_len(req))
                self._resume_lane(req, slot, now)
                self.metrics.on_prefill_skip()
                continue
            ok = (self._prefill_n is not None and not req.extra
                  and self._ctx_len(req) <= self.max_len)
            (batched if ok else fallback).append((req, res))

        # group eligible requests by padded bucket length, then chunk each
        # group to the prefill batch limit -> one dispatch per chunk
        groups = {}
        for req, res in batched:
            groups.setdefault(self._bucket_len(self._ctx_len(req)),
                              []).append((req, res))
        for blen, items in sorted(groups.items()):
            for i in range(0, len(items), self.max_prefill_batch):
                chunk = items[i:i + self.max_prefill_batch]
                toks = np.full((len(chunk), blen), self.config.pad_id,
                               np.int32)
                lens = np.zeros((len(chunk),), np.int32)
                for j, (req, _) in enumerate(chunk):
                    seq = self._prefill_tokens(req)
                    toks[j, :len(seq)] = seq
                    lens[j] = len(seq)
                logits, group_cache = self._prefill_n(
                    self.params, jnp.asarray(toks), jnp.asarray(lens))
                self.metrics.on_prefill(len(chunk), blen * len(chunk))
                slots = [free.pop(0) for _ in chunk]
                self._admit_group(chunk, slots, logits, group_cache, now,
                                  widths=[blen] * len(chunk))
        for req, res in fallback:
            seq = self._prefill_tokens(req)
            b = {"tokens": jnp.asarray(seq[None])}
            for k, v in req.extra.items():
                b[k] = jnp.asarray(v[None])
            logits, one_cache = self._prefill1(self.params, b)
            self.metrics.on_prefill(1, self._ctx_len(req))
            self._admit_group([(req, res)], [free.pop(0)], logits, one_cache,
                              now, widths=[self._ctx_len(req)])

        return (len(self.finished) > n_done_before
                and self.scheduler.depth > 0)

    def _resume_lane(self, req: Request, slot: int, now: float) -> None:
        """Place a request on a lane WITHOUT a prefill dispatch (state
        restore or full prefix hit); its next token is produced by the
        next decode step, which feeds the last context token."""
        ls = self.lane_sampling
        ls.set_lane(slot, req.sampling)
        if req.saved_key is not None:
            ls.key[slot] = req.saved_key
        if req.admitted_t is None:
            self.metrics.on_admit(req, now)
        else:
            self.metrics.on_resume(req, now)
        req.admitted_t = now
        req.saved_key = None
        req.saved_state = None
        self.slots[slot] = req

    # ------------------------------------------------------------------
    # growth / preemption
    # ------------------------------------------------------------------
    def _pick_victim(self) -> int:
        """LIFO (recompute) policy: preempt the most recently admitted lane
        — it has the least decode work to throw away and re-prefill, and
        old requests can't be starved by a stream of newer ones."""
        cands = [i for i, r in enumerate(self.slots) if r is not None]
        return max(cands,
                   key=lambda i: (self.slots[i].admitted_t,
                                  self.slots[i].rid))

    def preempt(self, slot: int, requeue: bool = True) -> Request:
        """Evict the lane: snapshot what the backend can save cheaply,
        release its capacity, and requeue the request (which resumes
        token-identically — by restore, or by recompute-prefill).

        ``requeue=False`` returns the request WITHOUT putting it back on
        this engine's queue — the fleet hook for migrating a lane to
        another worker, where ``inject(req, force=True)`` re-admits it
        (the frozen sampler PRNG and generated-token requeue travel with
        the Request, so the resume is token-identical on any engine
        serving the same model/params)."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"lane {slot} is idle: nothing to preempt")
        req.preemptions += 1
        req.saved_key = self.lane_sampling.key[slot].copy()
        req.saved_state = self.backend.snapshot(slot)
        self.backend.release(slot, tokens=self._cache_tokens(req))
        self.slots[slot] = None
        self.lane_sampling.clear_lane(slot)
        if requeue:
            self.scheduler.requeue(req)
        self.metrics.on_preempt(req)
        return req

    def forget_lane(self, slot: int) -> Request:
        """Release a lane whose DEVICE is gone (worker death): free the
        host-side bookkeeping without touching device state.  Unlike
        :meth:`preempt` it snapshots nothing (the device that held the
        state is unreachable) and registers no token content into the
        prefix cache (K/V that died with the device must never be
        offered as a cache hit).  Returns the request for the failover
        plane, which restores ``saved_key`` / ``saved_state`` from its
        last lane checkpoint before re-injecting it elsewhere."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"lane {slot} is idle: nothing to forget")
        req.preemptions += 1
        self.slots[slot] = None
        self.lane_sampling.clear_lane(slot)
        self.backend.release(slot)
        self.metrics.on_preempt(req)
        return req

    def _prepare_lanes(self) -> None:
        """Before a decode step, every active lane must have a writable
        private block at its next position (grow / COW-split / uncache —
        see ``CacheBackend.prepare_lane``); exhaustion preempts victims
        (possibly the needy lane itself) until it frees."""
        for slot in range(self.max_batch):
            if self.slots[slot] is None:
                continue
            while not self.backend.prepare_lane(slot):
                victim = self._pick_victim()
                self.preempt(victim)
                if victim == slot:
                    break

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> int:
        """Admit + one decode step for all active lanes. Returns #active."""
        # grow RUNNING lanes before admission takes the last free blocks —
        # else a fresh admission pays a whole prefill only to be the LIFO
        # victim of an older lane's growth this same step
        self._prepare_lanes()
        self._admit()
        # second pass covers lanes admitted above whose context ends
        # exactly on a block boundary, plus full-hit lanes whose first
        # write lands in a shared block (COW split)
        self._prepare_lanes()
        if self.active() == 0:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            # normally the lane's last sampled token; a lane admitted
            # without prefill (restore / full hit) re-feeds its last
            # context token to produce the next logits
            toks[i, 0] = req.out_tokens[-1] if req.out_tokens \
                else req.prompt[-1]
        active = np.asarray([s is not None for s in self.slots])
        logits = self.backend.step(self.params, toks, active)
        ls = self.lane_sampling
        # one host transfer per step: Sampler.sample returns host numpy;
        # tolist() converts the whole batch at once so the per-lane loop
        # below never touches an array element-wise (repro-lint R004)
        nxt = self.sampler.sample(logits[:, :self.vocab]).tolist()
        now = self._now()
        busy = self.active()          # before the finish-scan frees lanes
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = nxt[i]
            req.out_tokens.append(tok)
            if req.first_token_t is None:   # prefill-skipping admissions
                req.first_token_t = now
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                req.done_t = now
                self.slots[i] = None                # lane freed immediately
                ls.clear_lane(i)
                self.backend.release(i, tokens=self._cache_tokens(req))
                self.finished.append(req)
                self.metrics.on_finish(req, now)
        self.steps += 1
        self.metrics.on_step(self.scheduler.depth, busy, now,
                             blocks_in_use=self.backend.blocks_in_use)
        return self.active()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            # step() admits first, so one call per iteration does both
            if self.step() == 0 and not self.scheduler.depth:
                break
        else:
            if self.active() or self.scheduler.depth:
                warnings.warn(
                    f"run_until_drained exhausted max_steps={max_steps} "
                    f"with {self.active()} active lanes and "
                    f"{self.scheduler.depth} queued requests — returning "
                    f"PARTIAL results ({len(self.finished)} finished)",
                    RuntimeWarning, stacklevel=2)
        return self.finished

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Waiting requests in current admission order."""
        return self.scheduler.peek_order()

    def reset_stats(self) -> None:
        """Drop finished/rejected/expired records and metrics counters —
        e.g. after a jit warm-up pass — without touching lanes or queue."""
        self.finished.clear()
        self.scheduler.rejected.clear()
        self.scheduler.expired.clear()
        self.scheduler.rejected_total = 0
        self.scheduler.expired_total = 0
        self.steps = 0
        self.metrics = MetricsCollector(n_slots=self.max_batch,
                                        n_blocks=self.backend.n_blocks)
        self.backend.reset_counters()

    def metrics_snapshot(self) -> EngineSnapshot:
        return self.metrics.snapshot(
            queue_depth_now=self.scheduler.depth,
            rejected=self.scheduler.rejected_total,
            expired=self.scheduler.expired_total,
            kv_blocks_peak=self.backend.peak_blocks,
            kv_shared_blocks_peak=self.backend.shared_blocks_peak,
            cow_splits=self.backend.cow_splits,
            cache_evictions=self.backend.cache_evictions)
