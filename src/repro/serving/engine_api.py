"""The DecodeEngine protocol: the engine surface the fleet actually uses.

`ServingFleet`, `drive_sim` and the benches historically duck-typed
against :class:`~repro.serving.engine.ServeEngine`; with three engine
implementations (plain, pipeline-split, speculative) the contract is now
explicit.  An engine is anything that:

* takes work — ``submit`` (client entry, decode policy via
  ``SamplingParams``), ``inject`` (fleet routing / migration),
  ``pull_queued`` (backlog re-routing), ``feasible`` (admission
  pre-check for migration);
* advances — ``step`` (admit + one decode round; returns #active lanes)
  and the convenience ``run_until_drained``;
* yields lanes back — ``preempt`` (token-identical eviction, optionally
  returning the Request for migration) and ``lane_cost`` (victim
  ordering for cost-aware migration);
* reports — ``active``, ``metrics_snapshot`` / ``reset_stats``.

The protocol is methods-only (``@runtime_checkable`` ``isinstance``
checks look at methods, not attributes); the data attributes every
engine must also carry — the fleet reads them directly — are listed in
:data:`REQUIRED_ATTRS` and asserted by the conformance test.
"""

from __future__ import annotations

from typing import Any, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

import numpy as np

from repro.serving.metrics import EngineSnapshot
from repro.serving.sampling import SamplingParams

# data attributes the fleet reads off an engine besides the methods below
# (checked by hasattr in the conformance test; Protocols can't require
# instance attributes under runtime_checkable)
REQUIRED_ATTRS = ("scheduler", "slots", "finished", "max_batch", "metrics")


@runtime_checkable
class DecodeEngine(Protocol):
    """Structural type of every serving engine (plain / pipeline / spec)."""

    def submit(self, prompt: np.ndarray, max_new: int = ...,
               sampling: Optional[SamplingParams] = ..., priority: int = ...,
               deadline_s: Optional[float] = ..., **extra) -> Optional[int]:
        ...

    def inject(self, req, *, force: bool = ...) -> bool:
        ...

    def pull_queued(self) -> List:
        ...

    def feasible(self, req) -> bool:
        ...

    def preempt(self, slot: int, requeue: bool = ...):
        ...

    def forget_lane(self, slot: int):
        ...

    def lane_cost(self, slot: int) -> Tuple[int, int]:
        ...

    def active(self) -> int:
        ...

    def step(self) -> Any:
        ...

    def run_until_drained(self, max_steps: int = ...) -> List:
        ...

    def reset_stats(self) -> None:
        ...

    def metrics_snapshot(self) -> EngineSnapshot:
        ...
