"""Training plane: federated serve-while-train rounds over the fleet.

The paper's headline result is *training* acceleration from idle phone
compute.  This module runs it on the serving fleet without a second
scheduler: a :class:`FedRoundCoordinator` wraps a
:class:`~repro.serving.fleet.ServingFleet` and schedules device-scored
federated rounds into the workers' idle duty-cycle gaps.

Round lifecycle (all times simulated):

1. **Select** — among replica workers that are up, thermally at or below
   ``max_thermal_rank`` and serving-idle, pick the best
   ``participants`` by the same score shape routing uses (coolest, least
   backlog, fastest, name tiebreak).
2. **Local steps** — each participant runs ``local_steps`` real jitted
   steps through the existing :class:`~repro.runtime.trainer.Trainer`
   step machinery (fault-checked, thermally observed, timed on the
   fleet's SIM clock) over its own seeded synthetic shard
   (:class:`~repro.data.synthetic.TokenPipeline`; deterministic in
   ``(seed, step, shard)``).  Like the pipeline/spec planes, compute is
   EAGER — results only become visible when the sim-time charges are
   paid.
3. **Charge** — the local compute is charged against the SAME per-tick
   credit budget decode spends (``acc_s``), only in ticks where the
   worker has no serving work and is thermally eligible — backlog or a
   SERIOUS thermal state preempts training instantly.  The encoded
   update (:func:`repro.optim.fed.encode_update` — int8+error-feedback
   or bf16 wire frames) is then charged against the worker's link; a
   frame can stay in flight across ticks.
4. **Aggregate** — when every participant has delivered or failed (or
   the round deadline passes), the coordinator applies sample-weighted
   fed-avg (:func:`repro.optim.fed.fed_avg`) over the DELIVERED frames
   in fixed sorted-name order — bit-deterministic under a seeded trace.
   A participant that died mid-round (PR 9's failure plane: crash, or a
   partition that outlived the round) is excluded from the weights; a
   partition that heals before the deadline resumes paying its charges
   and contributes normally.

The trained model is the coordinator's own ``params`` — deliberately
separate from the fleet's serving params, so serving streams stay
token-identical with the training plane on or off (asserted in tests);
only serving *timing* may shift, which the bench bounds via SLO
attainment A/B against a serve-only baseline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.synthetic import DataConfig, TokenPipeline
from repro.models.api import Model
from repro.optim import fed
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.fleet import ServingFleet, _Worker

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Knobs of the federated serve-while-train plane."""
    rounds: int = 4                 # target rounds (the plane stops after)
    local_steps: int = 2            # jitted steps per participant per round
    participants: int = 2           # selection target (fewer if ineligible)
    batch: int = 4                  # per-participant batch size
    seq_len: int = 32
    lr: float = 0.3                 # local SGD learning rate
    seed: int = 0                   # data + init seed
    mode: str = "int8_ef"           # update frames: "int8_ef" | "bf16"
    topk_frac: Optional[float] = 0.5   # int8_ef sparsity (EF keeps the rest)
    train_flops_mult: float = 3.0   # fwd+bwd+update cost vs one forward
    max_thermal_rank: int = 2       # preempt at SERIOUS (rank 2) or worse
    round_timeout_s: float = 60.0   # sim deadline before stragglers drop


@dataclasses.dataclass(frozen=True)
class FedRoundSnapshot:
    """One completed round, frozen (repro-lint R006: immutable outside
    this module)."""
    round_id: int
    t_begin: float
    t_end: float
    participants: Tuple[str, ...]
    delivered: Tuple[str, ...]
    excluded: Tuple[str, ...]
    samples: int                 # sequences behind the applied update
    wire_bytes: int              # fed frame bytes charged on links
    train_s: float               # sim compute seconds charged for training
    loss_first: float            # mean first-local-step loss (delivered)
    loss_last: float             # mean last-local-step loss (delivered)


@functools.lru_cache(maxsize=16)
def _local_sgd_step(model: Model, lr: float):
    """Shared jitted local-SGD step per (model, lr) — FedAvg's classic
    local optimiser, and R001-compliant (one trace serves every
    participant and every round)."""
    @jax.jit
    def step_fn(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p, b: model.loss(p, b), has_aux=True)(params, batch)
        new = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - lr * gg.astype(jnp.float32)).astype(p.dtype),
            params, g)
        return new, opt, {"loss": loss}

    return step_fn


class _RoundState:
    """Mutable in-flight state of one participant's round leg."""

    def __init__(self, name: str, samples: int, frame: bytes,
                 comp_cold_s: float, link_s: float, new_error: Any,
                 losses: List[float]):
        self.name = name
        self.samples = samples
        self.frame = frame
        self.comp_rem = comp_cold_s   # cold compute seconds still unpaid
        self.link_rem = link_s        # wire seconds still unpaid
        self.frame_charged = False    # bytes counted when compute finishes
        self.new_error = new_error    # EF state, committed on delivery
        self.losses = losses
        self.delivered = False
        self.failed = False

    @property
    def resolved(self) -> bool:
        return self.delivered or self.failed


class FedRoundCoordinator:
    """Runs federated rounds inside a fleet's idle duty-cycle gaps.

    Drive it exactly like the fleet: ``coord.tick()`` advances the fleet
    one tick then pays/collects training charges; ``sim_t`` / ``idle`` /
    ``completed`` delegate, so :func:`repro.serving.fleet.drive_sim`
    accepts a coordinator wherever it accepts a fleet."""

    def __init__(self, fleet: ServingFleet, model: Model, cfg: FedConfig,
                 params: Any = None):
        if not fleet.workers:
            raise ValueError("the training plane needs replica workers "
                             "(stage groups / spec pairs serve one model "
                             "across members and do not train)")
        self.fleet = fleet
        self.model = model
        self.cfg = cfg
        self.params = params if params is not None \
            else model.init(jax.random.key(cfg.seed))
        self._step_fn = _local_sgd_step(model, cfg.lr)
        # stable shard index per worker name: every participant trains on
        # its OWN slice of one shared bigram task (same transition table,
        # disjoint deterministic streams)
        names = sorted(w.name for w in fleet.workers)
        self._shard_of = {n: i for i, n in enumerate(names)}
        dcfg = DataConfig(vocab_size=model.cfg.vocab_size,
                          seq_len=cfg.seq_len,
                          global_batch=cfg.batch * len(names),
                          seed=cfg.seed)
        self._data = {n: TokenPipeline(dcfg, shard=self._shard_of[n],
                                       n_shards=len(names)) for n in names}
        self._trainer = {
            n: Trainer(TrainerConfig(worker_name=n), self._step_fn,
                       clock=fleet._sim_now)
            for n in names}
        self._error: Dict[str, Any] = {}      # persistent EF state per worker
        self._active: List[_RoundState] = []
        self._round_t0 = 0.0
        self._deadline = 0.0
        self.rounds: List[FedRoundSnapshot] = []
        self.rounds_done = 0
        self.deliveries = 0
        self.exclusions = 0
        self.wire_bytes_total = 0
        self.train_s_total = 0.0
        self.preempt_ticks = 0

    # -- drive_sim duck-typing -----------------------------------------
    @property
    def sim_t(self) -> float:
        return self.fleet.sim_t

    @property
    def completed(self):
        return self.fleet.completed

    def idle(self) -> bool:
        return self.fleet.idle() and not self._active

    def submit(self, *args, **kwargs):
        return self.fleet.submit(*args, **kwargs)

    def tick(self) -> None:
        self.fleet.tick()
        self._advance()

    def run_rounds(self, max_ticks: int = 100_000) -> List[FedRoundSnapshot]:
        """Tick until the configured rounds complete (serving traffic, if
        any, interleaves through the shared tick)."""
        for _ in range(max_ticks):
            if self.rounds_done >= self.cfg.rounds:
                break
            self.tick()
        return self.rounds

    # -- round machinery -----------------------------------------------
    def _worker(self, name: str) -> _Worker:
        w = self.fleet.worker(name)
        assert isinstance(w, _Worker)
        return w

    def _eligible(self, w: _Worker) -> bool:
        f = self.fleet
        return (not f._is_down(w.name)
                and f.thermal_rank(w.name) <= self.cfg.max_thermal_rank
                and w.engine.scheduler.depth == 0
                and w.engine.active() == 0)

    def _advance(self) -> None:
        if not self._active:
            if self.rounds_done < self.cfg.rounds:
                self._start_round()
            return
        self._pay()
        if (self.sim_t >= self._deadline
                and any(not p.resolved for p in self._active)):
            for p in self._active:
                if not p.resolved:
                    p.failed = True
        if all(p.resolved for p in self._active):
            self._finish_round()

    def _start_round(self) -> None:
        f = self.fleet
        cands = [w for w in f.workers if self._eligible(w)]
        if not cands:
            return

        def score(w: _Worker):
            backlog = w.engine.scheduler.depth + w.engine.active()
            return (f.thermal_rank(w.name), backlog, -w.rate, w.name)

        picked = sorted(cands, key=score)[:self.cfg.participants]
        cfg = self.cfg
        rid = self.rounds_done
        self._round_t0 = self.sim_t
        self._deadline = self.sim_t + cfg.round_timeout_s
        self._active = []
        for w in sorted(picked, key=lambda w: w.name):
            # EAGER local training (the charge queue paces delivery, like
            # the pipeline/spec planes): local_steps jitted steps from the
            # current global params on this worker's seeded shard
            p_local, opt = self.params, {}
            losses: List[float] = []
            tr = self._trainer[w.name]
            for k in range(cfg.local_steps):
                step = rid * cfg.local_steps + k
                batch = self._data[w.name].batch(step)
                p_local, opt, rec = tr.train_step(p_local, opt, batch, step)
                losses.append(rec["loss"])
            delta = fed.tree_delta(p_local, self.params)
            frame, new_err = fed.encode_update(
                delta, mode=cfg.mode, error=self._error.get(w.name),
                topk_frac=cfg.topk_frac)
            samples = cfg.local_steps * cfg.batch
            comp_cold = (cfg.local_steps * cfg.train_flops_mult
                         * cfg.batch * cfg.seq_len / w.prefill_rate)
            link_s = len(frame) / w.spec.profile.link_bw
            self._active.append(_RoundState(
                w.name, samples, frame, comp_cold, link_s, new_err, losses))

    def _pay(self) -> None:
        f = self.fleet
        tick_s = f.tick_s
        for p in self._active:
            if p.resolved:
                continue
            if p.name in f._dead:
                # heartbeat-declared dead (crash, or partition past
                # detection that never returned): excluded from this round
                p.failed = True
                continue
            if f._is_down(p.name):
                continue             # down but undetected: no progress yet
            w = self._worker(p.name)
            if (f.thermal_rank(p.name) > self.cfg.max_thermal_rank
                    or w.engine.scheduler.depth > 0
                    or w.engine.active() > 0):
                self.preempt_ticks += 1   # serving or thermal preemption
                continue
            if p.comp_rem > _EPS:
                # training compute spends the SAME credit decode earns
                cost_now = p.comp_rem * w.slowdown
                pay = min(cost_now, max(w.acc_s, 0.0))
                if pay > 0.0:
                    w.acc_s -= pay
                    p.comp_rem -= pay / w.slowdown
                    self.train_s_total += pay
                    # training heats the device like any other busy time:
                    # next tick's thermal advance sees the added util
                    w.util = min(w.util + pay / tick_s, 1.0)
                if p.comp_rem > _EPS:
                    continue
            if not p.frame_charged:
                p.frame_charged = True
                self.wire_bytes_total += len(p.frame)
            # the update frame rides the link in parallel with compute
            # budgets elsewhere: up to one tick of wire time per tick,
            # in-flight across ticks when it outruns the budget
            pay_l = min(p.link_rem, tick_s)
            p.link_rem -= pay_l
            if p.link_rem <= _EPS:
                p.delivered = True

    def _finish_round(self) -> None:
        delivered = [p for p in self._active if p.delivered]
        excluded = [p for p in self._active if p.failed]
        updates = [fed.ClientUpdate(p.name, p.samples, p.frame)
                   for p in delivered]
        avg = fed.fed_avg(updates) if updates else None
        self.params = fed.apply_update(self.params, avg)
        for p in delivered:
            if self.cfg.mode == "int8_ef":
                self._error[p.name] = p.new_error
        n = len(delivered)
        snap = FedRoundSnapshot(
            round_id=self.rounds_done,
            t_begin=self._round_t0,
            t_end=self.sim_t,
            participants=tuple(p.name for p in self._active),
            delivered=tuple(p.name for p in delivered),
            excluded=tuple(p.name for p in excluded),
            samples=sum(p.samples for p in delivered),
            wire_bytes=sum(len(p.frame) for p in delivered),
            train_s=self.train_s_total,
            loss_first=(sum(p.losses[0] for p in delivered) / n
                        if n else float("nan")),
            loss_last=(sum(p.losses[-1] for p in delivered) / n
                       if n else float("nan")))
        self.rounds.append(snap)
        self.rounds_done += 1
        self.deliveries += n
        self.exclusions += len(excluded)
        self._active = []


__all__ = ["FedConfig", "FedRoundSnapshot", "FedRoundCoordinator"]
