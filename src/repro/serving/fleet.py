"""Thermal-aware heterogeneous serving fleet (paper §4.2 + §5.2, serving).

The paper's core claim is that a weak host plus a thermally-throttled phone
can serve real workloads; its §5.2 mitigations (swap / duty-cycle /
rebalance) were implemented for the *training* runtime in
:mod:`repro.runtime.elastic`.  This module puts the same machinery under
**live serving traffic**: a :class:`ServingFleet` runs one
:class:`~repro.serving.engine.ServeEngine` per simulated heterogeneous
worker, paced in *simulated time* by the worker's
:class:`~repro.hw.specs.DeviceProfile` serving rates
(``decode_steps_per_s`` / ``prefill_tokens_per_s``), and

* **routes** each admission to the worker with the coolest thermal state
  and the shortest estimated backlog (free backend capacity breaks ties);
* feeds per-step latency telemetry into a
  :class:`~repro.runtime.monitor.ThermalMonitor` — the paper's EWMA
  state machine now watches serving steps instead of training batches;
* executes :class:`~repro.runtime.elastic.ServingElasticPolicy` actions:
  a SERIOUS worker is **duty-cycled** (fewer decode steps per fleet tick),
  **drained** (new admissions routed away) or has its lanes **migrated** —
  ``engine.preempt(slot, requeue=False)`` releases the lane
  token-identically (frozen sampler PRNG + generated-token requeue) and
  ``inject(req, force=True)`` re-admits it on a cooler worker.  With
  content-addressed prefix caching enabled on the target, the migration
  re-prefill of shared-scaffold traffic is a near-full cache hit.

Simulation semantics: :meth:`ServingFleet.tick` advances simulated time by
``tick_s``.  A worker earns ``tick_s * duty`` seconds of compute per tick
and spends it on decode steps (``slowdown / decode_rate`` seconds each)
and prefill work (``prefilled_tokens * slowdown / prefill_rate``), where
``slowdown`` comes from a pluggable throttle model:

* :class:`ThrottleTrace` — exogenous per-worker ramp (paper Fig. 6 shape:
  plateau approach with a time constant), for deterministic benches;
* :class:`ThermalReservoir` — closed loop: heat integrates utilisation
  with the profile's ``thermal_tau_s``, idle time dissipates it, and
  slowdown ramps to ``1 / thermal_sustained`` at full heat — so
  duty-cycling genuinely cools a worker.

The engines' own latency metrics (TTFT/TPOT) remain wall-clock and are
meaningless under simulation; fleet-level **goodput** (completed tokens
per simulated second, total and per worker), migration counts and
thermal-state occupancy are the numbers to read
(:meth:`ServingFleet.snapshot`).  Request deadlines are engine-level and
stay wall-clock.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.hw.specs import DeviceProfile
from repro.models.api import Model
from repro.runtime.elastic import Action, ServingElasticPolicy
from repro.runtime.monitor import ThermalMonitor, ThermalState
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.metrics import EngineSnapshot
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.scheduler import SchedulerConfig


# ---------------------------------------------------------------------------
# throttle models
# ---------------------------------------------------------------------------
class NullThrottle:
    """No throttling: every worker always runs at its cold rate."""

    def advance(self, worker: str, dt: float, util: float) -> float:
        return 1.0


class ThrottleTrace:
    """Exogenous per-worker slowdown trace (paper Fig. 6 ramp shape).

    ``ramps`` maps worker name -> ``(start_s, factor, tau_s)``: from
    ``start_s`` of simulated time the slowdown approaches ``factor`` with
    time constant ``tau_s``.  Utilisation is ignored — the trace is the
    same whatever the policies do, which is exactly what a policies-on vs
    policies-off A/B needs.
    """

    def __init__(self, ramps: Dict[str, Tuple[float, float, float]]):
        self.ramps = dict(ramps)
        self._t: Dict[str, float] = {}

    def advance(self, worker: str, dt: float, util: float) -> float:
        t = self._t.get(worker, 0.0) + dt
        self._t[worker] = t
        if worker not in self.ramps:
            return 1.0
        start, factor, tau = self.ramps[worker]
        if t < start:
            return 1.0
        ramp = 1.0 - math.exp(-(t - start) / max(tau, 1e-9))
        return 1.0 + (factor - 1.0) * ramp


class ThermalReservoir:
    """Closed-loop thermal model driven by the profiles' §4.2 fields.

    Heat ``h`` in [0, 1] integrates utilisation with time constant
    ``thermal_tau_s`` and dissipates while idle (``cool_frac`` scales the
    cooling time constant); slowdown ramps to ``1 / thermal_sustained`` at
    full heat.  Duty-cycling a worker really cools it here — this is the
    model under which the §5.2 duty-cycle mitigation earns its keep.
    """

    def __init__(self, profiles: Dict[str, DeviceProfile],
                 cool_frac: float = 0.5):
        self.profiles = dict(profiles)
        self.cool_frac = cool_frac
        self.heat: Dict[str, float] = {}

    def advance(self, worker: str, dt: float, util: float) -> float:
        p = self.profiles.get(worker)
        if p is None or not math.isfinite(p.thermal_tau_s):
            return 1.0
        tau = max(p.thermal_tau_s, 1e-9)
        h = self.heat.get(worker, 0.0)
        h += dt * (util / tau
                   - (1.0 - util) * h / (tau * max(self.cool_frac, 1e-9)))
        h = min(max(h, 0.0), 1.0)
        self.heat[worker] = h
        return 1.0 + (1.0 / p.thermal_sustained - 1.0) * h


# ---------------------------------------------------------------------------
# fleet
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One simulated worker: a device profile plus engine sizing."""
    name: str
    profile: DeviceProfile
    max_batch: int = 4
    engine_config: Optional[EngineConfig] = None    # None = fleet default
    scheduler: Optional[SchedulerConfig] = None     # None = fleet default


@dataclasses.dataclass(frozen=True)
class CompletedRecord:
    """A finished request with fleet-level context."""
    req: Request
    worker: str                  # worker it FINISHED on
    sim_t: float                 # simulated completion time
    migrated: bool               # ever moved between workers


@dataclasses.dataclass(frozen=True)
class WorkerSnapshot:
    name: str
    profile: str
    engine: EngineSnapshot
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float      # tokens finished here / sim seconds
    steps_run: int
    duty: float
    drained: bool
    thermal_state: str
    slowdown: float
    state_occupancy: Dict[str, float]


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    sim_t: float
    ticks: int
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float      # completed tokens / sim seconds
    migrations: int                  # lane moves (preempt here, resume there)
    migrated_requests: int           # unique requests whose decode ever
    #                                  moved workers (lane moves + queued
    #                                  mid-flight moves)
    queue_moves: int                 # queued requests re-routed off a worker
    drains: int
    undrains: int
    rejected: int
    expired: int
    per_worker: Dict[str, WorkerSnapshot]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class _Worker:
    """Mutable runtime state the fleet keeps per WorkerSpec."""

    def __init__(self, spec: WorkerSpec, engine: ServeEngine):
        self.spec = spec
        self.engine = engine
        self.rate = spec.profile.decode_rate()
        self.prefill_rate = spec.profile.prefill_rate()
        self.duty = 1.0
        self.drained = False
        self.acc_s = 0.0             # unspent compute credit, seconds
        self.util = 0.0              # last tick's busy fraction
        self.slowdown = 1.0
        self.steps_run = 0
        self.n_collected = 0         # engine.finished entries already seen

    @property
    def name(self) -> str:
        return self.spec.name

    def free_fraction(self) -> float:
        """Free capacity in [0, 1]: pool budget fraction for budgeted
        backends (paged), free-lane fraction otherwise."""
        eng = self.engine
        budget = eng.backend.budget_tokens
        cap = eng.backend.capacity_tokens
        if budget is not None and cap:
            return budget / cap
        return (eng.max_batch - eng.active()) / eng.max_batch


class ServingFleet:
    """One ServeEngine per heterogeneous worker + thermal-aware routing.

    All workers serve the same ``(model, params)`` — the fleet is a replica
    set, not a pipeline split (that is the next step on the roadmap).  Each
    engine owns its own cache backend, i.e. its own device memory.
    """

    def __init__(self, model: Model, params,
                 workers: Sequence[WorkerSpec], *,
                 max_len: int = 64,
                 tick_s: float = 0.05,
                 monitor: Optional[ThermalMonitor] = None,
                 policy: Optional[ServingElasticPolicy] = None,
                 throttle=None,
                 engine_config: Optional[EngineConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 thermal_routing: bool = True):
        if not workers:
            raise ValueError("a fleet needs at least one worker")
        names = [w.name for w in workers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate worker names: {names}")
        self.tick_s = tick_s
        self.monitor = monitor or ThermalMonitor(
            alpha=0.25, calibration_steps=3, warmup_skip=0)
        self.policy = policy
        self.throttle = throttle or NullThrottle()
        # False = route on capacity/backlog alone (the thermally-naive
        # baseline a policies-off A/B measures against)
        self.thermal_routing = thermal_routing
        self.workers: List[_Worker] = []
        for spec in workers:
            eng = ServeEngine(
                model, params, max_batch=spec.max_batch, max_len=max_len,
                scheduler=spec.scheduler or scheduler,
                prefill_buckets=prefill_buckets,
                config=spec.engine_config or engine_config)
            self.workers.append(_Worker(spec, eng))
        self._by_name = {w.name: w for w in self.workers}
        self.sim_t = 0.0
        self.ticks = 0
        self._rid = 0
        self.completed: List[CompletedRecord] = []
        self.routed: Dict[int, str] = {}      # rid -> first worker routed to
        self.action_log: List[Tuple[float, Action]] = []   # (sim_t, action)
        self.migrations = 0
        self.queue_moves = 0
        self.drains = 0
        self.undrains = 0
        self.routing_rejected = 0    # no routable worker could queue it
        self._migrated_rids: Set[int] = set()

    # ------------------------------------------------------------------
    # admission routing
    # ------------------------------------------------------------------
    def worker(self, name: str) -> _Worker:
        return self._by_name[name]

    def _state_rank(self, name: str) -> int:
        ws = self.monitor.workers.get(name)
        order = list(ThermalState)
        return order.index(ws.state) if ws else 0

    def _route_order(self, exclude: Optional[_Worker] = None) -> List[_Worker]:
        """Workers best-first: non-drained coolest state, then shortest
        estimated backlog (queued + active work over the worker's cold
        rate), then most free backend capacity.  All-drained fleets fall
        back to every worker — admissions queue rather than vanish."""
        cands = [w for w in self.workers
                 if w is not exclude and not w.drained]
        if not cands:
            cands = [w for w in self.workers if w is not exclude]

        def score(w: _Worker):
            backlog = (w.engine.scheduler.depth + w.engine.active()) / w.rate
            rank = self._state_rank(w.name) if self.thermal_routing else 0
            return (rank, backlog, -w.free_fraction(), w.name)

        return sorted(cands, key=score)

    def submit(self, prompt, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        """Route one request to the best worker; returns a fleet-wide rid,
        or None if every routable worker's queue is full."""
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new, extra,
                      submitted_t=time.perf_counter(),
                      sampling=sampling or GREEDY, priority=priority,
                      deadline_s=deadline_s)
        fallback = None
        for w in self._route_order():
            # probe capacity BEFORE inject: a push into a full queue would
            # record a per-engine rejection for a request another worker
            # then admits (one fleet admission must count at most once)
            mq = w.engine.scheduler.config.max_queue
            if mq is not None and w.engine.scheduler.depth >= mq:
                continue
            if fallback is None:
                fallback = w
            # don't route onto a backend that can never hold the final
            # footprint while a worker that can is standing by
            if not w.engine.feasible(req):
                continue
            if w.engine.inject(req):
                self.routed[rid] = w.name
                return rid
        if fallback is not None and fallback.engine.inject(req):
            # no worker fits it: queue it anyway so the backend's alloc —
            # the authority on infeasibility — records the rejection
            self.routed[rid] = fallback.name
            return rid
        self.routing_rejected += 1
        return None

    # ------------------------------------------------------------------
    # simulation
    # ------------------------------------------------------------------
    def _collect_finished(self, w: _Worker) -> None:
        done = w.engine.finished
        for req in done[w.n_collected:]:
            self.completed.append(CompletedRecord(
                req, w.name, self.sim_t, req.rid in self._migrated_rids))
        w.n_collected = len(done)

    def _advance_worker(self, w: _Worker) -> None:
        w.slowdown = self.throttle.advance(w.name, self.tick_s, w.util)
        step_s = w.slowdown / w.rate
        w.acc_s = min(w.acc_s + self.tick_s * w.duty, self.tick_s + step_s)
        busy_s = 0.0
        while w.acc_s >= step_s:
            if not w.engine.active() and not w.engine.scheduler.depth:
                # idle: credit does not bank beyond one immediate step
                w.acc_s = min(w.acc_s, step_s)
                break
            tok0 = w.engine.metrics.prefill_tokens
            w.engine.step()
            self._collect_finished(w)
            prefill_s = ((w.engine.metrics.prefill_tokens - tok0)
                         * w.slowdown / w.prefill_rate)
            w.acc_s -= step_s + prefill_s
            busy_s += step_s + prefill_s
            w.steps_run += 1
        w.util = min(busy_s / self.tick_s, 1.0)
        # synthetic telemetry: the per-step latency this worker would have
        # reported this tick (a real fleet observes each executed step and
        # probes drained workers to notice recovery)
        self.monitor.observe(w.name, step_s)

    def tick(self) -> None:
        """Advance simulated time by ``tick_s``: run every worker's share
        of decode steps, feed telemetry, then apply policy actions."""
        self.sim_t += self.tick_s
        self.ticks += 1
        for w in self.workers:
            self._advance_worker(w)
        if self.policy is not None:
            actions = self.policy.step(self.monitor)
            # duty is re-asserted every tick while a worker is hot; a
            # worker the policy stopped mentioning runs full-duty again
            asserted = {a.worker for a in actions if a.kind == "duty_cycle"}
            for w in self.workers:
                if w.name not in asserted:
                    w.duty = 1.0
            self._apply(actions)

    def idle(self) -> bool:
        return all(not w.engine.active() and not w.engine.scheduler.depth
                   for w in self.workers)

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[CompletedRecord]:
        for _ in range(max_ticks):
            if self.idle():
                break
            self.tick()
        else:
            if not self.idle():
                warnings.warn(
                    f"fleet run_until_drained exhausted max_ticks="
                    f"{max_ticks} with work outstanding — returning "
                    f"PARTIAL results ({len(self.completed)} finished)",
                    RuntimeWarning, stacklevel=2)
        return self.completed

    # ------------------------------------------------------------------
    # elastic actions
    # ------------------------------------------------------------------
    def drain(self, name: str) -> None:
        """Route new admissions away from ``name`` (its queue still drains
        through it, and its active lanes keep decoding)."""
        w = self._by_name[name]
        if not w.drained:
            w.drained = True
            self.drains += 1

    def undrain(self, name: str) -> None:
        w = self._by_name[name]
        if w.drained:
            w.drained = False
            self.undrains += 1

    def migrate(self, name: str, queued: bool = True) -> int:
        """Move ``name``'s decode lanes (and optionally its queued backlog)
        to the best other workers.  Token-identity is the engine's
        preempt/resume contract; the move count is returned.

        A destination must pass ``engine.feasible(req)`` — a mid-flight
        request moved onto a worker whose backend can never hold its
        final footprint would be REJECTED there, i.e. silently dropped.
        Mid-flight requests (tokens already owed to a client) may bypass
        the destination's ``max_queue``; never-admitted queued backlog
        may not — admission control survives migration.  A lane with no
        acceptable destination is NOT preempted: it keeps decoding (and
        its cache state) on ``name`` rather than paying a re-prefill to
        go nowhere."""
        src = self._by_name[name]
        targets = self._route_order(exclude=src)
        if not targets or all(t.drained for t in targets):
            return 0

        def has_room(t: _Worker) -> bool:
            mq = t.engine.scheduler.config.max_queue
            return mq is None or t.engine.scheduler.depth < mq

        def dest_for(req, mid_flight: bool) -> Optional[_Worker]:
            return next(
                (t for t in self._route_order(exclude=src)
                 if t.engine.feasible(req) and (mid_flight or has_room(t))),
                None)

        moved = 0
        occupied = [i for i, r in enumerate(src.engine.slots)
                    if r is not None]
        for slot in occupied:
            # pick the destination BEFORE preempting: evicting a lane
            # that has nowhere to go would throw away its cache state
            # (and pay a re-prefill) just to put it back in line here
            dst = dest_for(src.engine.slots[slot], mid_flight=True)
            if dst is None:
                continue
            req = src.engine.preempt(slot, requeue=False)
            dst.engine.inject(req, force=True)
            self._migrated_rids.add(req.rid)
            self.migrations += 1
            moved += 1
        if queued:
            stay = []
            for req in src.engine.pull_queued():
                mid_flight = req.admitted_t is not None
                dst = dest_for(req, mid_flight)
                if dst is None:
                    stay.append(req)
                    continue
                # room/feasibility verified above; force skips the push
                # path so the probe can't record a spurious rejection
                dst.engine.inject(req, force=True)
                if mid_flight:
                    # a preempted-then-requeued request moved here will
                    # resume cross-engine: that IS a migration
                    self._migrated_rids.add(req.rid)
                self.queue_moves += 1
                moved += 1
            for req in stay:
                src.engine.inject(req, force=True)
        return moved

    def _apply(self, actions: Sequence[Action]) -> None:
        for a in actions:
            if a.worker not in self._by_name:
                # a shared ThermalMonitor may track non-fleet workers
                # (another fleet, the training pipeline); not ours to act on
                continue
            self.action_log.append((self.sim_t, a))
            if a.kind == "duty_cycle":
                self._by_name[a.worker].duty = a.detail["duty"]
            elif a.kind == "drain":
                self.drain(a.worker)
            elif a.kind == "undrain":
                self.undrain(a.worker)
            elif a.kind == "migrate":
                self.migrate(a.worker, queued=a.detail.get("queued", True))

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def snapshot(self) -> FleetSnapshot:
        occ = self.monitor.occupancy()
        per_worker: Dict[str, WorkerSnapshot] = {}
        sim = max(self.sim_t, 1e-12)
        for w in self.workers:
            recs = [r for r in self.completed if r.worker == w.name]
            toks = sum(len(r.req.out_tokens) for r in recs)
            ws = self.monitor.workers.get(w.name)
            per_worker[w.name] = WorkerSnapshot(
                name=w.name,
                profile=w.spec.profile.name,
                engine=w.engine.metrics_snapshot(),
                completed=len(recs),
                completed_tokens=toks,
                goodput_tokens_per_s=toks / sim,
                steps_run=w.steps_run,
                duty=w.duty,
                drained=w.drained,
                thermal_state=(ws.state.value if ws
                               else ThermalState.MINIMAL.value),
                slowdown=w.slowdown,
                state_occupancy=occ.get(w.name, {}),
            )
        total_tokens = sum(len(r.req.out_tokens) for r in self.completed)
        return FleetSnapshot(
            sim_t=self.sim_t,
            ticks=self.ticks,
            completed=len(self.completed),
            completed_tokens=total_tokens,
            goodput_tokens_per_s=total_tokens / sim,
            migrations=self.migrations,
            migrated_requests=len(self._migrated_rids),
            queue_moves=self.queue_moves,
            drains=self.drains,
            undrains=self.undrains,
            rejected=self.routing_rejected
            + sum(w.engine.scheduler.rejected_total for w in self.workers),
            expired=sum(w.engine.scheduler.expired_total
                        for w in self.workers),
            per_worker=per_worker,
        )


def drive_sim(fleet: ServingFleet, arrival_times: Sequence[float],
              submit, max_ticks: int = 1_000_000) -> float:
    """Open-loop driving in SIMULATED time: ``submit(i)`` is called when
    arrival ``i`` comes due on the fleet's sim clock, and the fleet ticks
    until every arrival is submitted and drained.  The sim-clock analogue
    of :func:`repro.serving.traffic.drive_open_loop` — shared so benches,
    demos and tests cannot drift apart on drive semantics.  Returns the
    simulated seconds elapsed."""
    t0 = fleet.sim_t
    n, i = len(arrival_times), 0
    for _ in range(max_ticks):
        while i < n and arrival_times[i] <= fleet.sim_t - t0:
            submit(i)
            i += 1
        if i >= n and fleet.idle():
            break
        fleet.tick()
    else:
        warnings.warn(
            f"drive_sim exhausted max_ticks={max_ticks} with work "
            f"outstanding ({len(fleet.completed)} finished)",
            RuntimeWarning, stacklevel=2)
    return fleet.sim_t - t0
