"""Thermal-aware heterogeneous serving fleet (paper §4.2 + §5.2, serving).

The paper's core claim is that a weak host plus a thermally-throttled phone
can serve real workloads; its §5.2 mitigations (swap / duty-cycle /
rebalance) were implemented for the *training* runtime in
:mod:`repro.runtime.elastic`.  This module puts the same machinery under
**live serving traffic**: a :class:`ServingFleet` runs one
:class:`~repro.serving.engine.ServeEngine` per simulated heterogeneous
worker, paced in *simulated time* by the worker's
:class:`~repro.hw.specs.DeviceProfile` serving rates
(``decode_steps_per_s`` / ``prefill_tokens_per_s``), and

* **routes** each admission to the worker with the coolest thermal state
  and the shortest estimated backlog (free backend capacity breaks ties);
* feeds per-step latency telemetry into a
  :class:`~repro.runtime.monitor.ThermalMonitor` — the paper's EWMA
  state machine now watches serving steps instead of training batches;
* executes :class:`~repro.runtime.elastic.ServingElasticPolicy` actions:
  a SERIOUS worker is **duty-cycled** (fewer decode steps per fleet tick),
  **drained** (new admissions routed away) or has its lanes **migrated** —
  ``engine.preempt(slot, requeue=False)`` releases the lane
  token-identically (frozen sampler PRNG + generated-token requeue) and
  ``inject(req, force=True)`` re-admits it on a cooler worker.  With
  content-addressed prefix caching enabled on the target, the migration
  re-prefill of shared-scaffold traffic is a near-full cache hit.

Simulation semantics: :meth:`ServingFleet.tick` advances simulated time by
``tick_s``.  A worker earns ``tick_s * duty`` seconds of compute per tick
and spends it on decode steps (``slowdown / decode_rate`` seconds each)
and prefill work (``prefilled_tokens * slowdown / prefill_rate``), where
``slowdown`` comes from a pluggable throttle model:

* :class:`ThrottleTrace` — exogenous per-worker ramp (paper Fig. 6 shape:
  plateau approach with a time constant), for deterministic benches;
* :class:`ThermalReservoir` — closed loop: heat integrates utilisation
  with the profile's ``thermal_tau_s``, idle time dissipates it, and
  slowdown ramps to ``1 / thermal_sustained`` at full heat — so
  duty-cycling genuinely cools a worker.

Fleet engines run on the fleet's SIM clock (``ServeEngine(clock=...)``):
``Request.deadline_s`` is evaluated against simulated seconds, and the
engines' latency metrics read in sim seconds too.  Fleet-level **goodput**
(completed tokens per simulated second, total and per worker), migration
counts and thermal-state occupancy remain the headline numbers
(:meth:`ServingFleet.snapshot`).

Telemetry is paid for, not free: a worker that executed steps this tick
reports their latency; an idle (usually drained) worker is only observed
through a paced **probe** (one step's compute charged against its budget,
every ``probe_every_s`` sim seconds) — so noticing that a drained worker
cooled down has a cost, as on a real fleet.  ``telemetry="wall"`` feeds
the monitor the *measured wall-clock* per-step latency of the real jitted
dispatches instead of the synthetic simulated value — for replica workers
and for stage-group members alike (per-stage dispatch times) — and probes
then re-observe the last *measured* value (or skip, before any dispatch
ran), so the monitor's baseline never mixes wall and sim time scales.
The bench harness uses this mode to drive the monitor with real
telemetry.

**Stage groups** (pipeline-split decode, paper §4.1 topology): a
:class:`StageGroup` pairs two or more member workers into ONE logical
serving unit running a :class:`~repro.serving.pipeline_decode.PipelineEngine`
— stage 0 holds the below-the-cut layers (and their KV), stage 1 the
rest, and every boundary activation crosses as a wire frame charged
against ``min(link_bw)`` in sim time (a frame that outruns the tick's
link budget stays IN FLIGHT into the next tick).  The cut comes from
:func:`repro.core.partition.split_decode`; when a member throttles, the
elastic ``migrate`` action is reinterpreted for its group as
**rebalance**: the split is re-cut from the members' derated rates, the
moved layer params are charged over the link, and every lane resumes
token-identically through the preempt/inject machinery.

**Failure plane** (``kill_trace=`` / ``failover=``): workers can DIE, not
just throttle.  A seeded :class:`~repro.runtime.faults.KillTrace`
schedules crashes, network partitions and zombie-reboots; liveness is
heartbeats fed from this module's existing paced telemetry (every
executed step or paced probe beats — see
:mod:`repro.serving.failover`), and a unit whose beats stop long enough
is declared dead: its lanes are rolled back to their last periodic
checkpoint and resurrected **token-identically** on survivors through
the same preempt/inject machinery migration uses, its queued backlog
re-routes, and nothing is ever lost (destination-less requests park and
retry).  ``FleetSnapshot`` reports ``deaths / resurrections /
recompute_tokens / orphaned / checkpoints``.

**Speculative pairs**: a :class:`SpecPair` welds a fast draft worker to a
slow target worker into ONE serving unit running a
:class:`~repro.serving.speculative.SpecEngine` — the draft member
proposes ``spec_k`` tokens per round, the target member verifies them in
one multi-token window, and BOTH directions of the token exchange cross
as wire frames charged against ``min(link_bw)`` (transfers are never
free).  The elastic ``migrate`` action on the DRAFT member means
**colocate**: drafting falls back onto the target worker (draft compute
charged there, link charges vanish) until every member cools, when the
``undrain`` re-splits the pair.  ``migrate`` on the TARGET member drains
the pair — the target holds the lanes and the big params; there is
nowhere cheaper to verify.

**Training plane** (:mod:`repro.serving.train_plane`): a
:class:`~repro.serving.train_plane.FedRoundCoordinator` wraps the fleet
and schedules federated training rounds into replica workers' idle
duty-cycle gaps — local steps charged against the SAME per-tick ``acc_s``
credit decode spends (and feeding the same thermal loop through
``util``), update frames charged against the link, dead participants
excluded per round through this module's failure plane.  The fleet
itself stays training-agnostic; :meth:`ServingFleet.thermal_rank` is the
public face the coordinator scores and preempts on.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from repro.core.partition import split_decode
from repro.hw.specs import DeviceProfile
from repro.models.api import Model
from repro.runtime.elastic import Action, ServingElasticPolicy
from repro.runtime.faults import KillEvent, KillTrace
from repro.runtime.monitor import ThermalMonitor, ThermalState
from repro.serving.failover import (DEAD, SUSPECT, FailoverConfig,
                                    HeartbeatMonitor, LaneCheckpoint)
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.engine_api import DecodeEngine
from repro.serving.metrics import EngineSnapshot
from repro.serving.pipeline_decode import (PipelineEngine, StepReport,
                                           decode_block_costs,
                                           stage_fixed_mem)
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.scheduler import SchedulerConfig
from repro.serving.speculative import SpecEngine, SpecReport


# ---------------------------------------------------------------------------
# throttle models
# ---------------------------------------------------------------------------
class NullThrottle:
    """No throttling: every worker always runs at its cold rate."""

    def advance(self, worker: str, dt: float, util: float) -> float:
        return 1.0


class ThrottleTrace:
    """Exogenous per-worker slowdown trace (paper Fig. 6 ramp shape).

    ``ramps`` maps worker name -> ``(start_s, factor, tau_s)``: from
    ``start_s`` of simulated time the slowdown approaches ``factor`` with
    time constant ``tau_s``.  Utilisation is ignored — the trace is the
    same whatever the policies do, which is exactly what a policies-on vs
    policies-off A/B needs.
    """

    def __init__(self, ramps: Dict[str, Tuple[float, float, float]]):
        self.ramps = dict(ramps)
        self._t: Dict[str, float] = {}

    def advance(self, worker: str, dt: float, util: float) -> float:
        t = self._t.get(worker, 0.0) + dt
        self._t[worker] = t
        if worker not in self.ramps:
            return 1.0
        start, factor, tau = self.ramps[worker]
        if t < start:
            return 1.0
        ramp = 1.0 - math.exp(-(t - start) / max(tau, 1e-9))
        return 1.0 + (factor - 1.0) * ramp


class ThermalReservoir:
    """Closed-loop thermal model driven by the profiles' §4.2 fields.

    Heat ``h`` in [0, 1] integrates utilisation with time constant
    ``thermal_tau_s`` and dissipates while idle (``cool_frac`` scales the
    cooling time constant); slowdown ramps to ``1 / thermal_sustained`` at
    full heat.  Duty-cycling a worker really cools it here — this is the
    model under which the §5.2 duty-cycle mitigation earns its keep.
    """

    def __init__(self, profiles: Dict[str, DeviceProfile],
                 cool_frac: float = 0.5):
        self.profiles = dict(profiles)
        self.cool_frac = cool_frac
        self.heat: Dict[str, float] = {}

    def advance(self, worker: str, dt: float, util: float) -> float:
        p = self.profiles.get(worker)
        if p is None or not math.isfinite(p.thermal_tau_s):
            return 1.0
        tau = max(p.thermal_tau_s, 1e-9)
        h = self.heat.get(worker, 0.0)
        h += dt * (util / tau
                   - (1.0 - util) * h / (tau * max(self.cool_frac, 1e-9)))
        h = min(max(h, 0.0), 1.0)
        self.heat[worker] = h
        return 1.0 + (1.0 / p.thermal_sustained - 1.0) * h


# ---------------------------------------------------------------------------
# fleet
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One simulated worker: a device profile plus engine sizing."""
    name: str
    profile: DeviceProfile
    max_batch: int = 4
    engine_config: Optional[EngineConfig] = None    # None = fleet default
    scheduler: Optional[SchedulerConfig] = None     # None = fleet default


@dataclasses.dataclass(frozen=True)
class StageGroup:
    """Several member workers serving ONE model split across their engines.

    ``workers`` are the stage members in stage order (stage 0 first);
    ``cuts`` are the layer indices where each next stage starts, or
    ``None`` to let :func:`repro.core.partition.split_decode` pick them
    from the members' device profiles (serving rates, link budgets and
    ``mem_bytes``).  The group routes, drains and migrates as one unit
    under its ``name``; its members keep their own thermal telemetry,
    duty cycles and throttle state under their worker names.
    """
    name: str
    workers: Tuple[WorkerSpec, ...]
    cuts: Optional[Tuple[int, ...]] = None
    max_batch: int = 4
    engine_config: Optional[EngineConfig] = None
    scheduler: Optional[SchedulerConfig] = None


@dataclasses.dataclass(frozen=True, eq=False)
class SpecPair:
    """A draft worker welded to a target worker for speculative decoding.

    ``draft`` runs ``draft_model`` (a small same-vocab proposer whose
    compute is charged at ``draft_share`` of a full decode step — default
    its layer count over the target's); ``target`` runs the fleet model
    and verifies ``spec_k``-token proposals in one window.  The pair
    routes, drains and migrates as one unit under ``name``; members keep
    their own thermal telemetry, duty cycles and throttle state.
    ``eq=False``: params pytrees aren't hashable, identity semantics are
    what a spec registry needs anyway.
    """
    name: str
    draft: WorkerSpec
    target: WorkerSpec
    draft_model: Model
    draft_params: object
    spec_k: int = 3
    draft_share: Optional[float] = None     # None = layer-count ratio
    max_batch: int = 4
    engine_config: Optional[EngineConfig] = None
    scheduler: Optional[SchedulerConfig] = None


@dataclasses.dataclass(frozen=True)
class CompletedRecord:
    """A finished request with fleet-level context."""
    req: Request
    worker: str                  # worker it FINISHED on
    sim_t: float                 # simulated completion time
    migrated: bool               # ever moved between workers


@dataclasses.dataclass(frozen=True)
class WorkerSnapshot:
    name: str
    profile: str
    engine: EngineSnapshot
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float      # tokens finished here / sim seconds
    steps_run: int
    duty: float
    drained: bool
    thermal_state: str
    slowdown: float
    state_occupancy: Dict[str, float]
    probes: int = 0                  # paced recovery probes paid


@dataclasses.dataclass(frozen=True)
class GroupSnapshot:
    """One stage group's reading: split shape, wire traffic, members."""
    name: str
    workers: Tuple[str, ...]         # member names, stage order
    cuts: Tuple[int, ...]
    engine: EngineSnapshot
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float
    steps_run: int                   # decode steps fully PAID in sim time
    drained: bool
    recuts: int                      # rebalance re-cuts applied
    frames_sent: int                 # boundary frames through the codec
    frame_bytes: int                 # activation bytes charged to the link
    recut_bytes: int                 # layer-param bytes moved by recuts
    transfer_s: float                # sim seconds the link was busy
    link_stall_ticks: int            # ticks a frame stayed in flight
    members: Dict[str, Dict]         # per member: duty/slowdown/state/util


@dataclasses.dataclass(frozen=True)
class SpecSnapshot:
    """One speculative pair's reading: acceptance, wire traffic, members.

    Units: ``frame_bytes`` are wire-codec bytes charged to the pair's
    link; ``transfer_s`` sim seconds the link was busy; acceptance
    metrics live in ``engine`` (``spec_acceptance_rate`` etc.)."""
    name: str
    workers: Tuple[str, str]         # (draft, target) member names
    spec_k: int
    draft_share: float
    engine: EngineSnapshot
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float
    rounds_run: int                  # draft->verify rounds fully PAID
    drained: bool
    colocated: bool                  # currently drafting on the target
    colocations: int                 # times the pair fell back colocated
    frame_bytes: int                 # drafted+sync bytes through the codec
    transfer_s: float
    link_stall_ticks: int
    members: Dict[str, Dict]


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    sim_t: float
    ticks: int
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float      # completed tokens / sim seconds
    migrations: int                  # lane moves (preempt here, resume there)
    migrated_requests: int           # unique requests whose decode ever
    #                                  moved workers (lane moves + queued
    #                                  mid-flight moves)
    queue_moves: int                 # queued requests re-routed off a worker
    drains: int
    undrains: int
    rejected: int
    expired: int
    per_worker: Dict[str, WorkerSnapshot]
    per_group: Dict[str, GroupSnapshot] = dataclasses.field(
        default_factory=dict)
    per_spec: Dict[str, SpecSnapshot] = dataclasses.field(
        default_factory=dict)
    recuts: int = 0                  # stage-group rebalances applied
    probes: int = 0                  # paced recovery probes across the fleet
    transfer_bytes: int = 0          # wire bytes charged (activations+recuts)
    transfer_s: float = 0.0          # sim seconds links were busy
    # failure plane (serving/failover.py): all zero without a kill trace
    deaths: int = 0                  # units declared DEAD by the heartbeat
    resurrections: int = 0           # mid-flight lanes resumed elsewhere
    recompute_tokens: int = 0        # tokens replayed by resurrections
    orphaned: int = 0                # stranded requests with no destination
    checkpoints: int = 0             # lane checkpoints taken
    dead_units: Tuple[str, ...] = ()

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class _Paced:
    """Sim-pacing state shared by plain workers and group members."""

    def __init__(self, spec: WorkerSpec):
        self.spec = spec
        self.duty = 1.0
        self.acc_s = 0.0             # unspent compute credit, seconds
        self.util = 0.0              # last tick's busy fraction
        self.slowdown = 1.0
        self.steps_run = 0
        self.next_probe_s = 0.0      # earliest sim time of the next probe
        self.probes = 0
        # last MEASURED wall-clock per-step latency (telemetry="wall"):
        # probes re-observe it so the monitor never mixes time scales
        self.last_wall_step_s: Optional[float] = None

    @property
    def name(self) -> str:
        return self.spec.name


class _Worker(_Paced):
    """Mutable runtime state the fleet keeps per replica WorkerSpec.

    The fleet drives ``engine`` strictly through the
    :class:`~repro.serving.engine_api.DecodeEngine` protocol surface —
    any conforming engine routes, migrates and snapshots the same way
    (the replica builder instantiates :class:`ServeEngine`)."""

    def __init__(self, spec: WorkerSpec, engine: DecodeEngine):
        super().__init__(spec)
        self.engine = engine
        self.rate = spec.profile.decode_rate()
        self.prefill_rate = spec.profile.prefill_rate()
        self.drained = False
        self.n_collected = 0         # engine.finished entries already seen
        self.done_count = 0          # completed requests (incremental; the
        self.done_tokens = 0         # snapshot must not rescan the log)

    def free_fraction(self) -> float:
        """Free capacity in [0, 1]: pool budget fraction for budgeted
        backends (paged), free-lane fraction otherwise."""
        eng = self.engine
        budget = eng.backend.budget_tokens
        cap = eng.backend.capacity_tokens
        if budget is not None and cap:
            return budget / cap
        return (eng.max_batch - eng.active()) / eng.max_batch


@dataclasses.dataclass
class _Charge:
    """One unpaid cost of a stage group's in-flight work.

    ``kind`` is ``"stage"`` (compute on member ``idx``, remaining COLD
    seconds — the member's live slowdown scales it at payment time),
    ``"link"`` (remaining wire seconds on boundary ``idx``; a partially
    paid link charge IS an activation frame in flight between ticks) or
    ``"commit"`` (free: the step's results become visible — finished
    requests are collected at the sim time the costs finished)."""
    kind: str
    idx: int
    remaining: float


class _GroupRuntime:
    """Runtime state of one StageGroup: engine, members, charge queue."""

    def __init__(self, spec: StageGroup, engine: PipelineEngine,
                 members: List[_Paced], costs, fixed_mem):
        self.spec = spec
        self.engine = engine
        self.members = members
        self.costs = costs               # decode_block_costs at build time
        self.fixed_mem = fixed_mem
        self.drained = False
        self.n_collected = 0
        self.done_count = 0
        self.done_tokens = 0
        self.steps_run = 0
        self.pending: Deque[_Charge] = collections.deque()
        self.link_acc = 0.0              # unspent link time, seconds
        self.transfer_s = 0.0            # sim seconds spent on the wire
        self.frame_bytes = 0             # activation bytes charged
        self.recut_bytes = 0             # layer-param bytes moved by recuts
        self.link_stall_ticks = 0        # ticks a frame stayed in flight
        self.recuts = 0
        self._set_rates()

    @property
    def name(self) -> str:
        return self.spec.name

    def _set_rates(self) -> None:
        """Per-stage cold costs from the CURRENT cut (recomputed after a
        rebalance): a stage holding ``share`` of the layers costs
        ``share / decode_rate`` cold seconds per decode step and
        ``share / prefill_rate`` per prefill token on its member."""
        n = self.engine.model.cfg.n_layers
        bounds = (0,) + self.engine.cuts + (n,)
        devs = [m.spec.profile for m in self.members]
        self.step_cold = [(bounds[i + 1] - bounds[i]) / n
                          / devs[i].decode_rate()
                          for i in range(len(self.members))]
        self.prefill_cold = [(bounds[i + 1] - bounds[i]) / n
                             / devs[i].prefill_rate()
                             for i in range(len(self.members))]
        self.link_bw = [min(devs[i].link_bw, devs[i + 1].link_bw)
                        for i in range(len(self.members) - 1)]
        self.rate = 1.0 / sum(self.step_cold)    # cold steps/s (routing)

    def free_fraction(self) -> float:
        eng = self.engine
        return (eng.max_batch - eng.active()) / eng.max_batch

    def busy(self) -> bool:
        return bool(self.pending) or self.engine.active() > 0 \
            or self.engine.scheduler.depth > 0


class _SpecRuntime:
    """Runtime state of one SpecPair: engine, (draft, target) members,
    charge queue.  Pacing mirrors :class:`_GroupRuntime`: every
    eagerly-executed engine round becomes an ordered charge list — draft
    compute on member 0, the drafted-token frame's flight, verify compute
    on member 1, the sync frame back — and the queue drains as members
    earn compute credit and the link earns wire time."""

    def __init__(self, spec: SpecPair, engine: SpecEngine,
                 members: List[_Paced], draft_share: float):
        self.spec = spec
        self.engine = engine
        self.members = members           # [draft, target]
        self.draft_share = draft_share
        self.drained = False
        self.n_collected = 0
        self.done_count = 0
        self.done_tokens = 0
        self.steps_run = 0               # rounds fully paid in sim time
        self.pending: Deque[_Charge] = collections.deque()
        self.link_acc = 0.0
        self.transfer_s = 0.0
        self.frame_bytes = 0
        self.link_stall_ticks = 0
        self.colocations = 0
        d, t = (m.spec.profile for m in members)
        self.link_bw = min(d.link_bw, t.link_bw)
        # routing rate: tokens/s of a cold round at FULL acceptance — the
        # optimistic bound plays the same role decode_rate() plays for a
        # plain worker (backlog comparison, not billing)
        k = spec.spec_k
        round_cold = ((k + 1) * draft_share / d.decode_rate()
                      + 1.0 / t.decode_rate() + k / t.prefill_rate())
        self.rate = (k + 1) / round_cold

    @property
    def name(self) -> str:
        return self.spec.name

    def set_colocated(self, flag: bool) -> None:
        if flag and not self.engine.colocated:
            self.colocations += 1
        self.engine.colocated = flag

    def free_fraction(self) -> float:
        eng = self.engine
        return (eng.max_batch - eng.active()) / eng.max_batch

    def busy(self) -> bool:
        return bool(self.pending) or self.engine.active() > 0 \
            or self.engine.scheduler.depth > 0


_Routable = Union[_Worker, _GroupRuntime, _SpecRuntime]


def _ctx_len_of(req: Request) -> int:
    """Cache positions a re-prefill of ``req`` occupies (the engine's
    ``_ctx_len``, computed fleet-side for recompute accounting)."""
    n = len(req.prompt) + len(req.out_tokens)
    fe = req.extra.get("frontend")
    if fe is not None:
        n += fe.shape[0]
    return n


def _cache_tokens_of(req: Request) -> Optional[np.ndarray]:
    """Token content behind ``req``'s cache positions, or None when the
    positions aren't pure tokens (requests with extra model inputs can
    neither hit nor feed a prefix cache)."""
    if req.extra:
        return None
    if not req.out_tokens:
        return req.prompt
    return np.concatenate(
        [req.prompt, np.asarray(req.out_tokens, np.int32)])


class ServingFleet:
    """Heterogeneous serving fleet: replica workers + stage groups.

    Replica workers each run a full-params :class:`ServeEngine`; stage
    groups run ONE model split across their members' engines
    (:class:`~repro.serving.pipeline_decode.PipelineEngine`), which is
    what lets the fleet serve models larger than any single worker's
    ``mem_bytes``.  Both route, drain and migrate as units under their
    names.
    """

    def __init__(self, model: Model, params,
                 workers: Sequence[WorkerSpec] = (), *,
                 groups: Sequence[StageGroup] = (),
                 spec_pairs: Sequence[SpecPair] = (),
                 max_len: int = 64,
                 tick_s: float = 0.05,
                 monitor: Optional[ThermalMonitor] = None,
                 policy: Optional[ServingElasticPolicy] = None,
                 throttle=None,
                 engine_config: Optional[EngineConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 thermal_routing: bool = True,
                 telemetry: str = "sim",
                 probe_every_s: float = 0.25,
                 kill_trace: Optional[KillTrace] = None,
                 failover: Optional[FailoverConfig] = None):
        if not workers and not groups and not spec_pairs:
            raise ValueError(
                "a fleet needs at least one worker, group or spec pair")
        names = ([w.name for w in workers] + [g.name for g in groups]
                 + [m.name for g in groups for m in g.workers]
                 + [p.name for p in spec_pairs]
                 + [m.name for p in spec_pairs
                    for m in (p.draft, p.target)])
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate worker/group names: {names}")
        if telemetry not in ("sim", "wall"):
            raise ValueError(f"telemetry must be 'sim' or 'wall', "
                             f"got {telemetry!r}")
        self.tick_s = tick_s
        self.monitor = monitor or ThermalMonitor(
            alpha=0.25, calibration_steps=3, warmup_skip=0)
        self.policy = policy
        self.throttle = throttle or NullThrottle()
        # False = route on capacity/backlog alone (the thermally-naive
        # baseline a policies-off A/B measures against)
        self.thermal_routing = thermal_routing
        self.telemetry = telemetry
        self.probe_every_s = probe_every_s
        self.workers: List[_Worker] = []
        for spec in workers:
            eng = ServeEngine(
                model, params, max_batch=spec.max_batch, max_len=max_len,
                scheduler=spec.scheduler or scheduler,
                prefill_buckets=prefill_buckets,
                config=spec.engine_config or engine_config,
                clock=self._sim_now)
            self.workers.append(_Worker(spec, eng))
        self.groups: List[_GroupRuntime] = []
        self._member_group: Dict[str, _GroupRuntime] = {}
        for gspec in groups:
            g = self._build_group(model, params, gspec, max_len, scheduler)
            self.groups.append(g)
            for m in g.members:
                self._member_group[m.name] = g
        self.spec_pairs: List[_SpecRuntime] = []
        self._member_spec: Dict[str, _SpecRuntime] = {}
        for pspec in spec_pairs:
            s = self._build_spec(model, params, pspec, max_len, scheduler)
            self.spec_pairs.append(s)
            for m in s.members:
                self._member_spec[m.name] = s
        self._by_name: Dict[str, _Routable] = {
            u.name: u for u in (*self.workers, *self.groups,
                                *self.spec_pairs)}
        self.sim_t = 0.0
        self.ticks = 0
        self._rid = 0
        self.completed: List[CompletedRecord] = []
        self.completed_tokens = 0    # kept incrementally by _collect_finished
        self.routed: Dict[int, str] = {}      # rid -> first unit routed to
        self.action_log: List[Tuple[float, Action]] = []   # (sim_t, action)
        self.migrations = 0
        self.queue_moves = 0
        self.drains = 0
        self.undrains = 0
        self.recuts = 0
        self.routing_rejected = 0    # no routable worker could queue it
        self._migrated_rids: Set[int] = set()
        # ---- failure plane (serving/failover.py) ----------------------
        # failover defaults ON whenever a kill trace is supplied; passing
        # a FailoverConfig alone also arms it (heartbeats + checkpoints
        # run even if nothing ever dies — that is their real cost)
        self.failover = failover or (FailoverConfig()
                                     if kill_trace is not None else None)
        self._kill_events: List[KillEvent] = \
            sorted(kill_trace, key=lambda e: e.t_s) if kill_trace else []
        self._next_kill = 0
        self._down: Dict[str, str] = {}        # unit name -> kill kind
        self._return_at: Dict[str, float] = {}
        self._dead: Set[str] = set()           # DETECTED dead units
        self._suspect: Set[str] = set()
        self._parked: List[Tuple[Request, bool, bool]] = []
        self._parked_rids: Set[int] = set()
        self._ckpt: Dict[int, LaneCheckpoint] = {}
        self.deaths = 0
        self.resurrections = 0
        self.recompute_tokens = 0
        self.checkpoints = 0
        self.failure_log: List[Tuple[float, str, str]] = []
        if self.failover is not None:
            member_names = [p.name for p in self._all_paced()]
            self._hb: Optional[HeartbeatMonitor] = HeartbeatMonitor(
                member_names, probe_every_s, self.failover)
            self._next_ckpt_s = self.failover.checkpoint_every_s
        else:
            self._hb = None
            self._next_ckpt_s = math.inf

    def _sim_now(self) -> float:
        """The fleet's engines live on this SIM clock: queue waits and
        deadlines are simulated seconds, not host wall time."""
        return self.sim_t

    def _all_paced(self) -> List[_Paced]:
        """Every heartbeat-bearing entity: workers, group members, spec
        members (the paced things that execute steps and answer probes)."""
        return [*self.workers,
                *(m for g in self.groups for m in g.members),
                *(m for s in self.spec_pairs for m in s.members)]

    def _unit_paced(self, u: _Routable) -> List[_Paced]:
        return u.members if isinstance(u, (_GroupRuntime, _SpecRuntime)) \
            else [u]

    def _owning_unit(self, worker: str) -> Optional[_Routable]:
        """The routable unit a kill on ``worker`` takes down: a group or
        spec pair dies whole when any member does (a pipeline can't run
        around a missing stage; a pair can't verify on a dead target)."""
        if worker in self._member_group:
            return self._member_group[worker]
        if worker in self._member_spec:
            return self._member_spec[worker]
        return self._by_name.get(worker)

    def _is_down(self, name: str) -> bool:
        return name in self._down or name in self._dead

    def _beat(self, name: str) -> None:
        if self._hb is not None:
            self._hb.beat(name, self.sim_t)

    def _build_group(self, model: Model, params, gspec: StageGroup,
                     max_len: int,
                     scheduler: Optional[SchedulerConfig]) -> _GroupRuntime:
        if len(gspec.workers) < 2:
            raise ValueError(f"stage group {gspec.name!r} needs >= 2 "
                             f"member workers")
        costs = decode_block_costs(model, params, gspec.max_batch, max_len)
        fixed = stage_fixed_mem(model, params, len(gspec.workers))
        cuts = gspec.cuts
        if cuts is None:
            plan = split_decode(costs, [w.profile for w in gspec.workers],
                                stage_fixed_mem=fixed)
            cuts = plan.cuts
        eng = PipelineEngine(model, params, max_batch=gspec.max_batch,
                             max_len=max_len, cuts=cuts,
                             scheduler=gspec.scheduler or scheduler,
                             config=gspec.engine_config,
                             clock=self._sim_now)
        members = [_Paced(w) for w in gspec.workers]
        return _GroupRuntime(gspec, eng, members, costs, fixed)

    def _build_spec(self, model: Model, params, pspec: SpecPair,
                    max_len: int,
                    scheduler: Optional[SchedulerConfig]) -> _SpecRuntime:
        eng = SpecEngine(model, params, pspec.draft_model,
                         pspec.draft_params, max_batch=pspec.max_batch,
                         max_len=max_len, spec_k=pspec.spec_k,
                         scheduler=pspec.scheduler or scheduler,
                         config=pspec.engine_config, clock=self._sim_now)
        share = pspec.draft_share
        if share is None:
            share = (pspec.draft_model.cfg.n_layers
                     / max(model.cfg.n_layers, 1))
        members = [_Paced(pspec.draft), _Paced(pspec.target)]
        return _SpecRuntime(pspec, eng, members, share)

    # ------------------------------------------------------------------
    # admission routing
    # ------------------------------------------------------------------
    def worker(self, name: str) -> _Routable:
        return self._by_name[name]

    def group(self, name: str) -> _GroupRuntime:
        u = self._by_name[name]
        if not isinstance(u, _GroupRuntime):
            raise KeyError(f"{name!r} is not a stage group")
        return u

    def _state_rank(self, name: str) -> int:
        ws = self.monitor.workers.get(name)
        order = list(ThermalState)
        return order.index(ws.state) if ws else 0

    def thermal_rank(self, name: str) -> int:
        """Public thermal rank of one worker: 0 MINIMAL .. 3 CRITICAL.
        The training plane scores participant selection and preemption on
        this without reaching into the monitor."""
        return self._state_rank(name)

    def _unit_rank(self, u: _Routable) -> int:
        """A group/pair is as hot as its hottest member: one throttled
        stage (or the verify side) throttles every lane spanning it."""
        if isinstance(u, (_GroupRuntime, _SpecRuntime)):
            return max(self._state_rank(m.name) for m in u.members)
        return self._state_rank(u.name)

    def _route_order(self, exclude: Optional[_Routable] = None
                     ) -> List[_Routable]:
        """Routable units best-first: non-drained coolest state, then
        shortest estimated backlog (queued + active work over the unit's
        cold rate), then most free backend capacity.  All-drained fleets
        fall back to every unit — admissions queue rather than vanish.
        Units the heartbeat monitor declared DEAD are never routable;
        SUSPECT units are avoided like drained ones (fall back only when
        nothing healthy remains) — their lanes keep decoding, but new
        work shouldn't bet on a worker that stopped answering."""
        units: List[_Routable] = [u for u in (*self.workers, *self.groups,
                                              *self.spec_pairs)
                                  if u.name not in self._dead]
        cands = [u for u in units if u is not exclude and not u.drained
                 and u.name not in self._suspect]
        if not cands:
            cands = [u for u in units if u is not exclude]

        def score(u: _Routable):
            backlog = (u.engine.scheduler.depth + u.engine.active()) / u.rate
            rank = self._unit_rank(u) if self.thermal_routing else 0
            return (rank, backlog, -u.free_fraction(), u.name)

        return sorted(cands, key=score)

    def submit(self, prompt, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        """Route one request to the best worker; returns a fleet-wide rid,
        or None if every routable worker's queue is full."""
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new, extra,
                      submitted_t=self.sim_t,
                      sampling=sampling or GREEDY, priority=priority,
                      deadline_s=deadline_s)
        fallback = None
        for w in self._route_order():
            # probe capacity BEFORE inject: a push into a full queue would
            # record a per-engine rejection for a request another worker
            # then admits (one fleet admission must count at most once)
            mq = w.engine.scheduler.config.max_queue
            if mq is not None and w.engine.scheduler.depth >= mq:
                continue
            if fallback is None:
                fallback = w
            # don't route onto a backend that can never hold the final
            # footprint while a worker that can is standing by
            if not w.engine.feasible(req):
                continue
            if w.engine.inject(req):
                self.routed[rid] = w.name
                return rid
        if fallback is not None and fallback.engine.inject(req):
            # no worker fits it: queue it anyway so the backend's alloc —
            # the authority on infeasibility — records the rejection
            self.routed[rid] = fallback.name
            return rid
        self.routing_rejected += 1
        return None

    # ------------------------------------------------------------------
    # simulation
    # ------------------------------------------------------------------
    def _collect_finished(self, u: _Routable) -> None:
        done = u.engine.finished
        for req in done[u.n_collected:]:
            self.completed.append(CompletedRecord(
                req, u.name, self.sim_t, req.rid in self._migrated_rids))
            # incremental per-unit + fleet totals: snapshot() must stay
            # O(units), not O(units x completed-request log)
            toks = len(req.out_tokens)
            u.done_count += 1
            u.done_tokens += toks
            self.completed_tokens += toks
            self._ckpt.pop(req.rid, None)    # checkpoint no longer needed
        u.n_collected = len(done)

    def _observe_or_probe(self, p: _Paced, ran: bool,
                          reading: Optional[float],
                          probe_cost: float) -> float:
        """Telemetry with a cost model: a worker that executed work this
        tick reports its step latency for free (the steps themselves were
        the observation).  An idle worker — drained, starved or stalled —
        is only observed through a paced PROBE every ``probe_every_s``
        sim seconds; the probe costs one step's compute (returned, so the
        caller charges it), because on a real fleet noticing that a
        drained phone cooled down means running something on it.

        ``reading`` is the latency to feed the monitor — the simulated
        step time, or under ``telemetry="wall"`` the measured wall value
        (a probe re-observes the LAST measured one, never a sim-scale
        stand-in: the monitor's baseline must stay on one time scale).
        ``None`` = no reading exists yet (wall mode before any dispatch
        ran): the observation is skipped rather than polluted."""
        if ran:
            if reading is not None:
                self.monitor.observe(p.name, reading)
            p.next_probe_s = self.sim_t + self.probe_every_s
            self._beat(p.name)       # executed work IS the heartbeat
            return 0.0
        if self.sim_t >= p.next_probe_s:
            p.next_probe_s = self.sim_t + self.probe_every_s
            # a probe that reaches the worker proves liveness even when
            # it carries no monitor reading yet (wall mode, pre-dispatch)
            self._beat(p.name)
            if reading is None:
                return 0.0
            p.probes += 1
            self.monitor.observe(p.name, reading)
            return probe_cost
        return 0.0

    def _advance_worker(self, w: _Worker) -> None:
        w.slowdown = self.throttle.advance(w.name, self.tick_s, w.util)
        step_s = w.slowdown / w.rate
        w.acc_s = min(w.acc_s + self.tick_s * w.duty, self.tick_s + step_s)
        busy_s = 0.0
        wall_s = 0.0
        steps_ran = 0
        while w.acc_s >= step_s:
            if not w.engine.active() and not w.engine.scheduler.depth:
                # idle: credit does not bank beyond one immediate step
                w.acc_s = min(w.acc_s, step_s)
                break
            tok0 = w.engine.metrics.prefill_tokens
            t0 = time.perf_counter()
            w.engine.step()
            wall_s += time.perf_counter() - t0
            self._collect_finished(w)
            prefill_s = ((w.engine.metrics.prefill_tokens - tok0)
                         * w.slowdown / w.prefill_rate)
            w.acc_s -= step_s + prefill_s
            busy_s += step_s + prefill_s
            w.steps_run += 1
            steps_ran += 1
        # telemetry: the simulated per-step latency, or — under
        # telemetry="wall" — the MEASURED wall time of the real jitted
        # dispatches (the bench harness's real-telemetry feed); probes
        # re-observe the last measured value so scales never mix
        if self.telemetry == "wall":
            if steps_ran:
                w.last_wall_step_s = wall_s / steps_ran
            reading = w.last_wall_step_s
        else:
            reading = step_s
        busy_s += self._observe_or_probe(w, steps_ran > 0, reading, step_s)
        w.util = min(busy_s / self.tick_s, 1.0)

    # -- stage groups ---------------------------------------------------
    def _charges_for(self, g: _GroupRuntime,
                     rep: StepReport) -> List[_Charge]:
        """Turn one eagerly-executed engine step into its sim-time costs,
        in pipeline order: per-stage prefill compute with the prompt
        activation frames between them, then per-stage decode compute
        with the decode boundary frames, then the free commit marker."""
        out: List[_Charge] = []
        n = len(g.members)
        if rep.prefill_tokens:
            for i in range(n):
                out.append(_Charge(
                    "stage", i, rep.prefill_tokens * g.prefill_cold[i]))
                if i < n - 1 and rep.prefill_frame_bytes[i]:
                    nb = rep.prefill_frame_bytes[i]
                    g.frame_bytes += nb
                    out.append(_Charge("link", i, nb / g.link_bw[i]))
        if rep.decode_step:
            for i in range(n):
                out.append(_Charge("stage", i, g.step_cold[i]))
                # wall telemetry feed: the measured per-stage dispatch time
                g.members[i].last_wall_step_s = rep.decode_stage_wall_s[i]
                if i < n - 1:
                    nb = rep.decode_frame_bytes[i]
                    g.frame_bytes += nb
                    out.append(_Charge("link", i, nb / g.link_bw[i]))
        out.append(_Charge("commit", 0, 0.0))
        return out

    def _advance_group(self, g: _GroupRuntime) -> None:
        """One tick of a stage group: members earn compute credit, the
        link earns wire time, and the charge queue drains in order — a
        decode step's stage-0 compute, its activation frame's flight, its
        stage-1 compute.  A frame whose flight outruns the tick's link
        budget stays IN FLIGHT into the next tick (that is the
        "activations cross between fleet ticks" semantics); compute that
        outruns a member's budget stalls the pipeline the same way."""
        n = len(g.members)
        for m in g.members:
            m.slowdown = self.throttle.advance(m.name, self.tick_s, m.util)
            m.acc_s = min(m.acc_s + self.tick_s * m.duty, self.tick_s)
        g.link_acc = min(g.link_acc + self.tick_s, self.tick_s)
        busy = [0.0] * n
        ran = [0] * n
        while True:
            if g.pending:
                ch = g.pending[0]
                if ch.kind == "stage":
                    m = g.members[ch.idx]
                    cost_now = ch.remaining * m.slowdown
                    pay = min(cost_now, m.acc_s)
                    m.acc_s -= pay
                    busy[ch.idx] += pay
                    ch.remaining -= pay / m.slowdown if m.slowdown else pay
                    if ch.remaining > 1e-12:
                        break                    # stage stalls into next tick
                    g.pending.popleft()
                    m.steps_run += 1
                    ran[ch.idx] += 1
                elif ch.kind == "link":
                    pay = min(ch.remaining, g.link_acc)
                    g.link_acc -= pay
                    g.transfer_s += pay
                    ch.remaining -= pay
                    if ch.remaining > 1e-12:
                        g.link_stall_ticks += 1  # frame crosses the tick
                        break
                    g.pending.popleft()
                else:                            # commit: results visible
                    g.pending.popleft()
                    g.steps_run += 1
                    self._collect_finished(g)
                continue
            if not (g.engine.active() or g.engine.scheduler.depth):
                break
            if all(m.acc_s <= 1e-12 for m in g.members):
                break                            # no stage could even start
            rep = g.engine.step_paced()
            if rep is None:
                break
            g.pending.extend(self._charges_for(g, rep))
        for i, m in enumerate(g.members):
            sim_step = g.step_cold[i] * m.slowdown
            reading = m.last_wall_step_s if self.telemetry == "wall" \
                else sim_step
            busy[i] += self._observe_or_probe(m, ran[i] > 0, reading,
                                              sim_step)
            m.util = min(busy[i] / self.tick_s, 1.0)

    # -- speculative pairs ---------------------------------------------
    def _spec_costs(self, s: _SpecRuntime) -> Tuple[float, float]:
        """Cold seconds of one round's (draft, verify) compute charges.
        Draft: k+1 token-steps (catch-up + k proposals) at the draft's
        layer share, on whichever member is currently drafting.  Verify:
        one decode step plus k extra window positions priced at the
        target's prefill rate (the window is one scanned dispatch, not
        k+1 separate decode steps — that IS the speedup)."""
        k = s.spec.spec_k
        di = 1 if s.engine.colocated else 0
        dprof = s.members[di].spec.profile
        tprof = s.members[1].spec.profile
        draft_s = (k + 1) * s.draft_share / dprof.decode_rate()
        verify_s = 1.0 / tprof.decode_rate() + k / tprof.prefill_rate()
        return draft_s, verify_s

    def _charges_for_spec(self, s: _SpecRuntime,
                          rep: SpecReport) -> List[_Charge]:
        """One eagerly-executed speculative round as ordered sim-time
        costs: admission prefills, draft compute, the drafted-token frame
        d->t, the verify window, the emitted/PRNG sync frame t->d, then
        the free commit marker.  Colocated pairs charge draft compute on
        the TARGET member (idx 1) and ship no frames (the report's byte
        counts are already zero)."""
        out: List[_Charge] = []
        di = 1 if s.engine.colocated else 0
        dprof = s.members[di].spec.profile
        tprof = s.members[1].spec.profile
        if rep.target_prefill_tokens:
            out.append(_Charge(
                "stage", 1,
                rep.target_prefill_tokens / tprof.prefill_rate()))
        if rep.draft_prefill_tokens:
            out.append(_Charge(
                "stage", di, rep.draft_prefill_tokens * s.draft_share
                / dprof.prefill_rate()))
        if rep.n_active:
            draft_s, verify_s = self._spec_costs(s)
            out.append(_Charge("stage", di, draft_s))
            if rep.d2t_frame_bytes:
                s.frame_bytes += rep.d2t_frame_bytes
                out.append(_Charge(
                    "link", 0, rep.d2t_frame_bytes / s.link_bw))
            out.append(_Charge("stage", 1, verify_s))
            if rep.t2d_frame_bytes:
                s.frame_bytes += rep.t2d_frame_bytes
                out.append(_Charge(
                    "link", 0, rep.t2d_frame_bytes / s.link_bw))
        out.append(_Charge("commit", 0, 0.0))
        return out

    def _advance_spec(self, s: _SpecRuntime) -> None:
        """One tick of a spec pair: same charge-queue drain as a stage
        group — draft compute, frame flight, verify compute, frame
        flight, commit — with frames crossing ticks when they outrun the
        link budget."""
        for m in s.members:
            m.slowdown = self.throttle.advance(m.name, self.tick_s, m.util)
            m.acc_s = min(m.acc_s + self.tick_s * m.duty, self.tick_s)
        s.link_acc = min(s.link_acc + self.tick_s, self.tick_s)
        busy = [0.0] * len(s.members)
        ran = [0] * len(s.members)
        while True:
            if s.pending:
                ch = s.pending[0]
                if ch.kind == "stage":
                    m = s.members[ch.idx]
                    cost_now = ch.remaining * m.slowdown
                    pay = min(cost_now, m.acc_s)
                    m.acc_s -= pay
                    busy[ch.idx] += pay
                    ch.remaining -= pay / m.slowdown if m.slowdown else pay
                    if ch.remaining > 1e-12:
                        break
                    s.pending.popleft()
                    m.steps_run += 1
                    ran[ch.idx] += 1
                elif ch.kind == "link":
                    pay = min(ch.remaining, s.link_acc)
                    s.link_acc -= pay
                    s.transfer_s += pay
                    ch.remaining -= pay
                    if ch.remaining > 1e-12:
                        s.link_stall_ticks += 1
                        break
                    s.pending.popleft()
                else:                            # commit: results visible
                    s.pending.popleft()
                    s.steps_run += 1
                    self._collect_finished(s)
                continue
            if not (s.engine.active() or s.engine.scheduler.depth):
                break
            if all(m.acc_s <= 1e-12 for m in s.members):
                break
            t0 = time.perf_counter()
            rep = s.engine.step_paced()
            wall = time.perf_counter() - t0
            if (rep.n_active == 0 and not rep.target_prefill_tokens
                    and not rep.draft_prefill_tokens):
                break
            # wall-telemetry feed: split the measured round time by the
            # members' cold-cost shares (one process runs both sides)
            draft_s, verify_s = self._spec_costs(s)
            tot = draft_s + verify_s
            s.members[0].last_wall_step_s = wall * draft_s / tot
            s.members[1].last_wall_step_s = wall * verify_s / tot
            s.pending.extend(self._charges_for_spec(s, rep))
        draft_s, verify_s = self._spec_costs(s)
        for i, m in enumerate(s.members):
            sim_step = (draft_s, verify_s)[i] * m.slowdown
            reading = m.last_wall_step_s if self.telemetry == "wall" \
                else sim_step
            busy[i] += self._observe_or_probe(m, ran[i] > 0, reading,
                                              sim_step)
            m.util = min(busy[i] / self.tick_s, 1.0)

    def tick(self) -> None:
        """Advance simulated time by ``tick_s``: run every worker's and
        group's share of work, feed telemetry, then apply policy
        actions.  With the failure plane armed, down units are skipped
        (a dead device executes nothing, beats nothing), the heartbeat
        monitor is evaluated after the advance, and lane checkpoints /
        parked-request retries run on their cadence."""
        self.sim_t += self.tick_s
        self.ticks += 1
        if self.failover is not None:
            self._process_returns()
            self._process_kills()
        for w in self.workers:
            if not self._is_down(w.name):
                self._advance_worker(w)
        for g in self.groups:
            if not self._is_down(g.name):
                self._advance_group(g)
        for s in self.spec_pairs:
            if not self._is_down(s.name):
                self._advance_spec(s)
        if self.failover is not None:
            self._detect_failures()
            self._checkpoint_lanes()
            self._retry_parked()
        if self.policy is not None:
            actions = self.policy.step(self.monitor)
            # duty is re-asserted every tick while a worker is hot; a
            # worker the policy stopped mentioning runs full-duty again
            asserted = {a.worker for a in actions if a.kind == "duty_cycle"}
            for p in (*self.workers,
                      *(m for g in self.groups for m in g.members),
                      *(m for s in self.spec_pairs for m in s.members)):
                if p.name not in asserted:
                    p.duty = 1.0
            self._apply(actions)

    def idle(self) -> bool:
        return (not self._parked
                and all(not w.engine.active() and not w.engine.scheduler.depth
                        for w in self.workers)
                and all(not g.busy() for g in self.groups)
                and all(not s.busy() for s in self.spec_pairs))

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[CompletedRecord]:
        for _ in range(max_ticks):
            if self.idle():
                break
            self.tick()
        else:
            if not self.idle():
                warnings.warn(
                    f"fleet run_until_drained exhausted max_ticks="
                    f"{max_ticks} with work outstanding — returning "
                    f"PARTIAL results ({len(self.completed)} finished)",
                    RuntimeWarning, stacklevel=2)
        return self.completed

    # ------------------------------------------------------------------
    # failure plane: kills, heartbeats, lane resurrection
    # ------------------------------------------------------------------
    def _unit_backends(self, u: _Routable) -> List:
        """Every cache backend a unit owns (pipeline: one per stage;
        spec pair: target + draft) — the zombie cold-rejoin flush set."""
        eng = u.engine
        stages = getattr(eng, "stages", None)
        if stages is not None:
            return [st.backend for st in stages]
        out = [eng.backend]
        draft = getattr(eng, "draft_backend", None)
        if draft is not None:
            out.append(draft)
        return out

    def _process_kills(self) -> None:
        """Apply due kill-trace events: the owning unit stops executing
        (and beating) from this tick on.  Nothing else happens yet — the
        fleet only learns of the death when the heartbeat gap crosses
        the dead threshold, exactly like a real control plane."""
        while (self._next_kill < len(self._kill_events)
               and self._kill_events[self._next_kill].t_s <= self.sim_t):
            ev = self._kill_events[self._next_kill]
            self._next_kill += 1
            unit = self._owning_unit(str(ev.worker))
            if unit is None or self._is_down(unit.name):
                continue
            self._down[unit.name] = ev.kind
            if ev.returns:
                self._return_at[unit.name] = ev.t_s + ev.down_s
            self.failure_log.append(
                (self.sim_t, f"kill:{ev.kind}", unit.name))

    def _process_returns(self) -> None:
        """Bring due partition/zombie units back.  A partition that heals
        BEFORE detection is a transparent blip: lanes and caches are
        intact and decode just resumes.  A unit that was declared dead
        rejoins as a fresh worker (its lanes were already resurrected
        elsewhere); a zombie additionally rejoins COLD — its caches are
        flushed, since reboot wiped the content behind every
        registration.  Pacing and heartbeats restart at rejoin so banked
        sim credit can't burst and detection doesn't instantly re-fire."""
        due = sorted(n for n, t in self._return_at.items()
                     if t <= self.sim_t)
        for name in due:
            del self._return_at[name]
            kind = self._down.pop(name, None)
            unit = self._by_name[name]
            self._dead.discard(name)
            self._suspect.discard(name)
            if kind == "zombie":
                for b in self._unit_backends(unit):
                    b.forget_cache()
            for p in self._unit_paced(unit):
                p.acc_s = 0.0
                p.next_probe_s = self.sim_t + self.probe_every_s
                self._beat(p.name)
            self.failure_log.append((self.sim_t, f"return:{kind}", name))

    def _detect_failures(self) -> None:
        """Heartbeat evaluation: a unit is DEAD when ANY member's beat
        gap crosses the dead threshold (a pipeline can't run around a
        missing stage), SUSPECT when any member crossed the suspect
        threshold — routed around, lanes untouched."""
        for u in (*self.workers, *self.groups, *self.spec_pairs):
            if u.name in self._dead:
                continue
            states = [self._hb.state(p.name, self.sim_t)
                      for p in self._unit_paced(u)]
            if DEAD in states:
                self._strand(u)
            elif SUSPECT in states:
                if u.name not in self._suspect:
                    self._suspect.add(u.name)
                    self.failure_log.append((self.sim_t, "suspect", u.name))
            else:
                self._suspect.discard(u.name)

    def _strand(self, u: _Routable) -> None:
        """Declare a unit dead and resurrect its work elsewhere: every
        active lane is forgotten (host bookkeeping freed, NOTHING saved
        from or registered by the unreachable device), rolled back to
        its last checkpoint and re-injected on a survivor; the queued
        backlog re-routes the way a drain-migration would.  Zero lost
        requests: anything with no feasible destination parks and
        retries every tick."""
        self._dead.add(u.name)
        self._suspect.discard(u.name)
        self.deaths += 1
        self.failure_log.append((self.sim_t, "dead", u.name))
        eng = u.engine
        pending = getattr(u, "pending", None)
        if pending is not None:
            # charge-paced units (groups / spec pairs) execute EAGERLY and
            # only deliver when the charge queue commits in sim time.  A
            # result whose commit was never paid was never delivered — the
            # device died first — so those requests resurrect too, and the
            # unpayable charges vanish with the unit.
            for req in eng.finished[u.n_collected:]:
                req.done_t = None
                self._rollback_to_ckpt(req)
                self._place(req, mid_flight=True, resurrect=True)
            del eng.finished[u.n_collected:]
            pending.clear()
        for slot in range(eng.max_batch):
            if eng.slots[slot] is None:
                continue
            req = eng.forget_lane(slot)
            self._rollback_to_ckpt(req)
            self._place(req, mid_flight=True, resurrect=True)
        for req in eng.pull_queued():
            # queued mid-flight requests (preempted earlier) carry valid
            # host-side saved state — no rollback, just a new home
            self._place(req, mid_flight=req.admitted_t is not None)

    def _rollback_to_ckpt(self, req: Request) -> None:
        """Restore a dead lane's request to its last checkpoint: tokens
        generated after the checkpoint are replayed on the survivor from
        the frozen PRNG counter, so the resumed stream is token-identical
        to the unkilled one.  No checkpoint = restart from scratch (still
        token-identical: admission re-seeds the sampling stream)."""
        n_out = len(req.out_tokens)
        ck = self._ckpt.get(req.rid)
        if ck is not None:
            del req.out_tokens[ck.out_len:]
            req.saved_key = None if ck.key is None else ck.key.copy()
            req.saved_state = ck.state
        else:
            req.out_tokens.clear()
            req.saved_key = None
            req.saved_state = None
        req.fp_memo = None
        self.recompute_tokens += n_out - len(req.out_tokens)

    def _place(self, req: Request, mid_flight: bool,
               resurrect: bool = False) -> bool:
        """Find a surviving home for a stranded request.  Mid-flight
        requests bypass ``max_queue`` (tokens are owed to a client) but
        still need ``engine.feasible``; never-admitted backlog respects
        admission control, exactly as migration does.  Returns False and
        parks the request when nowhere fits (retried every tick)."""
        def has_room(t: _Routable) -> bool:
            mq = t.engine.scheduler.config.max_queue
            return mq is None or t.engine.scheduler.depth < mq

        dst = next(
            (t for t in self._route_order()
             if t.engine.feasible(req) and (mid_flight or has_room(t))),
            None)
        if dst is None:
            self._parked.append((req, mid_flight, resurrect))
            if req.rid not in self._parked_rids:
                self._parked_rids.add(req.rid)
                self.failure_log.append(
                    (self.sim_t, "parked", f"rid={req.rid}"))
            return False
        self._parked_rids.discard(req.rid)
        if mid_flight:
            if req.saved_state is None:
                # recompute estimate: the context re-prefill the survivor
                # pays, minus what its prefix cache already holds
                toks = _cache_tokens_of(req)
                backend = getattr(dst.engine, "backend", None)
                cached = (backend.cached_prefix_tokens(toks)
                          if backend is not None and toks is not None else 0)
                self.recompute_tokens += max(_ctx_len_of(req) - cached, 0)
            self._migrated_rids.add(req.rid)
        if resurrect:
            self.resurrections += 1
            self.failure_log.append(
                (self.sim_t, "resurrect", f"rid={req.rid}->{dst.name}"))
        elif not mid_flight:
            self.queue_moves += 1
        dst.engine.inject(req, force=True)
        return True

    def _checkpoint_lanes(self) -> None:
        """Periodic lightweight lane checkpoints: per occupied lane, the
        generated-token count, a copy of the sampler PRNG counter, and
        the backend snapshot (free constant-size state on recurrent
        backends; ``None`` on dense/paged, whose KV dies with the
        device).  Host-side only — this is what resurrection runs on."""
        if self.sim_t < self._next_ckpt_s:
            return
        self._next_ckpt_s = self.sim_t + self.failover.checkpoint_every_s
        for u in (*self.workers, *self.groups, *self.spec_pairs):
            if self._is_down(u.name):
                continue
            eng = u.engine
            backend = getattr(eng, "backend", None)
            for slot in range(eng.max_batch):
                req = eng.slots[slot]
                if req is None:
                    continue
                state = backend.snapshot(slot) if backend is not None \
                    else None
                self._ckpt[req.rid] = LaneCheckpoint(
                    rid=req.rid, out_len=len(req.out_tokens),
                    key=eng.lane_sampling.key[slot].copy(), state=state,
                    t_s=self.sim_t)
                self.checkpoints += 1

    def _retry_parked(self) -> None:
        if not self._parked:
            return
        parked, self._parked = self._parked, []
        for req, mid, res in parked:
            self._place(req, mid, res)

    # ------------------------------------------------------------------
    # elastic actions
    # ------------------------------------------------------------------
    def drain(self, name: str) -> None:
        """Route new admissions away from ``name`` (its queue still drains
        through it, and its active lanes keep decoding)."""
        w = self._by_name[name]
        if not w.drained:
            w.drained = True
            self.drains += 1

    def undrain(self, name: str) -> None:
        w = self._by_name[name]
        if w.drained:
            w.drained = False
            self.undrains += 1

    def migrate(self, name: str, queued: bool = True,
                lanes: Optional[int] = None) -> int:
        """Move ``name``'s decode lanes (and optionally its queued backlog)
        to the best other workers.  Token-identity is the engine's
        preempt/resume contract; the move count is returned.

        Victim choice is COST-AWARE: lanes are moved cheapest-first by
        ``engine.lane_cost(slot)`` — zero recompute (snapshot-restoring
        backends) before recompute, less re-prefill work before more, and
        the larger memory footprint first within a class (moving it
        relieves the hot worker most per recompute token paid).
        ``lanes`` bounds how many lanes move (None = all) — the policy's
        partial-migration knob, instead of always evicting everything.

        A destination must pass ``engine.feasible(req)`` — a mid-flight
        request moved onto a worker whose backend can never hold its
        final footprint would be REJECTED there, i.e. silently dropped.
        Mid-flight requests (tokens already owed to a client) may bypass
        the destination's ``max_queue``; never-admitted queued backlog
        may not — admission control survives migration.  A lane with no
        acceptable destination is NOT preempted: it keeps decoding (and
        its cache state) on ``name`` rather than paying a re-prefill to
        go nowhere."""
        src = self._by_name[name]
        targets = self._route_order(exclude=src)
        if not targets or all(t.drained for t in targets):
            return 0

        def has_room(t: _Routable) -> bool:
            mq = t.engine.scheduler.config.max_queue
            return mq is None or t.engine.scheduler.depth < mq

        def dest_for(req, mid_flight: bool) -> Optional[_Routable]:
            return next(
                (t for t in self._route_order(exclude=src)
                 if t.engine.feasible(req) and (mid_flight or has_room(t))),
                None)

        moved = 0
        cost = {i: src.engine.lane_cost(i)
                for i, r in enumerate(src.engine.slots) if r is not None}
        occupied = sorted(cost, key=lambda i: (cost[i][0], -cost[i][1]))
        if lanes is not None:
            occupied = occupied[:max(lanes, 0)]
        for slot in occupied:
            # pick the destination BEFORE preempting: evicting a lane
            # that has nowhere to go would throw away its cache state
            # (and pay a re-prefill) just to put it back in line here
            dst = dest_for(src.engine.slots[slot], mid_flight=True)
            if dst is None:
                continue
            req = src.engine.preempt(slot, requeue=False)
            dst.engine.inject(req, force=True)
            self._migrated_rids.add(req.rid)
            self.migrations += 1
            moved += 1
        if queued:
            stay = []
            for req in src.engine.pull_queued():
                mid_flight = req.admitted_t is not None
                dst = dest_for(req, mid_flight)
                if dst is None:
                    stay.append(req)
                    continue
                # room/feasibility verified above; force skips the push
                # path so the probe can't record a spurious rejection
                dst.engine.inject(req, force=True)
                if mid_flight:
                    # a preempted-then-requeued request moved here will
                    # resume cross-engine: that IS a migration
                    self._migrated_rids.add(req.rid)
                self.queue_moves += 1
                moved += 1
            for req in stay:
                src.engine.inject(req, force=True)
        return moved

    def rebalance(self, group_name: str) -> bool:
        """Re-cut a stage group's split from its members' LIVE derated
        rates (the §5.2 rebalance mitigation, serving edition).  The
        engine preempts every lane into its own queue — they re-admit
        token-identically through the new stages via the same
        preempt/inject machinery migration uses — and the layer params
        that changed stage are charged over the link before decode
        resumes.  Returns True if the cut actually changed."""
        g = self.group(group_name)
        derated = [m.spec.profile.derate(m.slowdown) for m in g.members]
        plan = split_decode(g.costs, derated, stage_fixed_mem=g.fixed_mem)
        if not plan.feasible or plan.cuts == g.engine.cuts:
            return False
        old = g.engine.cuts
        moved = g.engine.recut(plan.cuts)
        g._set_rates()
        if moved:
            g.recut_bytes += moved
            # weights cross the slowest boundary link before decode resumes
            g.pending.appendleft(
                _Charge("link", 0, moved / min(g.link_bw)))
        g.recuts += 1
        self.recuts += 1
        self.action_log.append((self.sim_t, Action(
            "rebalance", group_name,
            {"cuts": list(plan.cuts), "prev": list(old),
             "moved_bytes": moved})))
        return True

    def _apply_member(self, g: _GroupRuntime, a: Action) -> None:
        """Policy actions name WORKERS; for a stage-group member they act
        on the group: duty stays per-member (duty-cycling one stage paces
        the whole pipeline through its charges), drain/undrain drain the
        group's admissions, and migrate becomes REBALANCE — a split
        group's lanes cannot leave half their layers behind, but the cut
        can move off the throttled stage."""
        if a.kind == "duty_cycle":
            next(m for m in g.members
                 if m.name == a.worker).duty = a.detail["duty"]
        elif a.kind == "drain":
            self.drain(g.name)
        elif a.kind == "undrain":
            # only undrain once EVERY member recovered: the group is as
            # hot as its hottest stage
            if all(self._state_rank(m.name) == 0 for m in g.members):
                self.undrain(g.name)
        elif a.kind == "migrate":
            self.rebalance(g.name)

    def _apply_spec_member(self, s: _SpecRuntime, a: Action) -> None:
        """Policy actions on a spec-pair member act on the pair: duty
        stays per-member, drain/undrain drain the pair's admissions, and
        migrate splits by role — a hot DRAFT member COLOCATES (drafting
        falls back onto the target, so the phone can cool while the pair
        keeps its speculative speedup mechanics), while a hot TARGET
        member drains the pair (the target holds the lanes and the full
        params; verify has nowhere cheaper to go).  Undrain — gated on
        EVERY member cooling — re-splits a colocated pair."""
        if a.kind == "duty_cycle":
            next(m for m in s.members
                 if m.name == a.worker).duty = a.detail["duty"]
        elif a.kind == "drain":
            self.drain(s.name)
        elif a.kind == "undrain":
            if all(self._state_rank(m.name) == 0 for m in s.members):
                self.undrain(s.name)
                s.set_colocated(False)
        elif a.kind == "migrate":
            if a.worker == s.members[0].name:
                s.set_colocated(True)
            else:
                self.drain(s.name)

    def _apply(self, actions: Sequence[Action]) -> None:
        for a in actions:
            if a.worker in self._member_group:
                self.action_log.append((self.sim_t, a))
                self._apply_member(self._member_group[a.worker], a)
                continue
            if a.worker in self._member_spec:
                self.action_log.append((self.sim_t, a))
                self._apply_spec_member(self._member_spec[a.worker], a)
                continue
            if a.worker not in self._by_name:
                # a shared ThermalMonitor may track non-fleet workers
                # (another fleet, the training pipeline); not ours to act on
                continue
            self.action_log.append((self.sim_t, a))
            if a.kind == "duty_cycle":
                self._by_name[a.worker].duty = a.detail["duty"]
            elif a.kind == "drain":
                self.drain(a.worker)
            elif a.kind == "undrain":
                self.undrain(a.worker)
            elif a.kind == "migrate":
                self.migrate(a.worker, queued=a.detail.get("queued", True),
                             lanes=a.detail.get("lanes"))

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def snapshot(self) -> FleetSnapshot:
        occ = self.monitor.occupancy()
        per_worker: Dict[str, WorkerSnapshot] = {}
        sim = max(self.sim_t, 1e-12)
        for w in self.workers:
            ws = self.monitor.workers.get(w.name)
            per_worker[w.name] = WorkerSnapshot(
                name=w.name,
                profile=w.spec.profile.name,
                engine=w.engine.metrics_snapshot(),
                completed=w.done_count,
                completed_tokens=w.done_tokens,
                goodput_tokens_per_s=w.done_tokens / sim,
                steps_run=w.steps_run,
                duty=w.duty,
                drained=w.drained,
                thermal_state=(ws.state.value if ws
                               else ThermalState.MINIMAL.value),
                slowdown=w.slowdown,
                state_occupancy=occ.get(w.name, {}),
                probes=w.probes,
            )
        per_group: Dict[str, GroupSnapshot] = {}
        for g in self.groups:
            members = {}
            for m in g.members:
                ws = self.monitor.workers.get(m.name)
                members[m.name] = {
                    "profile": m.spec.profile.name,
                    "duty": m.duty,
                    "slowdown": m.slowdown,
                    "util": m.util,
                    "probes": m.probes,
                    "thermal_state": (ws.state.value if ws
                                      else ThermalState.MINIMAL.value),
                    "state_occupancy": occ.get(m.name, {}),
                }
            per_group[g.name] = GroupSnapshot(
                name=g.name,
                workers=tuple(m.name for m in g.members),
                cuts=g.engine.cuts,
                engine=g.engine.metrics_snapshot(),
                completed=g.done_count,
                completed_tokens=g.done_tokens,
                goodput_tokens_per_s=g.done_tokens / sim,
                steps_run=g.steps_run,
                drained=g.drained,
                recuts=g.recuts,
                frames_sent=g.engine.frames_sent,
                frame_bytes=g.frame_bytes,
                recut_bytes=g.recut_bytes,
                transfer_s=g.transfer_s,
                link_stall_ticks=g.link_stall_ticks,
                members=members,
            )
        per_spec: Dict[str, SpecSnapshot] = {}
        for s in self.spec_pairs:
            members = {}
            for m in s.members:
                ws = self.monitor.workers.get(m.name)
                members[m.name] = {
                    "profile": m.spec.profile.name,
                    "duty": m.duty,
                    "slowdown": m.slowdown,
                    "util": m.util,
                    "probes": m.probes,
                    "thermal_state": (ws.state.value if ws
                                      else ThermalState.MINIMAL.value),
                    "state_occupancy": occ.get(m.name, {}),
                }
            per_spec[s.name] = SpecSnapshot(
                name=s.name,
                workers=(s.members[0].name, s.members[1].name),
                spec_k=s.spec.spec_k,
                draft_share=s.draft_share,
                engine=s.engine.metrics_snapshot(),
                completed=s.done_count,
                completed_tokens=s.done_tokens,
                goodput_tokens_per_s=s.done_tokens / sim,
                rounds_run=s.steps_run,
                drained=s.drained,
                colocated=s.engine.colocated,
                colocations=s.colocations,
                frame_bytes=s.frame_bytes,
                transfer_s=s.transfer_s,
                link_stall_ticks=s.link_stall_ticks,
                members=members,
            )
        total_tokens = self.completed_tokens
        units: List[_Routable] = [*self.workers, *self.groups,
                                  *self.spec_pairs]
        return FleetSnapshot(
            sim_t=self.sim_t,
            ticks=self.ticks,
            completed=len(self.completed),
            completed_tokens=total_tokens,
            goodput_tokens_per_s=total_tokens / sim,
            migrations=self.migrations,
            migrated_requests=len(self._migrated_rids),
            queue_moves=self.queue_moves,
            drains=self.drains,
            undrains=self.undrains,
            rejected=self.routing_rejected
            + sum(u.engine.scheduler.rejected_total for u in units),
            expired=sum(u.engine.scheduler.expired_total for u in units),
            per_worker=per_worker,
            per_group=per_group,
            per_spec=per_spec,
            recuts=self.recuts,
            probes=sum(w.probes for w in self.workers)
            + sum(m.probes for g in self.groups for m in g.members)
            + sum(m.probes for s in self.spec_pairs for m in s.members),
            transfer_bytes=sum(g.frame_bytes + g.recut_bytes
                               for g in self.groups)
            + sum(s.frame_bytes for s in self.spec_pairs),
            transfer_s=sum(g.transfer_s for g in self.groups)
            + sum(s.transfer_s for s in self.spec_pairs),
            deaths=self.deaths,
            resurrections=self.resurrections,
            recompute_tokens=self.recompute_tokens,
            orphaned=len(self._parked),
            checkpoints=self.checkpoints,
            dead_units=tuple(sorted(self._dead)),
        )


def drive_sim(fleet: ServingFleet, arrival_times: Sequence[float],
              submit, max_ticks: int = 1_000_000) -> float:
    """Open-loop driving in SIMULATED time: ``submit(i)`` is called when
    arrival ``i`` comes due on the fleet's sim clock, and the fleet ticks
    until every arrival is submitted and drained.  The sim-clock analogue
    of :func:`repro.serving.traffic.drive_open_loop` — shared so benches,
    demos and tests cannot drift apart on drive semantics.  Returns the
    simulated seconds elapsed."""
    t0 = fleet.sim_t
    n, i = len(arrival_times), 0
    for _ in range(max_ticks):
        while i < n and arrival_times[i] <= fleet.sim_t - t0:
            submit(i)
            i += 1
        if i >= n and fleet.idle():
            break
        fleet.tick()
    else:
        warnings.warn(
            f"drive_sim exhausted max_ticks={max_ticks} with work "
            f"outstanding ({len(fleet.completed)} finished)",
            RuntimeWarning, stacklevel=2)
    return fleet.sim_t - t0
