"""Pipeline-split decode: one model's layers spanning several engines.

The paper's actual topology (§4.1) is a single model cut across host +
phone; our fleet so far was a replica set, capping the servable model at
one worker's ``mem_bytes``.  This module is the stage-execution subsystem
that removes that cap:

* a :class:`PipelineEngine` owns ``max_batch`` decode lanes whose layers
  span N **stages** — stage 0 runs the below-the-cut layers and owns the
  low-layer KV (its own :class:`~repro.serving.backends.CacheBackend`
  instantiated over the layer slice via
  :func:`repro.models.api.stage_model`), stage 1 owns the rest;
* every boundary crossing — the full-prompt hidden states at prefill, the
  (B, 1, D) residual at each decode step — is a real **wire frame**:
  encoded and decoded through :mod:`repro.wire.codec`, so the byte counts
  the simulation charges against ``DeviceProfile.link_bw`` are the actual
  framed payloads (header + CRC included), and the token-identity claim
  covers the codec round-trip;
* the cut comes from :func:`repro.core.partition.split_decode` — serving
  rates + per-token boundary bytes + per-stage memory, searched over
  :class:`~repro.hw.specs.DeviceProfile`\\ s (see :func:`plan_decode_split`);
* :meth:`PipelineEngine.recut` re-cuts the split **token-identically**
  (the engine's preempt/resume contract: frozen per-lane sampler PRNG +
  generated-token re-prefill) and reports the layer-param bytes that
  crossed the wire — the elastic ``rebalance`` action in
  :mod:`repro.serving.fleet` charges them through the same link model.

The engine mirrors :class:`~repro.serving.engine.ServeEngine`'s fleet
surface (``submit / inject / pull_queued / feasible / preempt /
step / run_until_drained``), so fleet routing and migration treat a stage
group like any other worker.  Differences, deliberate for a first stage
plane: lanes are dense per stage (no paged pool across a cut yet), prefill
is per-request exact-length (no bucketed batching), and requests carrying
``extra`` model inputs are not admitted (the stage protocol carries tokens
and boundary hidden states only).

For external pacing, :meth:`PipelineEngine.step_paced` runs one engine
step eagerly and returns a :class:`StepReport` of everything the step
consumed (per-stage prefill tokens, every boundary frame's bytes) — the
fleet's :class:`~repro.serving.fleet.StageGroup` runtime turns that into
a sim-time charge queue where frames genuinely cross fleet ticks.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import DecodeSplitPlan, split_decode
from repro.hw.specs import DeviceProfile
from repro.models.api import (Model, param_bytes, split_stage_params,
                              stage_model)
from repro.serving.backends import make_backend
from repro.serving.engine import EngineConfig, Request, _shared_prefill_jits
from repro.serving.metrics import EngineSnapshot, MetricsCollector
from repro.serving.sampling import (GREEDY, Sampler, SamplingParams,
                                    resolve_sampling)
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig
from repro.wire import codec


# ---------------------------------------------------------------------------
# cut planning
# ---------------------------------------------------------------------------
def boundary_frame_bytes(model: Model, max_batch: int) -> int:
    """Wire bytes of one decode-step boundary frame — the (B, 1, D)
    residual as the codec actually frames it (headers + CRC included)."""
    dt = np.dtype(jnp.dtype(model.rcfg.compute_dtype))
    return len(codec.dumps(np.zeros((max_batch, 1, model.cfg.d_model), dt)))


def decode_block_costs(model: Model, params, max_batch: int, max_len: int
                       ) -> List[Tuple[float, float, float]]:
    """Per-layer ``(share, boundary_bytes, mem_bytes)`` for
    :func:`repro.core.partition.split_decode`.

    ``share`` is uniform (a uniform transformer's layers cost the same),
    ``boundary_bytes`` is the real framed decode-step payload, and
    ``mem_bytes`` is the layer's params plus its KV share at ``max_len``
    for ``max_batch`` dense lanes — i.e. what the layer pins on whichever
    stage it lands on."""
    from repro.models.attention import cache_span

    cfg = model.cfg
    n = cfg.n_layers
    frame = boundary_frame_bytes(model, max_batch)
    layer_params = param_bytes(params["blocks"]) / n
    itemsize = np.dtype(jnp.dtype(model.rcfg.compute_dtype)).itemsize
    kv_layer = (max_batch * cache_span(cfg, max_len) * cfg.n_kv_heads
                * cfg.head_dim * 2 * itemsize)
    return [(1.0 / n, float(frame), layer_params + kv_layer)] * n


def stage_fixed_mem(model: Model, params, n_stages: int) -> Tuple[float, ...]:
    """Per-stage constant bytes: the embedding table on stage 0, the final
    norm + head on the last (tied embeddings ship the table to both ends,
    and are charged on both)."""
    embed_b = param_bytes(params["embed"])
    tail = param_bytes(params["final_ln"])
    tail += param_bytes(params["head"]) if "head" in params else embed_b
    fixed = [0.0] * n_stages
    fixed[0] += embed_b
    fixed[-1] += tail
    return tuple(fixed)


def plan_decode_split(model: Model, params,
                      devices: Sequence[DeviceProfile], *,
                      max_batch: int, max_len: int) -> DecodeSplitPlan:
    """Pick the serving cut for ``devices`` from the model's real byte and
    rate numbers (the §4.1 hand-tuned split as a cost search)."""
    costs = decode_block_costs(model, params, max_batch, max_len)
    return split_decode(costs, devices,
                        stage_fixed_mem=stage_fixed_mem(model, params,
                                                        len(devices)))


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepReport:
    """What one (eagerly executed) engine step consumed — the sim layer's
    charge sheet.  ``prefill_frame_bytes[i]`` / ``decode_frame_bytes[i]``
    are the wire bytes that crossed boundary i (between stages i and
    i+1); ``decode_stage_wall_s[i]`` is the MEASURED wall time of stage
    i's decode dispatch (the fleet's telemetry="wall" feed for group
    members)."""
    admissions: int = 0
    prefill_tokens: int = 0
    prefill_frame_bytes: List[int] = dataclasses.field(default_factory=list)
    decode_frame_bytes: List[int] = dataclasses.field(default_factory=list)
    decode_stage_wall_s: List[float] = dataclasses.field(
        default_factory=list)
    decode_step: bool = False
    active: int = 0


class _Stage:
    """One layer slice: its model view, params, cache backend, prefill."""

    def __init__(self, full_model: Model, params, lo: int, hi: int,
                 max_batch: int, max_len: int, config: EngineConfig):
        self.lo, self.hi = lo, hi
        self.model = stage_model(full_model, lo, hi)
        self.params = params
        self.backend = make_backend(self.model, max_batch, max_len, config)
        self.prefill, _ = _shared_prefill_jits(self.model, max_len)

    @property
    def n_layers(self) -> int:
        return self.hi - self.lo


class PipelineEngine:
    """Continuous-batching decode over lanes whose layers span stages.

    ``cuts`` are block indices where each next stage starts (as in
    :class:`~repro.core.partition.DecodeSplitPlan`).  ``params`` may be
    the full tree (it is sliced per stage and not retained) or a
    pre-split list from :func:`repro.models.api.split_stage_params`.
    """

    def __init__(self, model: Model, params, max_batch: int, max_len: int,
                 cuts: Sequence[int], eos_id: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 config: Optional[EngineConfig] = None,
                 clock=None):
        self.model = model
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.config = config or EngineConfig()
        self._now = clock or time.perf_counter
        self.vocab = int(model.cfg.vocab_size)
        self.scheduler = AdmissionScheduler(scheduler)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.sampler = Sampler(max_batch)
        # legacy alias: fleet/preempt code reads lane arrays through here
        self.lane_sampling = self.sampler.lanes
        self._rid = 0
        self.steps = 0
        self.recuts = 0
        self.finished: List[Request] = []
        self.metrics = MetricsCollector(n_slots=max_batch)
        # wire-plane counters (the fleet reads them for FleetSnapshot)
        self.frames_sent = 0
        self.frame_bytes_total = 0
        self.prefill_frame_bytes_total = 0
        self.decode_frame_bytes_total = 0
        if isinstance(params, dict):
            params = split_stage_params(model, params, cuts)
        self._build_stages(tuple(int(c) for c in cuts), params)

    def _build_stages(self, cuts: Tuple[int, ...],
                      stage_params: List[dict]) -> None:
        n = self.model.cfg.n_layers
        bounds = (0,) + cuts + (n,)
        if len(stage_params) != len(bounds) - 1:
            raise ValueError(f"{len(stage_params)} param slices for "
                             f"{len(bounds) - 1} stages")
        self.cuts = cuts
        self.stages = [
            _Stage(self.model, stage_params[i], bounds[i], bounds[i + 1],
                   self.max_batch, self.max_len, self.config)
            for i in range(len(bounds) - 1)
        ]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def stage_param_bytes(self) -> Tuple[int, ...]:
        return tuple(param_bytes(st.params) for st in self.stages)

    # ------------------------------------------------------------------
    # submission / admission (ServeEngine fleet surface)
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None, **extra) -> Optional[int]:
        sampling = resolve_sampling(sampling, extra)
        if extra:
            raise ValueError(
                "pipeline-split lanes carry tokens and boundary hidden "
                "states only; extra model inputs are not supported")
        rid = self._rid
        self._rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new,
                      submitted_t=self._now(), sampling=sampling or GREEDY,
                      priority=priority, deadline_s=deadline_s)
        if not self.scheduler.push(req, req.submitted_t):
            return None
        return rid

    def inject(self, req: Request, *, force: bool = False) -> bool:
        req.fp_memo = None
        self._rid = max(self._rid, req.rid + 1)
        if force:
            self.scheduler.requeue(req)
            return True
        return self.scheduler.push(req, self._now())

    def pull_queued(self) -> List[Request]:
        return self.scheduler.take_all()

    def feasible(self, req: Request) -> bool:
        # dense stage lanes admit any token-only request (writes past
        # max_len clamp, as dense lanes always did); requests with extra
        # model inputs can't cross a cut
        return not req.extra

    def lane_cost(self, slot: int) -> Tuple[int, int]:
        """(recompute_tokens, footprint) of an active lane — the fleet's
        cost-aware migration victim ordering.  Stage lanes are dense and
        recompute on resume, so recompute = the full context re-prefill."""
        req = self.slots[slot]
        return self._ctx_len(req), self.max_len

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        if not req.out_tokens:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.out_tokens, np.int32)])

    def _ctx_len(self, req: Request) -> int:
        return len(req.prompt) + len(req.out_tokens)

    def _final_len(self, req: Request) -> int:
        return self._ctx_len(req) - len(req.out_tokens) + req.max_new - 1

    # ------------------------------------------------------------------
    # wire plane
    # ------------------------------------------------------------------
    def _ship(self, arr, *, prefill: bool) -> Tuple[jnp.ndarray, int]:
        """Push boundary activations through the real wire codec: the
        next stage decodes the framed bytes, and the byte count is what
        the simulation charges against the link."""
        payload = codec.dumps(np.asarray(arr))
        n = len(payload)
        self.frames_sent += 1
        self.frame_bytes_total += n
        if prefill:
            self.prefill_frame_bytes_total += n
        else:
            self.decode_frame_bytes_total += n
        return jnp.asarray(codec.loads(payload)), n

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admit(self, rep: StepReport) -> None:
        while self._admit_once(rep):
            pass

    def _admit_once(self, rep: StepReport) -> bool:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        now = self._now()
        batch = self.scheduler.pop(len(free), now)
        if not batch:
            return False
        n_done_before = len(self.finished)
        for req in batch:
            self._admit_one(req, free.pop(0), now, rep)
        return (len(self.finished) > n_done_before
                and self.scheduler.depth > 0)

    def _admit_one(self, req: Request, slot: int, now: float,
                   rep: StepReport) -> None:
        seq = self._prefill_tokens(req)
        n_ctx = len(seq)
        out = x = None
        for i, st in enumerate(self.stages):
            b = {"tokens": jnp.asarray(seq[None])} if i == 0 \
                else {"hidden": x}
            out, cache1 = st.prefill(st.params, b)
            res = st.backend.alloc(n_ctx, self._final_len(req), None)
            st.backend.prefill_paste(slot, cache1, 0, n_ctx, n_ctx, res)
            if i < self.n_stages - 1:
                x, nb = self._ship(out, prefill=True)
                rep.prefill_frame_bytes[i] += nb
        rep.admissions += 1
        rep.prefill_tokens += n_ctx
        self.metrics.on_prefill(1, n_ctx)

        ls = self.lane_sampling
        self.sampler.set_lane(slot, req.sampling)
        if req.saved_key is not None:
            ls.key[slot] = req.saved_key
        tok = int(self.sampler.sample(np.asarray(out)[:, :self.vocab],
                                      lanes=[slot])[0])
        t_first = self._now()
        req.out_tokens.append(tok)
        if req.admitted_t is None:
            req.first_token_t = t_first
            self.metrics.on_admit(req, now)
        else:
            self.metrics.on_resume(req, now)
        req.admitted_t = now
        req.saved_key = None
        if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
            req.done_t = t_first
            ls.clear_lane(slot)
            for st in self.stages:
                st.backend.release(slot)
            self.finished.append(req)
            self.metrics.on_finish(req, t_first)
            return
        self.slots[slot] = req

    # ------------------------------------------------------------------
    # preemption / re-cut
    # ------------------------------------------------------------------
    def preempt(self, slot: int, requeue: bool = True) -> Request:
        """Evict the lane token-identically (frozen sampler PRNG +
        generated-token re-prefill).  ``requeue=False`` is the fleet's
        migration hook, exactly as on :class:`ServeEngine`."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"lane {slot} is idle: nothing to preempt")
        req.preemptions += 1
        req.saved_key = self.lane_sampling.key[slot].copy()
        req.saved_state = None          # dense stage lanes recompute
        for st in self.stages:
            st.backend.release(slot)
        self.slots[slot] = None
        self.lane_sampling.clear_lane(slot)
        if requeue:
            self.scheduler.requeue(req)
        self.metrics.on_preempt(req)
        return req

    def forget_lane(self, slot: int) -> Request:
        """Release a lane whose device state is gone (worker death):
        :meth:`ServeEngine.forget_lane` semantics for a split engine —
        no snapshot (the stages' devices are unreachable), every stage's
        lane freed, nothing registered in any cache."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"lane {slot} is idle: nothing to forget")
        req.preemptions += 1
        for st in self.stages:
            st.backend.release(slot)
        self.slots[slot] = None
        self.lane_sampling.clear_lane(slot)
        self.metrics.on_preempt(req)
        return req

    def recut(self, cuts: Sequence[int]) -> int:
        """Re-cut the split (elastic rebalance): preempt every lane into
        the local queue (they re-admit token-identically through the new
        stages), reassemble the layer slices to the new bounds, and
        return the bytes of layer params that changed stage — the weight
        traffic a real re-cut pays over the link before decode resumes."""
        cuts = tuple(int(c) for c in cuts)
        if cuts == self.cuts:
            return 0
        for slot, req in enumerate(self.slots):
            if req is not None:
                self.preempt(slot)
        n = self.model.cfg.n_layers
        blocks = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *[st.params["blocks"] for st in self.stages])
        full = {"blocks": blocks,
                "embed": self.stages[0].params["embed"],
                "final_ln": self.stages[-1].params["final_ln"]}
        if "head" in self.stages[-1].params:
            full["head"] = self.stages[-1].params["head"]

        def stage_of(bounds: Tuple[int, ...], layer: int) -> int:
            return sum(1 for c in bounds if c <= layer)

        layer_bytes = param_bytes(blocks) / n
        moved = sum(layer_bytes for layer in range(n)
                    if stage_of(self.cuts, layer) != stage_of(cuts, layer))
        self._build_stages(cuts, split_stage_params(self.model, full, cuts))
        self.recuts += 1
        return int(moved)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step_paced(self) -> Optional[StepReport]:
        """One engine step, executed eagerly, returning its charge sheet
        for a sim layer (``None`` = nothing to do).  ``step()`` is the
        unpaced convenience wrapper."""
        rep = StepReport(
            prefill_frame_bytes=[0] * (self.n_stages - 1))
        self._admit(rep)
        if self.active() == 0:
            return rep if rep.admissions else None
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            toks[i, 0] = req.out_tokens[-1] if req.out_tokens \
                else req.prompt[-1]
        active = np.asarray([s is not None for s in self.slots])
        x = jnp.asarray(toks)
        out = None
        for i, st in enumerate(self.stages):
            t0 = time.perf_counter()
            out = st.backend.step(st.params, x, active)
            jax.block_until_ready(out)
            rep.decode_stage_wall_s.append(time.perf_counter() - t0)
            if i < self.n_stages - 1:
                x, nb = self._ship(out, prefill=False)
                rep.decode_frame_bytes.append(nb)
        ls = self.lane_sampling
        # the step's one deliberate device->host sync: the last stage's
        # logits feed the host-side sampler in a single batched transfer
        nxt = self.sampler.sample(  # repro-lint: allow[R004] single batched logits transfer per step
            np.asarray(out)[:, :self.vocab]).tolist()
        now = self._now()
        busy = self.active()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = nxt[i]
            req.out_tokens.append(tok)
            if req.first_token_t is None:
                req.first_token_t = now
            if len(req.out_tokens) >= req.max_new or tok == self.eos_id:
                req.done_t = now
                self.slots[i] = None
                ls.clear_lane(i)
                for st in self.stages:
                    st.backend.release(i)
                self.finished.append(req)
                self.metrics.on_finish(req, now)
        self.steps += 1
        self.metrics.on_step(self.scheduler.depth, busy, now)
        rep.decode_step = True
        rep.active = busy
        return rep

    def step(self) -> int:
        self.step_paced()
        return self.active()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self.scheduler.depth:
                break
        else:
            if self.active() or self.scheduler.depth:
                warnings.warn(
                    f"run_until_drained exhausted max_steps={max_steps} "
                    f"with {self.active()} active lanes and "
                    f"{self.scheduler.depth} queued requests — returning "
                    f"PARTIAL results ({len(self.finished)} finished)",
                    RuntimeWarning, stacklevel=2)
        return self.finished

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.peek_order()

    def reset_stats(self) -> None:
        self.finished.clear()
        self.scheduler.rejected.clear()
        self.scheduler.expired.clear()
        self.scheduler.rejected_total = 0
        self.scheduler.expired_total = 0
        self.steps = 0
        self.metrics = MetricsCollector(n_slots=self.max_batch)
        self.frames_sent = 0
        self.frame_bytes_total = 0
        self.prefill_frame_bytes_total = 0
        self.decode_frame_bytes_total = 0

    def metrics_snapshot(self) -> EngineSnapshot:
        return self.metrics.snapshot(
            queue_depth_now=self.scheduler.depth,
            rejected=self.scheduler.rejected_total,
            expired=self.scheduler.expired_total)
