"""Admission scheduling for the serving engine.

The engine asks the scheduler which queued requests to prefill whenever
decode lanes free up; the scheduler answers according to a pluggable policy
and enforces queue limits and per-request deadlines:

* ``fcfs``      — first come, first served (arrival order).
* ``spf``       — shortest-prompt-first: cheapest prefill next, which
  minimises mean TTFT under backlog (classic SJF argument).
* ``priority``  — higher ``Request.priority`` first; FCFS within a class.

``max_queue`` bounds the backlog (``submit`` is rejected beyond it — the
open-loop overload answer is admission control, not an unbounded queue), and
a request whose ``deadline_s`` elapses while still queued is dropped at pop
time rather than wasting prefill compute on an answer nobody is waiting for.

``pop`` is additionally FOOTPRINT-AWARE: a cache backend with a finite
capacity budget (the paged layout: free pool tokens, prefix-cache aware)
passes it with ``token_footprint``, and requests are packed against real
memory instead of popped blindly and bounced back; lane-bound backends
(dense, recurrent) pass no budget and get the plain take-k pop.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional

POLICIES = ("fcfs", "spf", "priority")
KEEP_DROPPED = 256          # recent rejected/expired kept for introspection


@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "fcfs"
    max_queue: Optional[int] = None      # None = unbounded
    default_deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; expected one of {POLICIES}")


class AdmissionScheduler:
    """Holds the waiting queue; policy decides pop order, limits decide drops.

    Works on any request object exposing ``rid``, ``prompt`` (sized),
    ``priority``, ``submitted_t`` and optional ``deadline_s`` — i.e. the
    engine's Request.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._queue: List = []
        # bounded recency windows (totals are separate counters so a
        # long-lived overloaded engine doesn't hoard dropped Request objects)
        self.rejected = collections.deque(maxlen=KEEP_DROPPED)
        self.expired = collections.deque(maxlen=KEEP_DROPPED)
        self.rejected_total = 0
        self.expired_total = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._queue)

    @property
    def depth(self) -> int:
        return len(self._queue)

    def push(self, req, now: float) -> bool:
        """Queue ``req``; False = rejected because the queue is full."""
        mq = self.config.max_queue
        if mq is not None and len(self._queue) >= mq:
            self.reject(req)
            return False
        if req.deadline_s is None:
            req.deadline_s = self.config.default_deadline_s
        self._queue.append(req)
        return True

    def requeue(self, req) -> None:
        """Return a previously popped request to the queue, bypassing
        ``max_queue`` — used for engine-side spills (KV blocks exhausted at
        admission) and preemptions, which must never be dropped.  The
        request keeps its original ``submitted_t``, so FCFS ranks it ahead
        of everything that arrived after it."""
        self._queue.append(req)

    def take_all(self) -> List:
        """Remove and return every queued request (no policy ordering) —
        the fleet re-routes a drained worker's backlog through it."""
        taken, self._queue = self._queue, []
        return taken

    def reject(self, req) -> None:
        """Record a request the engine can never run (admission control)."""
        self.rejected.append(req)
        self.rejected_total += 1

    def _drop_expired(self, now: float) -> None:
        live = []
        for r in self._queue:
            # deadlines bound QUEUE wait before first admission; a request
            # requeued mid-flight (preemption — admitted_t set) already has
            # tokens a client is owed and must never expire here
            started = getattr(r, "admitted_t", None) is not None
            if (not started and r.deadline_s is not None
                    and now - r.submitted_t > r.deadline_s):
                self.expired.append(r)
                self.expired_total += 1
            else:
                live.append(r)
        self._queue = live

    def _rank(self) -> Callable:
        # stable sort keyed per policy; arrival order breaks every tie
        if self.config.policy == "spf":
            return lambda r: (len(r.prompt), r.submitted_t, r.rid)
        if self.config.policy == "priority":
            return lambda r: (-r.priority, r.submitted_t, r.rid)
        return lambda r: (r.submitted_t, r.rid)

    def pop(self, k: int, now: float, footprint: Optional[Callable] = None,
            budget: Optional[int] = None,
            capacity: Optional[int] = None) -> List:
        """Take up to ``k`` requests to admit, best-first per policy.

        Footprint-aware admission: when the engine's cache backend exposes
        a capacity ``budget`` (e.g. free paged-KV tokens, prefix-cache
        aware), a request whose ``footprint(req)`` exceeds the remaining
        budget is SKIPPED — left queued, in order — and cheaper requests
        behind it may be packed instead of the whole pop stalling on one
        big prompt.  A request too big even for ``capacity`` (the whole
        pool) is still popped: the backend's ``alloc`` is the authority
        that rejects infeasible work up front, and hiding it in the queue
        forever would silently drop it."""
        if k <= 0:
            return []
        self._drop_expired(now)
        self._queue.sort(key=self._rank())
        if footprint is None or budget is None:
            taken, self._queue = self._queue[:k], self._queue[k:]
            return taken
        taken, kept = [], []
        remaining = budget
        for r in self._queue:
            if len(taken) >= k:
                kept.append(r)
                continue
            f = footprint(r)
            if f > remaining and (capacity is None or f <= capacity):
                kept.append(r)            # may fit later: keep waiting
                continue
            remaining -= f
            taken.append(r)
        self._queue = kept
        return taken

    def peek_order(self) -> List:
        """Current admission order (no side effects) — for introspection."""
        return sorted(self._queue, key=self._rank())

    def stats(self) -> Dict[str, int]:
        return {"depth": len(self._queue),
                "rejected": self.rejected_total,
                "expired": self.expired_total}
