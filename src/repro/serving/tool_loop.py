"""Agentic tool-use loop (paper §4.3).

The paper ran Qwen3-8B through a scripted scenario: begin three vector-DB
searches, then alternately retrieve a result and generate a summary — the
split begin/retrieve tools let searches run on the iOS worker WHILE the LRM
generates.  No pretrained weights ship in this container (DESIGN §8.5), so
the agent policy is the deterministic script from the paper's appendix A.4
and "summarization" is real timed decode work on the locally-served model;
the measured artifact — tool latency disappearing from the critical path —
is identical in structure to the paper's Fig. 7/8.

Two modes:
* ``async_tools=True``  (paper's system): begin all searches up-front, decode
  while they run, retrieve FIFO between summaries.
* ``async_tools=False`` (paper's Fig. 8 baseline): call tool, WAIT for it,
  then summarise.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.offload.tools import ToolExecutor
from repro.serving.engine import ServeEngine
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class Span:
    kind: str          # reason | tool_wait | summarize
    t0: float
    t1: float
    label: str = ""

    @property
    def seconds(self):
        return self.t1 - self.t0


@dataclasses.dataclass
class AgentTrace:
    spans: List[Span] = dataclasses.field(default_factory=list)
    t_start: float = 0.0
    t_end: float = 0.0

    @property
    def total(self):
        return self.t_end - self.t_start

    def time_in(self, kind: str) -> float:
        return sum(s.seconds for s in self.spans if s.kind == kind)

    def timeline(self) -> List[dict]:
        return [dict(kind=s.kind, start=round(s.t0 - self.t_start, 4),
                     end=round(s.t1 - self.t_start, 4), label=s.label)
                for s in self.spans]


def _generate(engine: ServeEngine, prompt: np.ndarray, n_tokens: int,
              sampling: Optional[SamplingParams] = None,
              prefix: Optional[np.ndarray] = None) -> None:
    """Timed decode work standing in for LRM reasoning/summarisation.

    ``prefix`` is the scenario scaffold (system prompt + tool-loop
    boilerplate) prepended to EVERY turn — exactly the shape of agentic
    traffic that a prefix-cached engine serves without re-prefilling the
    scaffold on each turn."""
    if prefix is not None:
        prompt = np.concatenate([np.asarray(prefix, np.int32),
                                 np.asarray(prompt, np.int32)])
    engine.submit(prompt, max_new=n_tokens, sampling=sampling)
    engine.run_until_drained()


def run_scenario(engine: ServeEngine, executor: ToolExecutor,
                 queries: List[str], *, async_tools: bool,
                 reason_tokens: int = 12, summary_tokens: int = 24,
                 seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 prefix_tokens: int = 0) -> AgentTrace:
    """The paper's A.4 scenario: N begin_search (async) or N [search+wait]
    (sync), then per query: retrieve -> summarize.

    ``prefix_tokens > 0`` prepends a fixed scenario prefix (seeded, so
    every turn shares it) to each generation turn, driving the engine's
    prefix cache end-to-end: turn 1 populates it, later turns admit
    against shared blocks and (once fully cached) skip prefill."""
    rng = np.random.default_rng(seed)
    vocab = engine.model.cfg.vocab_size
    prompt = lambda: rng.integers(0, vocab, size=8)
    prefix = (np.random.default_rng(seed + 1).integers(
        0, vocab, size=prefix_tokens) if prefix_tokens else None)
    trace = AgentTrace(t_start=time.perf_counter())

    def span(kind, label=""):
        class _S:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *a):
                trace.spans.append(Span(kind, self.t0, time.perf_counter(),
                                        label))
        return _S()

    if async_tools:
        # paper's system: queue ALL searches, reason while they run
        for q in queries:
            executor.begin("vector_db_begin_search", query=q, k=5)
        with span("reason", "initial reasoning / planning"):
            _generate(engine, prompt(), reason_tokens, sampling, prefix)
        for q in queries:
            with span("tool_wait", f"retrieve({q})"):
                executor.retrieve()
            with span("summarize", f"summary({q})"):
                _generate(engine, prompt(), summary_tokens, sampling, prefix)
    else:
        # Fig. 8 baseline: tool on the critical path
        with span("reason", "initial reasoning / planning"):
            _generate(engine, prompt(), reason_tokens, sampling, prefix)
        for q in queries:
            executor.begin("vector_db_begin_search", query=q, k=5)
            with span("tool_wait", f"search({q}) [blocking]"):
                executor.retrieve()
            with span("summarize", f"summary({q})"):
                _generate(engine, prompt(), summary_tokens, sampling, prefix)

    trace.t_end = time.perf_counter()
    return trace
