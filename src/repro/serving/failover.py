"""Failure plane for the serving fleet: heartbeats + lane checkpoints.

The paper's phones are the least reliable workers imaginable — battery
death, thermal shutdown, iOS backgrounding — yet until this module the
fleet only modelled *throttling*, never *disappearance*.  Two pieces make
a worker's death survivable:

* :class:`HeartbeatMonitor` — missed-probe detection layered on the
  fleet's existing paced telemetry.  Every decode step or paced probe a
  member executes IS its heartbeat (``ServingFleet._observe_or_probe``
  feeds :meth:`beat`); liveness costs nothing extra, exactly as on a real
  fleet where "the worker answered" is the signal.  A member whose last
  beat is older than ``suspect_after`` probe intervals is SUSPECT (routed
  around, lanes untouched); older than ``dead_after`` intervals is DEAD
  (its unit's lanes are resurrected elsewhere).  Thresholds are in
  multiples of the fleet's ``probe_every_s`` so tightening the probe
  cadence tightens detection with it.

* :class:`LaneCheckpoint` — the resurrection state: every
  ``checkpoint_every_s`` sim seconds the fleet snapshots each active
  lane's generated-token count, its frozen sampler PRNG counter, and
  whatever the backend can save cheaply (``CacheBackend.snapshot`` —
  free constant-size state for recurrent backends, ``None`` for
  dense/paged whose KV dies with the device).  A dead worker's request
  is rolled back to its checkpoint and re-admitted on a survivor through
  the same preempt/inject machinery migration uses, so the resume is
  **token-identical**: recurrent lanes restore state outright; paged and
  dense lanes re-prefill — through the destination's refcounted prefix
  cache when the content is there — with recompute bounded by
  tokens-since-checkpoint plus one context re-prefill.

Pure control-plane code: no jax, no wall clock, no global RNG
(repro-lint R002) — the jax-free scale plane imports it too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class FailoverConfig:
    """Failure-plane knobs.

    ``suspect_after`` / ``dead_after`` are in units of the fleet's
    ``probe_every_s`` (a healthy member beats at least once per probe
    interval, so "2 missed intervals" is meaningful at any cadence);
    ``checkpoint_every_s`` is in sim seconds and bounds resurrection
    recompute: a resurrected lane replays at most
    ``checkpoint_every_s * decode_rate`` generated tokens plus one
    context re-prefill."""
    checkpoint_every_s: float = 0.5
    suspect_after: float = 2.0       # missed probe intervals -> SUSPECT
    dead_after: float = 4.0          # missed probe intervals -> DEAD

    def __post_init__(self) -> None:
        if self.dead_after <= self.suspect_after:
            raise ValueError(
                f"dead_after ({self.dead_after}) must exceed suspect_after "
                f"({self.suspect_after}): a worker can't be dead before "
                "it's suspect")
        if self.checkpoint_every_s <= 0:
            raise ValueError("checkpoint_every_s must be positive")


@dataclasses.dataclass(frozen=True)
class LaneCheckpoint:
    """Resurrection state of one active lane at checkpoint time.

    ``key`` is a copy of the lane's sampler PRNG counter (the stream
    resumes exactly where the checkpoint left it); ``state`` is the
    backend snapshot (recurrent: host-side state, zero-recompute resume)
    or ``None`` (dense/paged: resume re-prefills context).  Host-side
    control-plane data only — nothing here lives on the dead device."""
    rid: int
    out_len: int                     # generated tokens at checkpoint
    key: Optional[Any]               # sampler PRNG counter copy
    state: Optional[Any]             # backend snapshot or None
    t_s: float                       # sim time the checkpoint was taken


class HeartbeatMonitor:
    """Last-seen tracking with suspect/dead thresholds.

    The fleet feeds :meth:`beat` from its paced-probe machinery; the
    monitor never reads a clock itself — ``now`` is always the caller's
    sim time, so seeded replays are pure functions of their seed."""

    def __init__(self, names: Iterable[str], probe_every_s: float,
                 cfg: Optional[FailoverConfig] = None, t0: float = 0.0):
        self.cfg = cfg or FailoverConfig()
        self.probe_every_s = probe_every_s
        self.last_seen: Dict[str, float] = {n: t0 for n in names}

    def beat(self, name: str, now: float) -> None:
        """Record liveness: ``name`` executed a step or answered a probe."""
        self.last_seen[name] = now

    def gap(self, name: str, now: float) -> float:
        """Sim seconds since ``name`` was last seen."""
        return now - self.last_seen[name]

    def state(self, name: str, now: float) -> str:
        """``"alive"`` / ``"suspect"`` / ``"dead"`` from the beat gap."""
        g = self.gap(name, now)
        if g >= self.cfg.dead_after * self.probe_every_s:
            return DEAD
        if g >= self.cfg.suspect_after * self.probe_every_s:
            return SUSPECT
        return ALIVE
