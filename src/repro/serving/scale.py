"""Scale plane: a vectorized fleet simulator for production-size benches.

:class:`repro.serving.fleet.ServingFleet` paces *real* jax engines in
simulated time — perfect for token-identity claims, useless at the
ROADMAP's "heavy traffic from millions of users" scale: every tick walks
Python loops over workers, lanes and charge queues, and every worker runs
real forward passes.  :class:`SimFleet` keeps the fleet's capacity
semantics (device serving rates, thermal reservoirs, duty/drain policy,
probe pacing, routing score shape) and drops the model math, so hundreds
of workers and tens of thousands of requests simulate in CI seconds.

Two interchangeable tick implementations:

* ``impl="loop"`` — the pre-refactor idiom: per-worker, per-step, per-lane
  Python loops, one token decrement at a time (how ServingFleet's tick is
  structured today).
* ``impl="vector"`` — numpy structure-of-arrays bookkeeping: worker state
  lives in flat float/int arrays, decode grants are closed-form
  (``min(floor(credit/step_cost), max lane need)`` per row), probes are
  batched mask updates.

Both produce **bit-identical** results (same float expression trees, same
event ordering), so the loop baseline is an honest yardstick for the
micro-bench's >=10x tick-throughput gate and a semantic oracle in tests.

On top of the tick core the SimFleet adds the production-scale control
surface the real fleet doesn't have yet:

* **admission control** — reject-at-submit when even the best worker's
  *predicted* TTFT (queued prefill + decode backlog, derated by thermal
  slowdown and duty) would blow the request's deadline (or its SLO class
  TTFT target).  Shed is counted separately from capacity rejects.
* **autoscaling** — an :class:`repro.runtime.elastic.AutoscalePolicy`
  consumes a :class:`~repro.runtime.elastic.FleetLoad` reading each tick;
  scale-up brings spare rows up with params charged over the link as
  warm-up seconds before they serve, scale-down drains a worker's lanes
  and queue then retires it.
* **failure plane** — a seeded :class:`repro.runtime.faults.KillTrace`
  marks rows dead mid-run (crash / partition / zombie).  Dead rows stop
  earning credit immediately; after ``detect_s`` the fleet strands their
  lanes and queue onto survivors.  Lane checkpoints (a ``lane_rem``
  snapshot every ``ckpt_every_s``) bound the redo: a stranded lane
  resumes from its checkpoint, charging only the tokens decoded since
  plus a re-prefill of the prompt to ``recompute_tokens``.  Partitions
  that heal before detection are transparent blips; zombies return cold
  (heat, credit and warm-up reset).  All fault phases are shared code,
  so ``impl="loop"`` and ``impl="vector"`` stay bit-identical under
  kills.
* **training plane** — with ``fed=FedSimConfig(...)`` the fleet mirrors
  :class:`repro.serving.train_plane.FedRoundCoordinator` at capacity
  level: federated rounds pay cold training seconds out of the same
  per-tick credit decode spends (serving-idle, thermally-eligible rows
  only; preemption counted), ship one update frame per participant over
  the row's link, heat the thermal reservoir like any busy time, and
  compose with the failure plane (a detected-dead participant is
  excluded from its round).  The fed phase is shared code, so loop and
  vector stay bit-identical with training on.

``SimFleet`` duck-types :func:`repro.serving.fleet.drive_sim` (``sim_t`` /
``tick`` / ``idle`` / ``completed``), and :func:`play` drives a
:class:`~repro.serving.traffic.TrafficTrace` end-to-end without importing
the jax-backed fleet at all.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.hw.specs import DeviceProfile
from repro.runtime.elastic import AutoscalePolicy, FleetLoad
from repro.runtime.faults import KillTrace
from repro.runtime.monitor import THRESHOLDS
from repro.serving.metrics import (OUTCOME_DONE, OUTCOME_EXPIRED,
                                   OUTCOME_REJECTED, OUTCOME_SHED, SLOClass,
                                   SLOReport, slo_report)

# slowdown thresholds for MINIMAL/FAIR/SERIOUS/CRITICAL ranks (0..3),
# shared with the real fleet's ThermalMonitor state machine
_RANK_EDGES = np.array([thr for thr in THRESHOLDS.values()][1:],
                       dtype=np.float64)

# non-terminal request states (terminal ones are the metrics OUTCOME_* ids)
_QUEUED = -1
_ACTIVE = -2


@dataclasses.dataclass(frozen=True)
class ScaleWorkerSpec:
    """Template for one SimFleet row: a replica worker, or — with
    ``n_members > 1`` — a pipeline-split StageGroup modelled at capacity
    level (members contribute their slice of each pass; boundary
    activations cost ``frame_bytes`` over the profile's link per hop)."""
    profile: DeviceProfile
    max_batch: int = 8
    max_queue: int = 64
    n_members: int = 1
    frame_bytes: int = 4096

    def decode_rate(self) -> float:
        """Effective batched decode steps/s of the unit."""
        step_s = 1.0 / self.profile.decode_rate()
        if self.n_members > 1:
            step_s += ((self.n_members - 1) * self.frame_bytes
                       / self.profile.link_bw)
        return 1.0 / step_s

    def prefill_rate(self) -> float:
        return self.profile.prefill_rate()

    def warm_s(self, param_bytes: float) -> float:
        """Seconds to stream ``param_bytes`` of params over the link before
        this row can serve; a split group ships its slices in parallel."""
        if param_bytes <= 0:
            return 0.0
        return param_bytes / max(self.n_members, 1) / self.profile.link_bw


def make_rows(spec: ScaleWorkerSpec, n: int) -> List[ScaleWorkerSpec]:
    """``n`` identical rows (the common homogeneous-pool case)."""
    return [spec] * n


@dataclasses.dataclass(frozen=True)
class FedSimConfig:
    """Capacity-level mirror of the training plane
    (:mod:`repro.serving.train_plane`) for the jax-free SimFleet: rounds
    of per-participant training compute charged from the SAME per-tick
    credit decode spends — only in serving-idle, thermally-eligible ticks
    — plus one update frame per participant charged over the link.  No
    model math runs; the mirror keeps the *scheduling* semantics so the
    serve-while-train SLO A/B gates at production scale."""
    rounds: int = 4
    participants: int = 2
    local_steps: int = 2
    step_tokens: int = 128          # batch * seq_len per local step
    flops_mult: float = 3.0         # fwd+bwd+update cost vs one forward
    frame_bytes: int = 1 << 16      # encoded update frame size
    max_rank: int = 2               # preempt at SERIOUS or worse
    round_timeout_s: float = 60.0


@dataclasses.dataclass(frozen=True)
class ScaleSnapshot:
    """One frozen reading of a SimFleet run.  Everything is hashable /
    equality-comparable, so determinism tests can assert two seeded runs
    (or the loop and vector implementations) produced the *same* snapshot."""
    sim_t: float
    ticks: int
    offered: int
    completed: int
    completed_tokens: int
    goodput_tokens_per_s: float
    shed: int                 # admission-control rejects (predicted TTFT miss)
    rejected: int             # capacity rejects (every eligible queue full)
    expired: int              # deadline passed while queued
    queued_now: int
    active_now: int
    serving_now: int
    peak_serving: int
    scale_ups: int            # scale-up events (rows brought up)
    scale_downs: int          # scale-down events (rows sent to retire)
    retired: int              # rows fully drained and dropped
    warm_bytes_total: float   # param bytes charged over links by scale-ups
    warm_link_s_total: float  # link-seconds those transfers cost
    probes: int
    drains: int
    undrains: int
    heat_max: float
    slo: SLOReport
    events: Tuple[Tuple[float, str, int], ...]
    serving_series: Tuple[int, ...]   # serving-worker count per tick
    deaths: int = 0               # rows declared dead after detect_s
    resurrections: int = 0        # stranded lanes resumed on survivors
    recompute_tokens: int = 0     # redone decode + re-prefill after deaths
    orphaned: int = 0             # stranded rids still awaiting a survivor
    fed_rounds: int = 0           # completed training rounds (fed mirror)
    fed_deliveries: int = 0       # participant legs delivered
    fed_excluded: int = 0         # legs excluded (death / round deadline)
    fed_samples: int = 0          # local steps behind applied updates
    fed_train_s: float = 0.0      # credit seconds spent on training
    fed_wire_bytes: int = 0       # update frame bytes charged on links
    fed_preempt_ticks: int = 0    # participant-ticks preempted by serving

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class SimFleet:
    """Hundreds of simulated serving workers in structure-of-arrays form.

    ``rows`` declares the scalable pool (one :class:`ScaleWorkerSpec` per
    potential worker); the first ``n_start`` rows begin alive, the rest are
    spare capacity only an :class:`AutoscalePolicy` can bring up.  See the
    module docstring for the semantics; all per-row state is public numpy
    arrays (``heat``, ``duty``, ``drained``, ``queue_len``, ...) so tests
    can stage scenarios directly.
    """

    def __init__(self, rows: Sequence[ScaleWorkerSpec], *,
                 n_start: Optional[int] = None,
                 tick_s: float = 0.05,
                 slo: Sequence[SLOClass] = (SLOClass("default"),),
                 admission: bool = True,
                 admission_safety: float = 1.0,
                 autoscaler: Optional[AutoscalePolicy] = None,
                 autoscale_every_s: float = 1.0,
                 elastic: bool = True,
                 fair_duty: float = 0.85,
                 serious_duty: float = 0.6,
                 drain_rank: int = 2,
                 thermal_routing: bool = True,
                 cool_frac: float = 0.5,
                 probe_every_s: float = 0.25,
                 warm_param_bytes: float = 0.0,
                 kill_trace: Optional[KillTrace] = None,
                 detect_s: float = 0.5,
                 ckpt_every_s: float = 0.5,
                 fed: Optional[FedSimConfig] = None,
                 impl: str = "vector"):
        if impl not in ("vector", "loop"):
            raise ValueError(f"impl must be 'vector' or 'loop', got {impl!r}")
        if not rows:
            raise ValueError("need at least one worker row")
        self.impl = impl
        self.n = len(rows)
        self.rows = tuple(rows)
        self.tick_s = float(tick_s)
        self.slo = tuple(slo)
        self.admission = admission
        self.admission_safety = float(admission_safety)
        self.autoscaler = autoscaler
        self.autoscale_every_s = float(autoscale_every_s)
        self.elastic = elastic
        self.fair_duty = float(fair_duty)
        self.serious_duty = float(serious_duty)
        self.drain_rank = int(drain_rank)
        self.thermal_routing = thermal_routing
        self.cool_frac = float(cool_frac)
        self.probe_every_s = float(probe_every_s)
        self.warm_param_bytes = float(warm_param_bytes)
        self.kill_trace = kill_trace
        self.detect_s = float(detect_s)
        self.ckpt_every_s = float(ckpt_every_s)

        n = self.n
        f64 = np.float64
        # immutable per-row ratings
        self.decode_rate_arr = np.array([r.decode_rate() for r in rows], f64)
        self.prefill_rate_arr = np.array([r.prefill_rate() for r in rows], f64)
        self.max_batch_arr = np.array([r.max_batch for r in rows], np.int64)
        self.max_queue_arr = np.array([r.max_queue for r in rows], np.int64)
        self.s_gain = np.array(
            [1.0 / r.profile.thermal_sustained - 1.0 for r in rows], f64)
        # bankable compute credit: two ticks, but never less than one decode
        # step at worst-case slowdown — a row whose step spans multiple
        # ticks must be able to save up for it or it deadlocks at 0 steps
        self._cap_s = np.maximum(2.0 * self.tick_s,
                                 (1.0 + self.s_gain) / self.decode_rate_arr)
        self.t_tau = np.array([r.profile.thermal_tau_s for r in rows], f64)
        self.warm_s_arr = np.array(
            [r.warm_s(self.warm_param_bytes) for r in rows], f64)
        self.link_bw_arr = np.array([r.profile.link_bw for r in rows], f64)
        self.lmax = int(self.max_batch_arr.max())

        # mutable worker state (SoA)
        if n_start is None:
            n_start = n
        if not 1 <= n_start <= n:
            raise ValueError("need 1 <= n_start <= len(rows)")
        self.alive = np.zeros(n, bool)
        self.alive[:n_start] = True
        self.retiring = np.zeros(n, bool)
        self.drained = np.zeros(n, bool)
        # dead rows keep alive=True (a crashed worker is NOT spare capacity
        # for _scale_up) but are masked out of earning/serving/routing
        self.dead = np.zeros(n, bool)
        self.warm_rem = np.zeros(n, f64)   # rows start warm; scale-ups don't
        self.duty = np.ones(n, f64)
        self.heat = np.zeros(n, f64)
        self.slowdown = np.ones(n, f64)
        self.credit = np.zeros(n, f64)
        self.util = np.zeros(n, f64)
        self.queue_len = np.zeros(n, np.int64)
        self.active_lanes = np.zeros(n, np.int64)
        self.pending_prefill = np.zeros(n, np.int64)  # queued prompt tokens
        self.pending_steps = np.zeros(n, np.int64)    # queued+active out tokens
        self.next_probe = np.zeros(n, f64)
        self.probes_arr = np.zeros(n, np.int64)
        self.lane_req = np.full((n, self.lmax), -1, np.int64)
        self.lane_rem = np.zeros((n, self.lmax), np.int64)
        self.queues: List[Deque[int]] = [deque() for _ in range(n)]
        self._earning = self.alive & (self.warm_rem <= 0.0) & ~self.dead
        self._prefill_spent = np.zeros(n, f64)
        self._has_deadlines = False

        # failure plane: checkpointed lane_rem, kill schedule, resume state
        self.lane_ckpt = np.zeros((n, self.lmax), np.int64)
        self._kill_events = list(kill_trace) if kill_trace is not None else []
        self._next_kill = 0
        self._detect_at: Dict[int, float] = {}
        self._return_at: Dict[int, Tuple[float, str]] = {}
        self._resume_rem: Dict[int, int] = {}
        self._strand_retry: Deque[Tuple[int, bool]] = deque()
        self._next_ckpt = self.ckpt_every_s
        self.deaths = 0
        self.resurrections = 0
        self.recompute_tokens = 0

        # training-plane mirror (shared-phase code: loop == vector)
        self.fed = fed
        self._fed_members: List[int] = []
        self._fed_comp: Dict[int, float] = {}   # cold compute s remaining
        self._fed_link: Dict[int, float] = {}   # wire s remaining
        self._fed_done: set = set()
        self._fed_failed: set = set()
        self._fed_deadline = math.inf
        self.fed_rounds = 0
        self.fed_deliveries = 0
        self.fed_excluded = 0
        self.fed_samples = 0
        self.fed_train_s = 0.0
        self.fed_wire_bytes = 0
        self.fed_preempt_ticks = 0

        # per-request records (parallel lists, index = rid)
        self.q_submit: List[float] = []
        self.q_first: List[float] = []
        self.q_done: List[float] = []
        self.q_prompt: List[int] = []
        self.q_max_new: List[int] = []
        self.q_class: List[int] = []
        self.q_deadline: List[Optional[float]] = []
        self.q_status: List[int] = []
        self.q_worker: List[int] = []

        # clocks + counters
        self.sim_t = 0.0
        self.ticks = 0
        self.offered = 0
        self.n_done = 0
        self.completed_tokens = 0
        self.generated_tokens = 0
        self.shed = 0
        self.rejected = 0
        self.expired = 0
        self.steps_run = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.retired = 0
        self.warm_bytes_total = 0.0
        self.warm_link_s_total = 0.0
        self.drains = 0
        self.undrains = 0
        self.peak_serving = int(n_start)
        self.events: List[Tuple[float, str, int]] = []
        self.serving_series: List[int] = []
        self._next_autoscale = 0.0

    # ------------------------------------------------------------------
    # derived views
    # ------------------------------------------------------------------
    @property
    def completed(self) -> List[int]:
        """Rids of completed requests (drive_sim duck-typing)."""
        return [rid for rid, st in enumerate(self.q_status)
                if st == OUTCOME_DONE]

    def idle(self) -> bool:
        # an active training round must resolve (deliver or deadline-fail)
        # before the fleet reads as idle — play() never cuts a round short
        return (int(self.queue_len.sum()) == 0
                and int(self.active_lanes.sum()) == 0
                and not self._strand_retry
                and not self._fed_members)

    def _serving_mask(self) -> np.ndarray:
        return (self.alive & (self.warm_rem <= 0.0) & ~self.retiring
                & ~self.dead)

    def _ranks(self) -> np.ndarray:
        """Thermal rank per row: 0 MINIMAL, 1 FAIR, 2 SERIOUS, 3 CRITICAL
        (same slowdown thresholds as the ThermalMonitor state machine)."""
        return np.searchsorted(_RANK_EDGES, self.slowdown, side="right")

    def _est_wait(self, idx: np.ndarray) -> np.ndarray:
        """Predicted seconds until a new admission would see its first
        token on each row: queued prefill + decode backlog, derated by the
        row's thermal slowdown and duty cycle."""
        sd = self.slowdown[idx]
        duty = np.maximum(self.duty[idx], 1e-3)
        pre = self.pending_prefill[idx] * sd / self.prefill_rate_arr[idx]
        dec = (self.pending_steps[idx] / self.max_batch_arr[idx]
               * sd / self.decode_rate_arr[idx])
        return (pre + dec) / duty

    def load(self) -> FleetLoad:
        """The aggregate reading an :class:`AutoscalePolicy` scales on."""
        serving = self._serving_mask()
        idx = np.flatnonzero(serving)
        wait = self._est_wait(idx) if len(idx) else np.zeros(0)
        ranks = self._ranks()[idx]
        return FleetLoad(
            sim_t=self.sim_t,
            serving=int(serving.sum()),
            warming=int((self.alive & (self.warm_rem > 0.0)
                         & ~self.dead).sum()),
            spare=int((~self.alive & ~self.retiring).sum()),
            queue_depth=int(self.queue_len[idx].sum()) if len(idx) else 0,
            backlog_s=float(wait.mean()) if len(idx) else 0.0,
            backlog_max_s=float(wait.max()) if len(idx) else 0.0,
            hot_frac=float((ranks >= 2).mean()) if len(idx) else 0.0,
            util_mean=float(self.util[idx].mean()) if len(idx) else 0.0)

    # ------------------------------------------------------------------
    # submission: routing, admission control, capacity rejects
    # ------------------------------------------------------------------
    def submit(self, prompt_len: int, max_new: int = 16, *,
               class_id: int = 0, deadline_s: Optional[float] = None
               ) -> Optional[int]:
        """Route one request; returns its rid, or None when shed by
        admission control or rejected for capacity (recorded either way)."""
        if not 0 <= class_id < len(self.slo):
            raise ValueError(f"unknown SLO class {class_id}")
        rid = len(self.q_status)
        self.q_submit.append(self.sim_t)
        self.q_first.append(float("nan"))
        self.q_done.append(float("nan"))
        self.q_prompt.append(int(prompt_len))
        self.q_max_new.append(int(max_new))
        self.q_class.append(int(class_id))
        self.q_deadline.append(deadline_s)
        self.q_worker.append(-1)
        self.offered += 1
        if deadline_s is not None:
            self._has_deadlines = True

        warm = self.alive & (self.warm_rem <= 0.0) & ~self.dead
        room = self.queue_len < self.max_queue_arr
        open_ = warm & ~self.drained & ~self.retiring & room
        if not open_.any():
            # all-drained fallback: queue rather than vanish (matches
            # ServingFleet's routing), but never onto a retiring worker
            open_ = warm & ~self.retiring & room
        if not open_.any():
            self.q_status.append(OUTCOME_REJECTED)
            self.rejected += 1
            return None
        idx = np.flatnonzero(open_)
        pred = (self._est_wait(idx)
                + prompt_len * self.slowdown[idx] / self.prefill_rate_arr[idx])
        if self.admission:
            limit = deadline_s if deadline_s is not None \
                else self.slo[class_id].ttft_s
            if (limit is not None and np.isfinite(limit)
                    and float(pred.min()) > limit * self.admission_safety):
                self.q_status.append(OUTCOME_SHED)
                self.shed += 1
                return None
        rank = (self._ranks()[idx] if self.thermal_routing
                else np.zeros(len(idx), np.int64))
        # routing score, least-loaded-coolest-first; same shape as the real
        # fleet's _route_order: (thermal rank, backlog, tiebreak by index)
        best = int(idx[np.lexsort((idx, self.queue_len[idx], pred, rank))[0]])
        self.q_status.append(_QUEUED)
        self.q_worker[rid] = best
        self.queues[best].append(rid)
        self.queue_len[best] += 1
        self.pending_prefill[best] += int(prompt_len)
        self.pending_steps[best] += int(max_new)
        return rid

    # ------------------------------------------------------------------
    # request terminal transitions
    # ------------------------------------------------------------------
    def _rem_total(self, rid: int) -> int:
        """Output tokens this rid still owes: its checkpointed remainder
        when resuming after a death, its full budget otherwise."""
        return self._resume_rem.get(rid, self.q_max_new[rid])

    def _drop_expired(self, w: int, rid: int) -> None:
        self.q_status[rid] = OUTCOME_EXPIRED
        self.q_done[rid] = self.sim_t
        self.expired += 1
        self.queue_len[w] -= 1
        self.pending_prefill[w] -= self.q_prompt[rid]
        self.pending_steps[w] -= self._rem_total(rid)
        self._resume_rem.pop(rid, None)

    def _complete(self, rid: int) -> None:
        self.q_status[rid] = OUTCOME_DONE
        self.q_done[rid] = self.sim_t
        self.n_done += 1
        self.completed_tokens += self.q_max_new[rid]

    def _finish_lane(self, w: int, lane: int) -> None:
        rid = int(self.lane_req[w, lane])
        self.lane_req[w, lane] = -1
        self.active_lanes[w] -= 1
        self._complete(rid)

    def _expired_now(self, rid: int) -> bool:
        dl = self.q_deadline[rid]
        return dl is not None and self.sim_t - self.q_submit[rid] > dl

    # ------------------------------------------------------------------
    # tick phases.  Admission/expiry, policy and autoscale are shared code;
    # the credit/decode/probe/thermal hot path exists twice — see module
    # docstring for the loop-vs-vector contract (bit-identical results).
    # ------------------------------------------------------------------
    def tick(self) -> None:
        self.sim_t += self.tick_s
        self.ticks += 1
        if self.kill_trace is not None:
            self._process_faults()
        if self.impl == "vector":
            self._phase_rates_vector()
        else:
            self._phase_rates_loop()
        self._admit_and_expire()
        if self.impl == "vector":
            self._phase_decode_vector()
        else:
            self._phase_decode_loop()
        if self.fed is not None:
            self._phase_fed()
        if self.kill_trace is not None:
            self._checkpoint_lanes()
        if self.elastic:
            self._apply_elastic()
        if self.autoscaler is not None and self.sim_t >= self._next_autoscale:
            self._next_autoscale = self.sim_t + self.autoscale_every_s
            for act in self.autoscaler.step(self.load()):
                if act.kind == "scale_up":
                    self._scale_up(int(act.detail["n"]))
                elif act.kind == "scale_down":
                    self._scale_down(int(act.detail["n"]))
        self._retire_done()
        serving_now = int(self._serving_mask().sum())
        self.peak_serving = max(self.peak_serving, serving_now)
        self.serving_series.append(serving_now)

    # --- phase A: slowdown, warm-up spend, credit accrual -------------
    def _phase_rates_vector(self) -> None:
        self.slowdown = 1.0 + self.heat * self.s_gain
        spend = np.where(self.alive,
                         np.minimum(self.warm_rem, self.tick_s), 0.0)
        self.warm_rem = self.warm_rem - spend
        self._earning = self.alive & (self.warm_rem <= 0.0) & ~self.dead
        grown = np.minimum(self.credit + self.tick_s * self.duty, self._cap_s)
        self.credit = np.where(self._earning, grown, self.credit)

    def _phase_rates_loop(self) -> None:
        for w in range(self.n):
            self.slowdown[w] = 1.0 + self.heat[w] * self.s_gain[w]
            spend = min(self.warm_rem[w], self.tick_s) if self.alive[w] else 0.0
            self.warm_rem[w] = self.warm_rem[w] - spend
            earning = (bool(self.alive[w]) and self.warm_rem[w] <= 0.0
                       and not bool(self.dead[w]))
            self._earning[w] = earning
            if earning:
                self.credit[w] = min(
                    self.credit[w] + self.tick_s * self.duty[w],
                    self._cap_s[w])

    # --- shared: head expiry + prefill admission ----------------------
    def _admit_and_expire(self) -> None:
        self._prefill_spent[:] = 0.0
        mask = (self.queue_len > 0) & self._earning
        if not self._has_deadlines:
            # nothing can expire, so rows without a free lane and positive
            # credit have no admission work this tick
            mask &= (self.active_lanes < self.max_batch_arr) \
                & (self.credit > 0.0)
        rows = np.flatnonzero(mask)
        for w in rows.tolist():
            q = self.queues[w]
            # expire rotting heads even when no lane or credit is free
            while q and self._expired_now(q[0]):
                self._drop_expired(w, q.popleft())
            while (q and self.active_lanes[w] < self.max_batch_arr[w]
                    and self.credit[w] > 0.0):
                rid = q.popleft()
                if self._expired_now(rid):
                    self._drop_expired(w, rid)
                    continue
                # prefill is charged whole at admission (may push the row
                # into credit debt — a long prompt spans ticks)
                rem_total = self._rem_total(rid)
                self._resume_rem.pop(rid, None)
                cost = (self.q_prompt[rid] * self.slowdown[w]
                        / self.prefill_rate_arr[w])
                self.credit[w] -= cost
                self._prefill_spent[w] += cost
                self.queue_len[w] -= 1
                self.pending_prefill[w] -= self.q_prompt[rid]
                self.pending_steps[w] -= 1          # first token via prefill
                if math.isnan(self.q_first[rid]):
                    self.q_first[rid] = self.sim_t
                self.generated_tokens += 1
                if rem_total <= 1:
                    self._complete(rid)
                    continue
                lane = int(np.flatnonzero(self.lane_req[w] < 0)[0])
                self.lane_req[w, lane] = rid
                self.lane_rem[w, lane] = rem_total - 1
                self.lane_ckpt[w, lane] = rem_total - 1
                self.active_lanes[w] += 1
                self.q_status[rid] = _ACTIVE

    # --- phase B: decode grants, finishes, probes, util, heat ---------
    def _phase_decode_vector(self) -> None:
        step_cost = self.slowdown / self.decode_rate_arr
        can = self._earning & (self.active_lanes > 0) & (self.credit > 0.0)
        ncap = np.where(can, np.floor(self.credit / step_cost),
                        0.0).astype(np.int64)
        occupied = self.lane_req >= 0
        need = np.max(np.where(occupied, self.lane_rem, 0), axis=1)
        nuse = np.minimum(ncap, need)
        granted = np.where(occupied,
                           np.minimum(self.lane_rem, nuse[:, None]), 0)
        self.lane_rem = self.lane_rem - granted
        row_tokens = granted.sum(axis=1)
        self.credit = self.credit - nuse * step_cost
        self.pending_steps = self.pending_steps - row_tokens
        self.generated_tokens += int(row_tokens.sum())
        self.steps_run += int(nuse.sum())
        done_r, done_l = np.nonzero(occupied & (self.lane_rem == 0))
        for w, lane in zip(done_r.tolist(), done_l.tolist()):
            self._finish_lane(w, lane)
        # probe batching: every truly idle worker pays one step_cost per
        # probe window (the real fleet's keep-alive capability probe)
        ran = (nuse > 0) | (self._prefill_spent > 0.0)
        idle = (self._earning & ~ran & (self.active_lanes == 0)
                & (self.queue_len == 0))
        due = idle & (self.sim_t >= self.next_probe)
        self.credit = np.where(due, self.credit - step_cost, self.credit)
        self.probes_arr = self.probes_arr + due
        reset = due | (ran & self._earning)
        self.next_probe = np.where(reset, self.sim_t + self.probe_every_s,
                                   self.next_probe)
        busy = (self._prefill_spent + nuse * step_cost
                + np.where(due, step_cost, 0.0))
        self.util = np.where(self._earning,
                             np.minimum(busy / self.tick_s, 1.0), 0.0)
        dh = self.tick_s * (
            self.util / self.t_tau
            - (1.0 - self.util) * self.heat / (self.t_tau * self.cool_frac))
        heatable = self._earning & np.isfinite(self.t_tau)
        self.heat = np.where(heatable,
                             np.clip(self.heat + dh, 0.0, 1.0), self.heat)

    def _phase_decode_loop(self) -> None:
        for w in range(self.n):
            earning = bool(self._earning[w])
            step_cost = self.slowdown[w] / self.decode_rate_arr[w]
            steps = 0
            tokens = 0
            if earning and self.active_lanes[w] > 0 and self.credit[w] > 0.0:
                ncap = int(np.floor(self.credit[w] / step_cost))
                # the pre-vectorization hot path: one token per lane per
                # step, one step at a time
                while steps < ncap:
                    advanced = 0
                    for lane in range(self.lmax):
                        if (self.lane_req[w, lane] >= 0
                                and self.lane_rem[w, lane] > 0):
                            self.lane_rem[w, lane] -= 1
                            advanced += 1
                    if advanced == 0:
                        break
                    steps += 1
                    tokens += advanced
            self.credit[w] = self.credit[w] - steps * step_cost
            self.pending_steps[w] = self.pending_steps[w] - tokens
            self.generated_tokens += tokens
            self.steps_run += steps
            for lane in range(self.lmax):
                if (self.lane_req[w, lane] >= 0
                        and self.lane_rem[w, lane] == 0):
                    self._finish_lane(w, lane)
            ran = steps > 0 or self._prefill_spent[w] > 0.0
            idle = (earning and not ran and self.active_lanes[w] == 0
                    and self.queue_len[w] == 0)
            due = idle and self.sim_t >= self.next_probe[w]
            if due:
                self.credit[w] = self.credit[w] - step_cost
                self.probes_arr[w] += 1
            if due or (ran and earning):
                self.next_probe[w] = self.sim_t + self.probe_every_s
            busy = (self._prefill_spent[w] + steps * step_cost
                    + (step_cost if due else 0.0))
            self.util[w] = (min(busy / self.tick_s, 1.0) if earning else 0.0)
            if earning and np.isfinite(self.t_tau[w]):
                dh = self.tick_s * (
                    self.util[w] / self.t_tau[w]
                    - (1.0 - self.util[w]) * self.heat[w]
                    / (self.t_tau[w] * self.cool_frac))
                self.heat[w] = min(max(self.heat[w] + dh, 0.0), 1.0)

    # --- shared: duty/drain policy + autoscale execution --------------
    def _apply_elastic(self) -> None:
        ranks = self._ranks()
        duty = np.where(ranks >= 2, self.serious_duty,
                        np.where(ranks >= 1, self.fair_duty, 1.0))
        self.duty = np.where(self.alive, duty, 1.0)
        want = self.alive & (ranks >= self.drain_rank)
        self.drains += int((want & ~self.drained).sum())
        self.drained = self.drained | want
        # hysteresis: undrain only on full recovery to MINIMAL
        recovered = self.drained & (ranks == 0)
        self.undrains += int(recovered.sum())
        self.drained = self.drained & ~recovered

    def _scale_up(self, n: int) -> None:
        spare = np.flatnonzero(~self.alive & ~self.retiring)[:n]
        if len(spare) == 0:
            return
        self.alive[spare] = True
        self.warm_rem[spare] = self.warm_s_arr[spare]
        self.heat[spare] = 0.0
        self.slowdown[spare] = 1.0
        self.credit[spare] = 0.0
        self.duty[spare] = 1.0
        self.drained[spare] = False
        self.next_probe[spare] = self.sim_t + self.probe_every_s
        self.scale_ups += 1
        self.warm_bytes_total += self.warm_param_bytes * len(spare)
        self.warm_link_s_total += float(self.warm_s_arr[spare].sum())
        self.events.append((self.sim_t, "scale_up", int(len(spare))))

    def _scale_down(self, n: int) -> None:
        cand = np.flatnonzero(self._serving_mask())
        if len(cand) <= 1:
            return
        n = min(n, len(cand) - 1)   # never retire the whole fleet
        if n <= 0:
            return
        # retire the emptiest rows first: they drain fastest
        backlog = (self.active_lanes[cand] + self.queue_len[cand])
        order = np.lexsort((cand, self.util[cand], backlog))
        pick = cand[order[:n]]
        self.retiring[pick] = True
        self.scale_downs += 1
        self.events.append((self.sim_t, "scale_down", int(n)))

    def _retire_done(self) -> None:
        # a dead retiring row must not "finish draining" into the spare
        # pool just because its lanes were stranded elsewhere
        done = (self.retiring & ~self.dead & (self.active_lanes == 0)
                & (self.queue_len == 0))
        k = int(done.sum())
        if k:
            self.alive[done] = False
            self.retiring[done] = False
            self.heat[done] = 0.0
            self.credit[done] = 0.0
            self.retired += k

    # --- shared: failure plane (kills, detection, lane resurrection) --
    def _process_faults(self) -> None:
        # returns first, so a partition that heals before its detection
        # deadline cancels the strand — a transparent blip
        for w in [w for w, (t, _) in self._return_at.items()
                  if self.sim_t >= t]:
            _, kind = self._return_at.pop(w)
            self.dead[w] = False
            self._detect_at.pop(w, None)
            if kind == "zombie":
                # cold restart: model state gone, params re-stream
                self.warm_rem[w] = self.warm_s_arr[w]
                self.heat[w] = 0.0
                self.slowdown[w] = 1.0
            self.credit[w] = 0.0
            self.next_probe[w] = self.sim_t + self.probe_every_s
            self.events.append((self.sim_t, "return", int(w)))
        while (self._next_kill < len(self._kill_events)
               and self._kill_events[self._next_kill].t_s <= self.sim_t):
            ev = self._kill_events[self._next_kill]
            self._next_kill += 1
            try:
                w = int(ev.worker)
            except (TypeError, ValueError):
                continue
            if not (0 <= w < self.n) or self.dead[w] or not self.alive[w]:
                continue
            self.dead[w] = True
            self._detect_at[w] = self.sim_t + self.detect_s
            if ev.returns:
                self._return_at[w] = (self.sim_t + ev.down_s, ev.kind)
            self.events.append((self.sim_t, "kill", int(w)))
        for w in [w for w, t in self._detect_at.items() if self.sim_t >= t]:
            self._detect_at.pop(w)
            if self.dead[w]:
                self._strand_row(w)
        # orphans parked when no survivor could take them: retry each tick
        for _ in range(len(self._strand_retry)):
            rid, resurrect = self._strand_retry.popleft()
            if self._expired_now(rid):
                self.q_status[rid] = OUTCOME_EXPIRED
                self.q_done[rid] = self.sim_t
                self.expired += 1
                self._resume_rem.pop(rid, None)
                continue
            self._fo_route(rid, resurrect=resurrect)

    def _strand_row(self, w: int) -> None:
        """Declare row ``w`` dead: roll its active lanes back to their
        checkpoints and re-route them (plus its queue) onto survivors."""
        self.deaths += 1
        self.events.append((self.sim_t, "death", int(w)))
        # a detected-dead participant is excluded from its training round
        # (mirrors the coordinator keying exclusion on fleet._dead)
        if w in self._fed_members and not self._fed_resolved(w):
            self._fed_failed.add(w)
        for lane in range(self.lmax):
            rid = int(self.lane_req[w, lane])
            if rid < 0:
                continue
            ck = int(self.lane_ckpt[w, lane])
            rem = int(self.lane_rem[w, lane])
            # tokens decoded since the checkpoint are redone on the
            # destination, plus a re-prefill of the prompt
            self.recompute_tokens += (ck - rem) + self.q_prompt[rid]
            self.lane_req[w, lane] = -1
            self.lane_rem[w, lane] = 0
            self.active_lanes[w] -= 1
            self.pending_steps[w] -= rem
            # the checkpoint holds state after (q_max_new - ck) tokens, so
            # ck remain; re-admission's prefill token is the first of them
            self._resume_rem[rid] = ck
            self.q_status[rid] = _QUEUED
            self._fo_route(rid, resurrect=True)
        q = self.queues[w]
        while q:
            rid = q.popleft()
            self.queue_len[w] -= 1
            self.pending_prefill[w] -= self.q_prompt[rid]
            self.pending_steps[w] -= self._rem_total(rid)
            self._fo_route(rid, resurrect=False)

    def _fo_route(self, rid: int, *, resurrect: bool) -> None:
        """Failover routing: same score shape as submit(), but never shed
        by admission control — the request was already accepted once."""
        warm = self.alive & (self.warm_rem <= 0.0) & ~self.dead
        room = self.queue_len < self.max_queue_arr
        open_ = warm & ~self.drained & ~self.retiring & room
        if not open_.any():
            open_ = warm & ~self.retiring & room
        if not open_.any():
            self._strand_retry.append((rid, resurrect))
            return
        idx = np.flatnonzero(open_)
        pred = (self._est_wait(idx) + self.q_prompt[rid]
                * self.slowdown[idx] / self.prefill_rate_arr[idx])
        rank = (self._ranks()[idx] if self.thermal_routing
                else np.zeros(len(idx), np.int64))
        best = int(idx[np.lexsort((idx, self.queue_len[idx], pred, rank))[0]])
        self.q_worker[rid] = best
        self.queues[best].append(rid)
        self.queue_len[best] += 1
        self.pending_prefill[best] += self.q_prompt[rid]
        self.pending_steps[best] += self._rem_total(rid)
        if resurrect:
            self.resurrections += 1
            self.events.append((self.sim_t, "resurrect", int(rid)))

    # --- shared: training-plane mirror (serve-while-train charging) ---
    def _fed_resolved(self, w: int) -> bool:
        return w in self._fed_done or w in self._fed_failed

    def _phase_fed(self) -> None:
        """Mirror of :class:`~repro.serving.train_plane.FedRoundCoordinator`
        at capacity level: one active round at a time, each participant
        paying cold training seconds out of the row's leftover per-tick
        credit (after decode), then one update frame over its link.  Runs
        as SHARED code after both decode phases, so loop and vector stay
        bit-identical with the training plane on."""
        fed = self.fed
        if not self._fed_members and self.fed_rounds < fed.rounds:
            ranks = self._ranks()
            elig = (self._serving_mask() & self._earning
                    & (ranks <= fed.max_rank) & (self.queue_len == 0)
                    & (self.active_lanes == 0))
            idx = np.flatnonzero(elig)
            if len(idx):
                # coolest-emptiest-fastest-first, same score shape as the
                # real coordinator's participant selection
                backlog = self.active_lanes[idx] + self.queue_len[idx]
                order = np.lexsort((idx, -self.prefill_rate_arr[idx],
                                    backlog, ranks[idx]))
                picked = idx[order[:fed.participants]]
                self._fed_members = sorted(int(w) for w in picked)
                cold = (fed.local_steps * fed.flops_mult * fed.step_tokens)
                for w in self._fed_members:
                    self._fed_comp[w] = cold / self.prefill_rate_arr[w]
                    self._fed_link[w] = 0.0
                self._fed_done = set()
                self._fed_failed = set()
                self._fed_deadline = self.sim_t + fed.round_timeout_s
        if not self._fed_members:
            return
        for w in self._fed_members:
            if self._fed_resolved(w):
                continue
            # a down row makes no progress; detection (_strand_row) fails
            # it, a blip that heals before detection resumes transparently
            if self.dead[w] or not self._earning[w]:
                continue
            if (self.queue_len[w] > 0 or self.active_lanes[w] > 0
                    or self._ranks()[w] > fed.max_rank):
                self.fed_preempt_ticks += 1
                continue
            if self._fed_comp[w] > 0.0:
                cost_now = self._fed_comp[w] * self.slowdown[w]
                pay = min(cost_now, max(float(self.credit[w]), 0.0))
                if pay > 0.0:
                    self.credit[w] -= pay
                    self._fed_comp[w] -= pay / self.slowdown[w]
                    self.fed_train_s += pay
                    du = pay / self.tick_s
                    self.util[w] = min(self.util[w] + du, 1.0)
                    if self._earning[w] and np.isfinite(self.t_tau[w]):
                        # first-order heat delta of the reservoir update
                        # for the extra util the training spend added
                        dh = self.tick_s * du * (
                            1.0 / self.t_tau[w]
                            + self.heat[w] / (self.t_tau[w] * self.cool_frac))
                        self.heat[w] = min(max(self.heat[w] + dh, 0.0), 1.0)
                if self._fed_comp[w] <= 1e-12:
                    self._fed_comp[w] = 0.0
                    self.fed_wire_bytes += fed.frame_bytes
                    self._fed_link[w] = fed.frame_bytes / self.link_bw_arr[w]
            if self._fed_comp[w] == 0.0 and w not in self._fed_done:
                self._fed_link[w] -= min(self._fed_link[w], self.tick_s)
                if self._fed_link[w] <= 1e-12:
                    self._fed_done.add(w)
        if self.sim_t >= self._fed_deadline:
            for w in self._fed_members:
                if not self._fed_resolved(w):
                    self._fed_failed.add(w)
        if all(self._fed_resolved(w) for w in self._fed_members):
            self.fed_rounds += 1
            self.fed_deliveries += len(self._fed_done)
            self.fed_excluded += len(self._fed_failed)
            self.fed_samples += (len(self._fed_done)
                                 * fed.local_steps * fed.step_tokens)
            self._fed_members = []
            self._fed_comp.clear()
            self._fed_link.clear()
            self._fed_done = set()
            self._fed_failed = set()
            self._fed_deadline = math.inf

    def _checkpoint_lanes(self) -> None:
        """Refresh per-lane checkpoints on live rows (a dead row's state
        is unreachable — its last pre-kill checkpoint stands)."""
        if self.sim_t < self._next_ckpt:
            return
        self._next_ckpt = self.sim_t + self.ckpt_every_s
        live = ~self.dead
        self.lane_ckpt[live] = self.lane_rem[live]

    # ------------------------------------------------------------------
    def snapshot(self) -> ScaleSnapshot:
        n = len(self.q_status)
        terminal = [rid for rid in range(n) if self.q_status[rid] >= 0]
        ttft = [self.q_first[rid] - self.q_submit[rid] for rid in terminal]
        tpot = []
        tokens = []
        for rid in terminal:
            m = self.q_max_new[rid]
            if (self.q_status[rid] == OUTCOME_DONE and m > 1):
                tpot.append((self.q_done[rid] - self.q_first[rid]) / (m - 1))
            else:
                tpot.append(float("nan"))
            tokens.append(m if self.q_status[rid] == OUTCOME_DONE else 0)
        report = slo_report(
            self.slo, [self.q_class[rid] for rid in terminal], ttft, tpot,
            tokens, [self.q_status[rid] for rid in terminal],
            span_s=self.sim_t)
        return ScaleSnapshot(
            sim_t=self.sim_t, ticks=self.ticks, offered=self.offered,
            completed=self.n_done, completed_tokens=self.completed_tokens,
            goodput_tokens_per_s=(self.completed_tokens / self.sim_t
                                  if self.sim_t > 0 else 0.0),
            shed=self.shed, rejected=self.rejected, expired=self.expired,
            queued_now=int(self.queue_len.sum()),
            active_now=int(self.active_lanes.sum()),
            serving_now=int(self._serving_mask().sum()),
            peak_serving=self.peak_serving,
            scale_ups=self.scale_ups, scale_downs=self.scale_downs,
            retired=self.retired,
            warm_bytes_total=self.warm_bytes_total,
            warm_link_s_total=self.warm_link_s_total,
            probes=int(self.probes_arr.sum()),
            drains=self.drains, undrains=self.undrains,
            heat_max=float(self.heat.max()),
            slo=report,
            events=tuple(self.events),
            serving_series=tuple(self.serving_series),
            deaths=self.deaths, resurrections=self.resurrections,
            recompute_tokens=self.recompute_tokens,
            orphaned=len(self._strand_retry),
            fed_rounds=self.fed_rounds,
            fed_deliveries=self.fed_deliveries,
            fed_excluded=self.fed_excluded,
            fed_samples=self.fed_samples,
            fed_train_s=round(self.fed_train_s, 9),
            fed_wire_bytes=self.fed_wire_bytes,
            fed_preempt_ticks=self.fed_preempt_ticks)


def play(fleet: SimFleet, trace, *, max_ticks: int = 10_000_000) -> float:
    """Drive a :class:`~repro.serving.traffic.TrafficTrace` through a
    SimFleet open-loop in simulated time (the jax-free analogue of
    :func:`repro.serving.fleet.drive_sim`): submit each arrival when its
    sim time comes due, tick until drained, return simulated seconds."""
    t0 = fleet.sim_t
    arrivals = trace.arrivals
    n, i = len(trace), 0
    for _ in range(max_ticks):
        while i < n and arrivals[i] <= fleet.sim_t - t0:
            fleet.submit(int(trace.prompt_lens[i]),
                         int(trace.max_news[i]),
                         class_id=int(trace.classes[i]))
            i += 1
        if i >= n and fleet.idle():
            break
        fleet.tick()
    else:
        warnings.warn(
            f"play exhausted max_ticks={max_ticks} with work outstanding "
            f"({fleet.n_done} finished)", RuntimeWarning, stacklevel=2)
    return fleet.sim_t - t0
