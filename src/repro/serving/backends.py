"""Cache backends: every decode-state layout behind ONE protocol.

The serving engine holds exactly one :class:`CacheBackend` and speaks only
its verbs — it has no idea whether lanes are dense ``max_len`` strips, a
shared block pool, or pooled recurrent state.  Model-side capabilities
come from ``model.decode_state`` (:class:`repro.models.api.DecodeState`);
eligibility is decided there once and realised here once, so adding a new
layout (quantized KV, host offload, sharded multi-device cache) means one
new subclass, not another optional hook + engine branch.

Protocol (one backend instance per engine; ``slot`` is a lane index):

* ``token_footprint(n_ctx, max_new, tokens)`` — admission charge, in the
  backend's capacity units (cache positions for attention layouts, state
  units for recurrent ones).  Prefix-cache aware for the paged layout.
* ``alloc(n_ctx, final_len, tokens)`` — reserve capacity for one request:
  a :class:`Reservation` on success, ``None`` when it cannot fit *now*
  (spill back to the queue), or :data:`INFEASIBLE` when it can never fit
  (reject up front instead of livelocking).
* ``prefill_paste(slot, group_cache, src_lane, n_ctx, width, res)`` —
  scatter one lane of a (possibly right-padded, batched) prefill cache
  into the backend's storage for ``slot``.
* ``activate(slot, res)`` — install a FULL-HIT reservation without any
  prefill: every needed K/V position is already cached, so the lane
  starts directly in decode (TTFT skips the prefill entirely).
* ``prepare_lane(slot)`` — make the lane's next write position safe
  before a decode step: grow into a fresh block, COW-split a shared one,
  or uncache a sole-holder cached one.  ``False`` = out of memory, the
  engine must preempt a victim and retry.
* ``step(params, tokens, active)`` — advance every lane one token.
* ``append_tokens(slot, toks)`` / ``verify_step(params, tokens, active)``
  / ``rollback(slot, n)`` — the speculative-decoding verify plumbing:
  reserve write capacity for ``len(toks)`` consecutive positions (paged
  grows / COW-splits per position; ``False`` = pool exhausted), advance
  every lane W tokens in one scanned dispatch returning per-position
  logits (B, W, Vp), then truncate the last ``n`` of a lane's writes
  after partial acceptance (dense/paged retreat the position; recurrent
  state is not position-addressed, so the backend replays the kept
  prefix of the verify window from a host-side stash).
* ``reset_lane(slot)`` — return a lane to the empty-stream state (the
  draft side of a speculative pair admits 1-token prompts with nothing
  to prefill).
* ``snapshot(slot)`` / ``restore(slot, snap)`` — preemption support:
  backends with cheap constant-size state return it host-side so a
  preempted request resumes WITHOUT recompute; ``None`` means the
  recompute (re-prefill) policy applies.
* ``release(slot, tokens)`` — free the lane; paged registers the token
  content actually written so future prompts can prefix-match it.

Implementations:

* :class:`DenseBackend` — one ``max_len``-wide lane per slot (the
  original layout; admission is bound by lane count).
* :class:`PagedBackend` — block-pooled KV with refcounted
  copy-on-write prefix caching over :class:`BlockManager`.
* :class:`RecurrentBackend` — ssm / rwkv / hybrid: a pool of
  constant-footprint state lanes (admission charged in state units, not
  fictitious ``max_len`` tokens) with snapshot/restore preemption.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.block_manager import BlockManager

# alloc() verdict: the request can NEVER fit (final footprint exceeds the
# pool or the lane span) — reject up front, don't requeue forever.
INFEASIBLE = object()


@dataclasses.dataclass
class Reservation:
    """Capacity reserved by ``alloc`` for one admission.

    ``blocks`` / ``n_cached`` are paged-layout details (empty elsewhere);
    ``full_hit`` marks a reservation whose every context position short of
    the last is already cached — the engine skips prefill and calls
    ``activate``.  ``n_lookup`` is the token count of the prefix-cache
    query (0 = no lookup happened) for hit-rate accounting.
    """

    blocks: List[int] = dataclasses.field(default_factory=list)
    n_cached: int = 0
    n_lookup: int = 0
    full_hit: bool = False


def _lane_axes(model: Model, n_lanes: int, max_len: int):
    """Locate each cache leaf's lane axis ONCE by diffing the shapes of two
    abstract caches that differ only in batch (-1 = no lane axis)."""
    s_a = jax.eval_shape(lambda: model.init_cache(n_lanes, max_len))
    s_b = jax.eval_shape(lambda: model.init_cache(n_lanes + 1, max_len))

    def lane_axis(a, b):
        for ax, (da, db) in enumerate(zip(a.shape, b.shape)):
            if da != db:
                return ax
        return -1

    return jax.tree.map(lane_axis, s_a, s_b), s_a


# jax.jit caches are PER WRAPPER OBJECT, and a fleet builds one engine per
# worker from the same model — per-instance wrappers would re-trace and
# re-compile identical programs once per worker (and once per reference
# engine in benches).  These caches share one wrapper per key; Model /
# DecodeState are frozen and hashable, and hold no params, so keeping them
# alive in the cache is cheap.
@functools.lru_cache(maxsize=64)
def _lane_tools(model: Model, n_lanes: int, max_len: int):
    """Lane-axis map, abstract cache shapes, and the jitted lane
    paste / extract shared by dense-layout backends of one
    (model, n_lanes, max_len)."""
    lane_ax, shapes = _lane_axes(model, n_lanes, max_len)

    def paste(cache, src_cache, src_lane, dst_slot):
        """Copy lane ``src_lane`` of a prefill cache into decode lane
        ``dst_slot``.  Lane indices are traced, so every admission
        reuses one compile per source-batch shape."""
        def fix(ax, dst, src):
            if ax < 0:
                return dst
            piece = jax.lax.dynamic_index_in_dim(src, src_lane, axis=ax,
                                                 keepdims=True)
            idx = tuple(dst_slot if i == ax else 0
                        for i in range(dst.ndim))
            return jax.lax.dynamic_update_slice(
                dst, piece.astype(dst.dtype), idx)
        return jax.tree.map(fix, lane_ax, cache, src_cache)

    def extract(cache, slot):
        def fix(ax, leaf):
            if ax < 0:
                return leaf
            return jax.lax.dynamic_index_in_dim(leaf, slot, axis=ax,
                                                keepdims=True)
        return jax.tree.map(fix, lane_ax, cache)

    return (lane_ax, shapes, jax.jit(paste, donate_argnums=0),
            jax.jit(extract))


@functools.lru_cache(maxsize=64)
def _decode_jit(model: Model):
    return jax.jit(model.decode_step, donate_argnums=1)


@functools.lru_cache(maxsize=64)
def _pool_step_jit(decode_state):
    return jax.jit(decode_state.pool_step, donate_argnums=1)


@functools.lru_cache(maxsize=64)
def _window_jit(model: Model, donate: bool):
    """Jitted W-token verify window.  ``donate=False`` for the recurrent
    backend, whose rollback replays from a stashed pre-window cache that
    donation would invalidate."""
    ws = model.decode_state.window_step
    if ws is None:
        raise ValueError(
            f"model family {model.cfg.family!r} wires no window_step; "
            f"speculative verify is unavailable on it")
    return jax.jit(ws, donate_argnums=1) if donate else jax.jit(ws)


@functools.lru_cache(maxsize=64)
def _pool_window_jit(decode_state):
    return jax.jit(decode_state.pool_window_step, donate_argnums=1)


def _dense_add_pos(cache, slot, delta):
    return {**cache, "pos": cache["pos"].at[slot].add(delta)}


def _dense_set_pos(cache, slot, val):
    return {**cache, "pos": cache["pos"].at[slot].set(val)}


_DENSE_ADD_POS = jax.jit(_dense_add_pos, donate_argnums=0)
_DENSE_SET_POS = jax.jit(_dense_set_pos, donate_argnums=0)


def _pool_paste(cache, src_layers, src_lane, flat_idx, dst_slot, length):
    """Scatter lane ``src_lane`` of a prefill cache into a lane's
    allocated pool blocks.  ``flat_idx`` (width,) maps prefill positions
    to flattened pool slots; positions past the real context — and
    positions already covered by SHARED cache blocks, which must never be
    rewritten — point at the sink."""
    def fix(pool, src):
        nl = pool.shape[0]
        flat = pool.reshape((nl, -1) + pool.shape[3:])
        piece = jax.lax.dynamic_index_in_dim(
            src, src_lane, axis=1, keepdims=False)
        piece = jax.lax.slice_in_dim(
            piece, 0, flat_idx.shape[0], axis=1)
        flat = flat.at[:, flat_idx].set(piece.astype(flat.dtype))
        return flat.reshape(pool.shape)
    layers = {"k": fix(cache["layers"]["k"], src_layers["k"]),
              "v": fix(cache["layers"]["v"], src_layers["v"])}
    pos = cache["pos"].at[dst_slot].set(length)
    return {"layers": layers, "pos": pos}


def _pool_set_pos(cache, slot, val):
    return {"layers": cache["layers"],
            "pos": cache["pos"].at[slot].set(val)}


def _pool_cow_copy(cache, src, dst):
    """Duplicate one pool block (all layers, K and V) dst <- src."""
    def fix(pool):
        return pool.at[:, dst].set(pool[:, src])
    return {"layers": {"k": fix(cache["layers"]["k"]),
                       "v": fix(cache["layers"]["v"])},
            "pos": cache["pos"]}


# pool-layout helpers are model-independent pure functions: one wrapper
# per process (recompiles per pool shape happen inside jit as usual)
_POOL_PASTE = jax.jit(_pool_paste, donate_argnums=0)
_POOL_SET_POS = jax.jit(_pool_set_pos, donate_argnums=0)
_POOL_COW_COPY = jax.jit(_pool_cow_copy, donate_argnums=0)


class CacheBackend:
    """Base class: the dense-lane defaults every layout can fall back on."""

    #: attributes fleet/engine code duck-types against on ANY backend; a
    #: subclass may shadow them but must never delete them (repro-lint
    #: R005 checks this statically for every ``*Backend`` class).
    REQUIRED_ATTRS = ("name", "n_blocks", "state_version", "snapshot_free")

    name = "dense"

    def __init__(self, model: Model, n_lanes: int, max_len: int):
        self.model = model
        self.n_lanes = n_lanes
        self.max_len = max_len

    # -- gauges (zeros unless the layout tracks them) -------------------
    n_blocks = 0
    blocks_in_use = 0
    peak_blocks = 0
    shared_blocks_peak = 0
    cow_splits = 0
    cache_evictions = 0
    # bumped whenever capacity/match state changes; footprints computed at
    # one version stay valid while it holds (engine memoizes against it)
    state_version = 0
    # True where snapshot()/restore() resume WITHOUT recompute (recurrent
    # state) — cost-aware migration prefers such lanes as victims
    snapshot_free = False

    def fits(self, n_ctx: int, final_len: int) -> bool:
        """Could a request with this FINAL footprint ever be admitted
        here?  The side-effect-free face of ``alloc``'s INFEASIBLE
        verdict — fleet migration consults it before picking a
        destination, so a mid-flight request is never moved onto a
        worker that must reject it."""
        return True

    def cached_prefix_tokens(self, tokens) -> int:
        """Context positions a re-prefill of ``tokens`` would find already
        cached HERE — the failover plane's recompute estimate when
        resurrecting a dead worker's lane on this backend.  Zero unless
        the layout runs a content-addressed prefix cache."""
        return 0

    def forget_cache(self) -> int:
        """Drop reusable cached content (a zombie worker rejoins COLD
        after a reboot: stale registrations must not be served as hits).
        Returns entries dropped; zero where nothing is cached."""
        return 0

    # capacity the admission scheduler may pack against; None = the lane
    # count is the only bound (footprints are not budget-constrained)
    @property
    def budget_tokens(self) -> Optional[int]:
        return None

    @property
    def capacity_tokens(self) -> Optional[int]:
        return None

    def reset_counters(self) -> None:
        pass


class DenseBackend(CacheBackend):
    """One ``max_len``-wide cache lane per slot (the original layout)."""

    name = "dense"

    def __init__(self, model: Model, n_lanes: int, max_len: int):
        super().__init__(model, n_lanes, max_len)
        from repro.models.attention import cache_span

        self._span = cache_span(model.cfg, max_len) \
            if model.decode_state.kind != "encdec" else max_len
        self.cache = model.init_cache(n_lanes, max_len)
        self._lane_ax, _, self._paste, self._extract = _lane_tools(
            model, n_lanes, max_len)
        self._decode = _decode_jit(model)
        self._window = None          # built on first verify_step

    # ------------------------------------------------------------------
    def token_footprint(self, n_ctx: int, max_new: int,
                        tokens: Optional[Sequence[int]] = None) -> int:
        # a lane is max_len wide no matter how short the request is —
        # that fiction is exactly what the paged layout removes
        return self._span

    def alloc(self, n_ctx: int, final_len: int,
              tokens: Optional[Sequence[int]] = None):
        # dense lanes admit anything (writes past max_len clamp, as the
        # pre-paged engine always did); capacity is the lane count, which
        # the engine bounds before calling alloc
        return Reservation()

    def prefill_paste(self, slot: int, group_cache, src_lane: int,
                      n_ctx: int, width: int, res: Reservation) -> None:
        self.cache = self._paste(self.cache, group_cache,
                                 jnp.int32(src_lane), jnp.int32(slot))

    def activate(self, slot: int, res: Reservation, n_ctx: int) -> None:
        raise NotImplementedError("dense lanes never produce full hits")

    def prepare_lane(self, slot: int) -> bool:
        return True

    def step(self, params, tokens: np.ndarray, active: np.ndarray):
        logits, self.cache = self._decode(params, self.cache,
                                          jnp.asarray(tokens))
        return logits

    # -- speculative verify plumbing -----------------------------------
    def append_tokens(self, slot: int,
                      toks: Sequence[int]) -> bool:
        return True          # lane strips are pre-sized max_len wide

    def verify_step(self, params, tokens: np.ndarray, active: np.ndarray):
        """W sequential decode steps in one dispatch.  tokens (B, W);
        returns per-position logits (B, W, Vp).  Every lane's pos
        advances by W — idle-lane garbage, reset at the next paste, the
        same contract as ``step``."""
        if self._window is None:
            self._window = _window_jit(self.model, True)
        logits, self.cache = self._window(params, self.cache,
                                          jnp.asarray(tokens))
        return logits

    def rollback(self, slot: int, n: int) -> None:
        """Un-write the lane's last ``n`` positions.  Attention K/V is
        position-addressed: retreating pos is enough, the stale entries
        are masked out of every read and overwritten by the next write."""
        if n <= 0:
            return
        if self.model.decode_state.kind == "recurrent":
            raise RuntimeError(
                "dense lanes cannot roll back recurrent state; use "
                "backend='recurrent'")
        self.cache = _DENSE_ADD_POS(self.cache, jnp.int32(slot),
                                    jnp.int32(-n))

    def reset_lane(self, slot: int) -> None:
        self.cache = _DENSE_SET_POS(self.cache, jnp.int32(slot),
                                    jnp.int32(0))

    def snapshot(self, slot: int) -> Optional[Any]:
        return None          # recompute policy: resume re-prefills

    def restore(self, slot: int, snap: Any) -> bool:
        return False

    def release(self, slot: int,
                tokens: Optional[Sequence[int]] = None) -> None:
        pass                 # lane garbage is overwritten by the next paste


class RecurrentBackend(DenseBackend):
    """Pooled constant-footprint lanes for recurrent-state families.

    ssm / rwkv / hybrid decode state does not grow with context length —
    per lane it is a fixed bundle (conv tail + ssm state / rwkv matrix
    state / hybrid shared-attention span).  These families were previously
    exiled to dense lanes with a fictitious ``max_len``-token admission
    charge; ``token_footprint`` now reports the true per-lane state size.
    Every lane costs the same, so admission stays exactly lane-bound (the
    scheduler's budget packing only engages for backends with a finite
    ``budget_tokens``, i.e. paged) — the constant unit is there for
    observability and for future layouts that spill state.  The real win
    is preemption: ``snapshot`` copies the (small, fixed) state host-side
    and a preempted request resumes with ZERO recompute.
    """

    name = "recurrent"
    snapshot_free = True

    def __init__(self, model: Model, n_lanes: int, max_len: int):
        super().__init__(model, n_lanes, max_len)
        # true per-lane state size (elements across all cache leaves);
        # _extract comes shared from _lane_tools via DenseBackend
        _, shapes, _, _ = _lane_tools(model, n_lanes, max_len)
        sizes = jax.tree.leaves(jax.tree.map(
            lambda ax, s: int(np.prod(s.shape)) // (s.shape[ax] if ax >= 0 else 1)
            if ax >= 0 else 0, self._lane_ax, shapes))
        self.state_units = int(sum(sizes))
        # speculative-rollback stash: host copy of the pre-window cache +
        # the window tokens + params, and replayed prefixes memoized per
        # kept length (several lanes rolling back the same amount after
        # one verify round share one replay dispatch)
        self._stash = None
        self._stash_tokens: Optional[np.ndarray] = None
        self._stash_params = None
        self._replay_memo: dict = {}
        self._zero_lane = None

    def token_footprint(self, n_ctx: int, max_new: int,
                        tokens: Optional[Sequence[int]] = None) -> int:
        return self.state_units     # independent of prompt/generation length

    def step(self, params, tokens: np.ndarray, active: np.ndarray):
        # extend the rollback record: single steps taken AFTER a verify
        # window (the draft side of a speculative pair drafts this way)
        # are part of the replayable history.  Memoized prefixes stay
        # valid — appending columns never changes tokens[:, :keep].
        if self._stash_tokens is not None:
            self._stash_tokens = np.concatenate(
                # repro-lint: allow[R004] tokens is the host-side input batch; extends the host rollback record, no device transfer
                [self._stash_tokens, np.asarray(tokens)], axis=1)
        return super().step(params, tokens, active)

    # -- speculative verify plumbing -----------------------------------
    def verify_step(self, params, tokens: np.ndarray, active: np.ndarray):
        """Like the dense window, but rollback must be able to rebuild the
        state as of any window prefix — recurrent state is not
        position-addressed, so nothing can be 'un-written'.  Stash a HOST
        copy of the pre-window cache (the window jit must therefore not
        donate its cache argument) and replay from it on rollback."""
        self._stash = jax.tree.map(np.asarray, self.cache)
        # repro-lint: allow[R004] tokens is the host-side window batch; the stash above is the one deliberate sync per verify window
        self._stash_tokens = np.asarray(tokens)
        self._stash_params = params
        self._replay_memo = {}
        if self._window is None:
            self._window = _window_jit(self.model, False)
        logits, self.cache = self._window(params, self.cache,
                                          jnp.asarray(tokens))
        return logits

    def rollback(self, slot: int, n: int) -> None:
        """Rebuild the lane's state as of window position W - n by
        replaying the kept prefix on the stashed pre-window cache, then
        pasting that one lane into the live cache.  The replay runs the
        FULL multi-lane batch (a 1-lane replay could drift bitwise via
        batch-shape-dependent reduction order); a length-(W-n) scan of
        the same body is bitwise identical to the first W-n iterations
        of the length-W scan."""
        if n <= 0:
            return
        if self._stash is None:
            raise RuntimeError("rollback without a preceding verify_step")
        keep = self._stash_tokens.shape[1] - n
        if keep not in self._replay_memo:
            pre = jax.tree.map(jnp.asarray, self._stash)
            if keep <= 0:
                self._replay_memo[keep] = pre
            else:
                _, replayed = self._window(
                    self._stash_params, pre,
                    jnp.asarray(self._stash_tokens[:, :keep]))
                self._replay_memo[keep] = replayed
        lane = self._extract(self._replay_memo[keep], jnp.int32(slot))
        self.cache = self._paste(self.cache,
                                 jax.tree.map(np.asarray, lane),
                                 jnp.int32(0), jnp.int32(slot))

    def reset_lane(self, slot: int) -> None:
        if self._zero_lane is None:
            self._zero_lane = jax.tree.map(
                np.asarray, self.model.init_cache(1, self.max_len))
        self.cache = self._paste(self.cache, self._zero_lane,
                                 jnp.int32(0), jnp.int32(slot))

    def snapshot(self, slot: int) -> Any:
        snap = self._extract(self.cache, jnp.int32(slot))
        return jax.tree.map(np.asarray, snap)   # host-side, survives donation

    def restore(self, slot: int, snap: Any) -> bool:
        self.cache = self._paste(self.cache, snap, jnp.int32(0),
                                 jnp.int32(slot))
        return True


class PagedBackend(CacheBackend):
    """Block-pooled KV with refcounted copy-on-write prefix caching."""

    name = "paged"

    def __init__(self, model: Model, n_lanes: int, max_len: int,
                 kv_blocks: int, block_size: int,
                 watermark_frac: float = 0.0, prefix_cache: bool = False):
        super().__init__(model, n_lanes, max_len)
        ds = model.decode_state
        self.blocks = BlockManager(kv_blocks, block_size, watermark_frac)
        self.prefix_cache = prefix_cache
        self.max_blocks_per_lane = -(-max_len // block_size)
        self.cache = ds.pool_init(n_lanes, kv_blocks, block_size)
        self.block_tables = np.zeros(
            (n_lanes, self.max_blocks_per_lane), np.int32)
        self._lane_blocks: List[List[int]] = [[] for _ in range(n_lanes)]
        self._lane_pos = np.zeros((n_lanes,), np.int64)
        self._decode = _pool_step_jit(ds)
        self._pool_window = None     # built on first verify_step
        self._paste = _POOL_PASTE
        self._set_pos = _POOL_SET_POS
        self._cow_copy = _POOL_COW_COPY

    # -- gauges ---------------------------------------------------------
    @property
    def n_blocks(self) -> int:                           # type: ignore[override]
        return self.blocks.n_blocks

    @property
    def blocks_in_use(self) -> int:                      # type: ignore[override]
        return self.blocks.in_use

    @property
    def peak_blocks(self) -> int:                        # type: ignore[override]
        return self.blocks.peak_in_use

    @property
    def shared_blocks_peak(self) -> int:                 # type: ignore[override]
        return self.blocks.shared_peak

    @property
    def cow_splits(self) -> int:                         # type: ignore[override]
        return self.blocks.cow_splits

    @property
    def cache_evictions(self) -> int:                    # type: ignore[override]
        return self.blocks.evictions

    @property
    def state_version(self) -> int:                      # type: ignore[override]
        return self.blocks.version

    @property
    def budget_tokens(self) -> Optional[int]:
        bm = self.blocks
        return max(0, bm.free - bm.watermark_blocks) * bm.block_size

    @property
    def capacity_tokens(self) -> Optional[int]:
        bm = self.blocks
        return (bm.n_blocks - bm.watermark_blocks) * bm.block_size

    def reset_counters(self) -> None:
        bm = self.blocks
        bm.peak_in_use = bm.in_use
        bm.shared_peak = bm.shared_now
        bm.cow_splits = 0
        bm.evictions = 0

    def cached_prefix_tokens(self, tokens) -> int:
        if not self.prefix_cache or tokens is None:
            return 0
        return min(self.blocks.match_prefix(tokens).n_tokens, len(tokens))

    def forget_cache(self) -> int:
        return self.blocks.flush_cache()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def token_footprint(self, n_ctx: int, max_new: int,
                        tokens: Optional[Sequence[int]] = None) -> int:
        """Free-pool tokens this admission would consume NOW: blocks for
        the context, minus blocks already held live by other lanes (a
        refcount-zero cache hit still consumes a free block when revived,
        so only live-shared hits are discounted)."""
        bm = self.blocks
        need = bm.blocks_needed(n_ctx)
        if self.prefix_cache and tokens is not None:
            m = bm.match_prefix(tokens)
            need -= sum(1 for b in m.blocks if bm.ref_count(b) > 0)
        return need * bm.block_size

    def fits(self, n_ctx: int, final_len: int) -> bool:
        # feasibility is judged on the FINAL footprint: the context plus
        # every token the request may still generate.  A request admitted
        # on prompt size alone but over-budget at completion would die in
        # a preempt/reject loop; one past max_len could resume with more
        # context than the prefill cache span holds.  Blocks freed by
        # prefix sharing don't relax this bound: COW can re-privatise
        # every shared block before the request completes.
        bm = self.blocks
        usable = bm.n_blocks - bm.watermark_blocks
        return (final_len <= self.max_len
                and bm.blocks_needed(final_len) <= usable)

    def alloc(self, n_ctx: int, final_len: int,
              tokens: Optional[Sequence[int]] = None):
        bm = self.blocks
        if not self.fits(n_ctx, final_len):
            return INFEASIBLE
        hits: List[int] = []
        n_cached = n_lookup = 0
        if self.prefix_cache and tokens is not None:
            m = bm.match_prefix(tokens)
            hits, n_cached, n_lookup = list(m.blocks), m.n_tokens, n_ctx
        need = bm.blocks_needed(n_ctx)
        fresh_n = need - len(hits)
        revived = sum(1 for b in hits if bm.ref_count(b) == 0)
        # admission charges only blocks the free pool actually loses:
        # fresh allocations plus revived cache hits; live-shared blocks
        # ride along for free
        if not bm.can_admit(fresh_n + revived):
            return None
        for b in hits:
            bm.ref(b)        # BEFORE allocate(): hits must not be evicted
        fresh = bm.allocate(fresh_n) if fresh_n else []
        blocks = hits + fresh
        if self.prefix_cache and tokens is not None:
            # register the prompt's full blocks NOW (content arrives with
            # this round's paste, before any decode dispatch reads it) so
            # same-round admissions already share them
            bm.register(blocks, tokens)
        full_hit = bool(self.prefix_cache and tokens is not None
                        and n_cached >= n_ctx - 1)
        return Reservation(blocks=blocks, n_cached=n_cached,
                           n_lookup=n_lookup, full_hit=full_hit)

    def _flat_idx(self, blocks: List[int], n_cached: int, n_ctx: int,
                  width: int) -> np.ndarray:
        """Flattened pool slots for prefill positions 0..width-1: positions
        the lane must write go to its blocks; the pad tail AND the shared
        cached prefix (already holding identical K/V) go to the sink."""
        bs = self.blocks.block_size
        i = np.arange(width)
        phys = (i % bs).astype(np.int64)               # sink by default
        mine = (i >= n_cached) & (i < n_ctx)
        ids = np.asarray(blocks, np.int64)
        phys[mine] = ids[i[mine] // bs] * bs + i[mine] % bs
        return phys

    def prefill_paste(self, slot: int, group_cache, src_lane: int,
                      n_ctx: int, width: int, res: Reservation) -> None:
        flat = self._flat_idx(res.blocks, res.n_cached, n_ctx, width)
        self.cache = self._paste(self.cache, group_cache["layers"],
                                 jnp.int32(src_lane), jnp.asarray(flat),
                                 jnp.int32(slot), jnp.int32(n_ctx))
        self._install(slot, res.blocks, n_ctx)

    def activate(self, slot: int, res: Reservation, n_ctx: int) -> None:
        """Full hit: every context position short of the last is cached.
        The lane starts at pos = n_ctx - 1 and its first decode step feeds
        the last context token — no prefill dispatch at all."""
        self.cache = self._set_pos(self.cache, jnp.int32(slot),
                                   jnp.int32(n_ctx - 1))
        self._install(slot, res.blocks, n_ctx - 1)

    def _install(self, slot: int, blocks: List[int], pos: int) -> None:
        self._lane_blocks[slot] = list(blocks)
        self.block_tables[slot, :] = 0
        self.block_tables[slot, :len(blocks)] = blocks
        self._lane_pos[slot] = pos

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def prepare_lane(self, slot: int) -> bool:
        """Make the lane's next write position safe: grow into a fresh
        block on a boundary, COW-split a shared block on first write, or
        uncache a sole-holder cached block whose content will diverge.
        False = pool exhausted (the engine preempts and retries)."""
        bm = self.blocks
        bs = bm.block_size
        bidx = int(self._lane_pos[slot]) // bs
        if bidx >= self.max_blocks_per_lane:
            return True                  # saturated: dense-path clamp
        blocks = self._lane_blocks[slot]
        if bidx >= len(blocks):
            blk = bm.allocate_one()
            if blk is None:
                return False
            blocks.append(blk)
            self.block_tables[slot, bidx] = blk
            return True
        blk = blocks[bidx]
        if bm.ref_count(blk) > 1:
            fresh = bm.cow_split(blk)
            if fresh is None:
                return False
            self.cache = self._cow_copy(self.cache, jnp.int32(blk),
                                        jnp.int32(fresh))
            blocks[bidx] = fresh
            self.block_tables[slot, bidx] = fresh
        elif bm.is_cached(blk):
            bm.uncache(blk)              # sole holder: write in place
        return True

    def step(self, params, tokens: np.ndarray, active: np.ndarray):
        logits, self.cache = self._decode(params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(self.block_tables))
        self._lane_pos[active] += 1
        return logits

    # -- speculative verify plumbing -----------------------------------
    def append_tokens(self, slot: int, toks: Sequence[int]) -> bool:
        """Reserve write capacity for ``len(toks)`` consecutive positions:
        run the single-position ``prepare_lane`` (grow / COW-split /
        uncache) once per position, crossing block boundaries as needed.
        All-or-nothing: on exhaustion the position is restored and the
        engine preempts a victim and retries."""
        pos0 = int(self._lane_pos[slot])
        for i in range(len(toks)):
            self._lane_pos[slot] = pos0 + i
            if not self.prepare_lane(slot):
                self._lane_pos[slot] = pos0
                return False
        self._lane_pos[slot] = pos0
        return True

    def verify_step(self, params, tokens: np.ndarray, active: np.ndarray):
        if self._pool_window is None:
            self._pool_window = _pool_window_jit(self.model.decode_state)
        w = tokens.shape[1]
        logits, self.cache = self._pool_window(
            params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.block_tables))
        self._lane_pos[active] += w
        return logits

    def rollback(self, slot: int, n: int) -> None:
        """Truncate the lane's last ``n`` writes and free trailing blocks
        it no longer covers.  Safe by construction: a verify round always
        commits at least one token, so the post-rollback position sits
        strictly past the pre-round content — every freed block is a
        this-round private allocation (``append_tokens`` grows fresh or
        COW-private blocks), never a shared/cached prefix block."""
        if n <= 0:
            return
        bm = self.blocks
        new_pos = max(0, int(self._lane_pos[slot]) - n)
        self._lane_pos[slot] = new_pos
        self.cache = self._set_pos(self.cache, jnp.int32(slot),
                                   jnp.int32(new_pos))
        blocks = self._lane_blocks[slot]
        keep = bm.blocks_needed(new_pos)
        if len(blocks) > keep:
            tail = blocks[keep:]
            del blocks[keep:]
            bm.release(tail)
            self.block_tables[slot, keep:] = 0

    def reset_lane(self, slot: int) -> None:
        self.release(slot)
        self.cache = self._set_pos(self.cache, jnp.int32(slot),
                                   jnp.int32(0))

    def snapshot(self, slot: int) -> Optional[Any]:
        return None          # recompute policy (resume prefix-matches the
        #                      blocks registered at release, so the
        #                      re-prefill is usually a full hit anyway)

    def restore(self, slot: int, snap: Any) -> bool:
        return False

    def release(self, slot: int,
                tokens: Optional[Sequence[int]] = None) -> None:
        blocks = self._lane_blocks[slot]
        if blocks:
            if self.prefix_cache and tokens is not None:
                n_valid = min(int(self._lane_pos[slot]), len(tokens))
                self.blocks.register(blocks, tokens[:n_valid])
            self.blocks.release(blocks)
        self._lane_blocks[slot] = []
        self.block_tables[slot, :] = 0
        self._lane_pos[slot] = 0


def make_backend(model: Model, n_lanes: int, max_len: int,
                 config) -> CacheBackend:
    """Pick the backend for (model, engine config).

    ``config`` is the engine's :class:`~repro.serving.engine.EngineConfig`.
    ``config.backend`` forces a layout (``"dense" | "paged" | "recurrent"``);
    the default ``None`` auto-selects: a block pool wherever the family
    supports it and ``kv_blocks`` is set, pooled recurrent lanes for
    recurrent-state families, dense lanes otherwise.
    """
    ds = model.decode_state
    choice = config.backend
    if choice is None:
        if config.kv_blocks is not None and ds.poolable:
            choice = "paged"
        elif ds.kind == "recurrent":
            choice = "recurrent"
        else:
            choice = "dense"
    if choice == "paged":
        if not ds.poolable:
            raise ValueError(
                f"family {model.cfg.family!r} has no pool-layout decode "
                f"state; only attention-K/V families are pageable")
        if config.kv_blocks is None:
            raise ValueError("backend='paged' requires EngineConfig.kv_blocks")
        return PagedBackend(model, n_lanes, max_len,
                            kv_blocks=config.kv_blocks,
                            block_size=config.kv_block_size,
                            watermark_frac=config.watermark_frac,
                            prefix_cache=config.prefix_cache)
    if choice == "recurrent":
        if ds.kind != "recurrent":
            raise ValueError(
                f"backend='recurrent' on a {ds.kind!r}-state family")
        return RecurrentBackend(model, n_lanes, max_len)
    if choice == "dense":
        return DenseBackend(model, n_lanes, max_len)
    raise ValueError(f"unknown backend {choice!r}")
