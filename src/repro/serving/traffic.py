"""Traffic plane: trace-driven arrival generators + open-loop driving.

Open loop means arrivals never wait for the server — the standard way to
measure a serving system at a given offered load (benchmarks) or to demo
overload behaviour (examples).  Shared here so benches, examples and the
scale-plane simulator cannot drift apart on drive semantics.

Two halves:

* **Traces** — :class:`TrafficTrace` plus seeded generators for the arrival
  shapes production fleets actually see: stationary Poisson
  (:func:`poisson_trace`), diurnal load curves (:func:`diurnal_trace`, an
  inhomogeneous Poisson process sampled by thinning), bursty traffic
  (:func:`mmpp_trace`, a 2-state Markov-modulated Poisson process), and
  replayed logs (:func:`replay_trace`).  Everything is derived from a
  ``numpy`` Generator seeded explicitly, so the same seed yields the same
  trace bit-for-bit — fleet snapshots driven by a seeded trace are
  reproducible and can be asserted on in tests and CI.
* **Drivers** — :func:`drive_open_loop` submits a trace against a
  :class:`~repro.serving.engine.ServeEngine`, pacing by the **engine's own
  clock**: wall-clock engines nap between arrivals, sim-paced engines jump
  their :class:`SimClock` forward and never sleep.  (The fleet-level
  equivalent for tick-paced simulators is
  :func:`repro.serving.fleet.drive_sim`.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving.engine import ServeEngine


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """A deterministic arrival trace: per-request timing, sizing and class.

    Arrays are parallel, length ``n``; ``arrivals`` is seconds from trace
    start, sorted ascending.  ``classes`` indexes into whatever SLO-class
    table the consumer carries (see :class:`repro.serving.metrics.SLOClass`).
    """
    arrivals: np.ndarray       # float64 (n,) seconds, ascending
    prompt_lens: np.ndarray    # int64 (n,) prompt tokens
    max_news: np.ndarray       # int64 (n,) output-token budgets
    classes: np.ndarray        # int64 (n,) SLO-class ids
    kind: str = "replay"
    seed: Optional[int] = None

    def __post_init__(self):
        n = len(self.arrivals)
        if not (len(self.prompt_lens) == len(self.max_news)
                == len(self.classes) == n):
            raise ValueError("trace arrays must be parallel")
        if n and np.any(np.diff(self.arrivals) < 0):
            raise ValueError("arrivals must be sorted ascending")

    def __len__(self) -> int:
        return len(self.arrivals)

    @property
    def duration_s(self) -> float:
        return float(self.arrivals[-1]) if len(self) else 0.0

    @property
    def offered_rps(self) -> float:
        return len(self) / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def offered_tokens(self) -> int:
        return int(self.prompt_lens.sum() + self.max_news.sum())


def _sizes(rng: np.random.Generator, n: int, *,
           prompt_tokens=(8, 64), max_new_tokens=(8, 32),
           class_weights: Sequence[float] = (1.0,)):
    """Draw per-request sizes and SLO classes (uniform-int ranges, weighted
    class mix) from the trace's own rng stream."""
    plo, phi = prompt_tokens
    nlo, nhi = max_new_tokens
    prompts = rng.integers(plo, phi + 1, size=n, dtype=np.int64)
    max_news = rng.integers(nlo, nhi + 1, size=n, dtype=np.int64)
    w = np.asarray(class_weights, dtype=np.float64)
    classes = rng.choice(len(w), size=n, p=w / w.sum()).astype(np.int64)
    return prompts, max_news, classes


def poisson_trace(rate_rps: float, duration_s: float, *, seed: int = 0,
                  **size_kw) -> TrafficTrace:
    """Stationary Poisson arrivals at ``rate_rps`` for ``duration_s``."""
    rng = np.random.default_rng(seed)
    # exponential inter-arrivals, cumulated then truncated to the window
    n_max = max(16, int(rate_rps * duration_s * 2 + 64))
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-12), size=n_max)
    ts = np.cumsum(gaps)
    ts = ts[ts < duration_s]
    p, m, c = _sizes(rng, len(ts), **size_kw)
    return TrafficTrace(ts, p, m, c, kind="poisson", seed=seed)


def diurnal_trace(mean_rps: float, duration_s: float, *, period_s: float,
                  depth: float = 0.8, phase: float = -0.5 * np.pi,
                  seed: int = 0, **size_kw) -> TrafficTrace:
    """Diurnal load curve: inhomogeneous Poisson arrivals whose rate follows
    ``mean_rps * (1 + depth*sin(2*pi*t/period_s + phase))``, sampled exactly
    by thinning (Lewis & Shedler): draw candidates at the peak rate, keep
    each with probability ``rate(t)/peak``.  ``depth`` in [0, 1); the default
    phase starts the window at the trough so a bench sees a full ramp."""
    if not 0.0 <= depth < 1.0:
        raise ValueError("depth must be in [0, 1)")
    rng = np.random.default_rng(seed)
    peak = mean_rps * (1.0 + depth)
    n_max = max(16, int(peak * duration_s * 2 + 64))
    ts = np.cumsum(rng.exponential(1.0 / max(peak, 1e-12), size=n_max))
    ts = ts[ts < duration_s]
    rate = mean_rps * (1.0 + depth * np.sin(2 * np.pi * ts / period_s + phase))
    keep = rng.random(len(ts)) < rate / peak
    ts = ts[keep]
    p, m, c = _sizes(rng, len(ts), **size_kw)
    return TrafficTrace(ts, p, m, c, kind="diurnal", seed=seed)


def mmpp_trace(calm_rps: float, burst_rps: float, duration_s: float, *,
               calm_dwell_s: float = 30.0, burst_dwell_s: float = 5.0,
               seed: int = 0, **size_kw) -> TrafficTrace:
    """Bursty traffic: a 2-state Markov-modulated Poisson process.  The
    modulating chain dwells exponentially in a calm state (``calm_rps``)
    and a burst state (``burst_rps``); arrivals within each dwell are
    Poisson at that state's rate."""
    rng = np.random.default_rng(seed)
    ts_parts = []
    t, bursting = 0.0, False
    while t < duration_s:
        dwell = rng.exponential(burst_dwell_s if bursting else calm_dwell_s)
        end = min(t + dwell, duration_s)
        rate = burst_rps if bursting else calm_rps
        if rate > 0:
            n_max = max(4, int(rate * (end - t) * 2 + 16))
            seg = t + np.cumsum(rng.exponential(1.0 / rate, size=n_max))
            ts_parts.append(seg[seg < end])
        t, bursting = end, not bursting
    ts = (np.concatenate(ts_parts) if ts_parts
          else np.empty(0, dtype=np.float64))
    p, m, c = _sizes(rng, len(ts), **size_kw)
    return TrafficTrace(ts, p, m, c, kind="mmpp", seed=seed)


def replay_trace(arrivals: Sequence[float],
                 prompt_lens: Sequence[int],
                 max_news: Sequence[int],
                 classes: Optional[Sequence[int]] = None) -> TrafficTrace:
    """Wrap a recorded log (e.g. parsed production timestamps) as a trace."""
    a = np.asarray(arrivals, dtype=np.float64)
    order = np.argsort(a, kind="stable")
    c = (np.asarray(classes, dtype=np.int64) if classes is not None
         else np.zeros(len(a), dtype=np.int64))
    return TrafficTrace(a[order],
                        np.asarray(prompt_lens, dtype=np.int64)[order],
                        np.asarray(max_news, dtype=np.int64)[order],
                        c[order], kind="replay")


def merge_traces(*traces: TrafficTrace) -> TrafficTrace:
    """Superpose traces (e.g. a diurnal base + an MMPP burst overlay) into
    one time-sorted trace; class ids are preserved as-is."""
    if not traces:
        raise ValueError("need at least one trace")
    a = np.concatenate([t.arrivals for t in traces])
    order = np.argsort(a, kind="stable")
    return TrafficTrace(
        a[order],
        np.concatenate([t.prompt_lens for t in traces])[order],
        np.concatenate([t.max_news for t in traces])[order],
        np.concatenate([t.classes for t in traces])[order],
        kind="+".join(t.kind for t in traces))


# ---------------------------------------------------------------------------
# clocks + drivers
# ---------------------------------------------------------------------------
class SimClock:
    """A callable clock the driver can jump forward: pass as
    ``ServeEngine(..., clock=SimClock())`` and :func:`drive_open_loop`
    advances simulated time instead of sleeping wall time."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("cannot advance a clock backwards")
        self.t += dt


def drive_open_loop(engine: ServeEngine, arrival_times: Sequence[float],
                    submit: Callable[[int, float], None], *,
                    max_sleep_s: float = 0.01) -> float:
    """Run ``engine`` until every arrival is submitted and drained.

    ``arrival_times`` are seconds from start, sorted ascending;
    ``submit(i, now)`` is called when arrival ``i`` comes due (it decides
    prompt/params and calls ``engine.submit``).  Between due arrivals the
    engine decodes.

    Pacing follows the **engine's clock** (``engine.clock``): under the
    default wall clock an idle engine naps until the next arrival (bounded
    by ``max_sleep_s`` so admission stays responsive); under a sim-paced
    clock the driver jumps time forward to the next arrival and never
    sleeps — a sim-paced drive costs compute time only, regardless of the
    trace's simulated span.  Sim clocks must expose ``advance(dt)``
    (see :class:`SimClock`).  Returns elapsed seconds on the pacing clock.

    The legacy ``wall_clock=`` kwarg (deprecated in PR 7) is gone: pacing
    is always ``engine.clock``, which is the never-sleep invariant
    repro-lint R002 enforces statically.
    """
    clock: Callable[[], float] = engine.clock
    simulated = clock is not time.perf_counter
    t0 = clock()
    n, nxt = len(arrival_times), 0
    while nxt < n or engine.active() or engine.scheduler.depth:
        now = clock() - t0
        while nxt < n and arrival_times[nxt] <= now:
            submit(nxt, now)
            nxt += 1
        if not engine.step() and nxt < n:
            wait = arrival_times[nxt] - (clock() - t0)
            if wait <= 0:
                continue
            if simulated:
                advance = getattr(clock, "advance", None)
                if advance is None:
                    raise TypeError(
                        "engine.clock is sim-paced but has no advance(); "
                        "use repro.serving.traffic.SimClock (or drive a "
                        "tick-paced fleet with repro.serving.fleet.drive_sim)")
                advance(wait)
            else:
                # the ONE legitimate nap: a wall-clock engine idling until
                # its next arrival really does wait in real time
                time.sleep(min(wait, max_sleep_s))  # repro-lint: allow[R002] wall-clock engines nap for real; sim clocks take the advance() branch above
    return clock() - t0


def drive_trace(engine: ServeEngine, trace: TrafficTrace,
                submit: Callable[[int, float], None], *,
                max_sleep_s: float = 0.01) -> float:
    """Drive a :class:`TrafficTrace` open-loop: thin sugar over
    :func:`drive_open_loop` for callers that already hold a trace."""
    return drive_open_loop(engine, trace.arrivals, submit,
                           max_sleep_s=max_sleep_s)
