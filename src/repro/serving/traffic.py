"""Open-loop traffic driving: submit requests at fixed arrival times while
continuously stepping the engine.

Open loop means arrivals never wait for the server — the standard way to
measure a serving system at a given offered load (benchmarks) or to demo
overload behaviour (examples).  Shared here so the bench and the demo
cannot drift apart on drive semantics.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

from repro.serving.engine import ServeEngine


def drive_open_loop(engine: ServeEngine, arrival_times: Sequence[float],
                    submit: Callable[[int, float], None], *,
                    max_sleep_s: float = 0.01) -> float:
    """Run ``engine`` until every arrival is submitted and drained.

    ``arrival_times`` are seconds from start, sorted ascending;
    ``submit(i, now)`` is called when arrival ``i`` comes due (it decides
    prompt/params and calls ``engine.submit``).  Between due arrivals the
    engine decodes; when idle it naps until the next arrival (bounded by
    ``max_sleep_s`` so admission stays responsive).  Returns wall seconds.
    """
    t0 = time.perf_counter()
    n, nxt = len(arrival_times), 0
    while nxt < n or engine.active() or engine.scheduler.depth:
        now = time.perf_counter() - t0
        while nxt < n and arrival_times[nxt] <= now:
            submit(nxt, now)
            nxt += 1
        if not engine.step() and nxt < n:
            wait = arrival_times[nxt] - (time.perf_counter() - t0)
            time.sleep(min(max(wait, 0.0), max_sleep_s))
    return time.perf_counter() - t0
