"""Token sampling for the serving engine.

Per-request :class:`SamplingParams` are flattened into per-lane arrays
(temperature / top-k / top-p / PRNG key) so one jitted :func:`sample_tokens`
call serves every active lane of the continuous batch at once — greedy lanes
and stochastic lanes coexist in the same dispatch.

Semantics (matching the usual serving conventions):

* ``temperature <= 0``  -> greedy argmax; the PRNG is not consumed.
* ``top_k > 0``         -> restrict to the k highest logits.
* ``top_p < 1``         -> restrict to the smallest prefix of the
  probability-sorted vocab whose cumulative mass reaches ``top_p``
  (the nucleus; the boundary token is always kept).
* filters compose: top-k first, then top-p over the RENORMALIZED
  survivor distribution (HF-style).

Each lane owns an independent counter-mode PRNG stream derived from the
request's ``seed``, so decode order / lane placement / batch composition
never change a request's sampled tokens.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy.  Defaults reproduce the old greedy engine."""
    temperature: float = 0.0
    top_k: int = 0                 # 0 = disabled
    top_p: float = 1.0             # 1 = disabled
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass
class LaneSampling:
    """SoA view of the sampling state of every lane (host side).

    The engine owns one of these sized ``max_batch``; admission writes a
    request's params into its lane, and every decode step ships the arrays
    to :func:`sample_tokens` and writes back the advanced PRNG counters.
    """
    temperature: np.ndarray        # (B,) float32
    top_k: np.ndarray              # (B,) int32
    top_p: np.ndarray              # (B,) float32
    key: np.ndarray                # (B, 2) uint32 (jax threefry key data)

    @classmethod
    def empty(cls, n_lanes: int) -> "LaneSampling":
        # key width depends on the active PRNG impl (threefry: 2 uint32,
        # rbg: 4) — ask jax rather than hardcoding
        kd = jax.random.key_data(jax.random.key(0))
        return cls(
            temperature=np.zeros((n_lanes,), np.float32),
            top_k=np.zeros((n_lanes,), np.int32),
            top_p=np.ones((n_lanes,), np.float32),
            key=np.zeros((n_lanes,) + kd.shape, kd.dtype),
        )

    def set_lane(self, lane: int, params: SamplingParams) -> None:
        self.temperature[lane] = params.temperature
        self.top_k[lane] = params.top_k
        self.top_p[lane] = params.top_p
        self.key[lane] = jax.random.key_data(jax.random.key(params.seed))

    def clear_lane(self, lane: int) -> None:
        self.set_lane(lane, GREEDY)


def _filter_one(logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
                top_p: jax.Array) -> jax.Array:
    """Temperature-scale then top-k/top-p mask one lane's logits (V,)."""
    v = logits.shape[-1]
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    order = jnp.sort(scaled)[::-1]                       # descending
    # top-k threshold: value of the k-th largest logit (k==0 -> whole vocab)
    k = jnp.where(top_k > 0, top_k, v)
    in_topk = jnp.arange(v) < k
    kth = order[jnp.clip(k - 1, 0, v - 1)]
    # top-p over the RENORMALIZED top-k survivors: keep entries whose
    # *preceding* cumulative survivor mass is < top_p (boundary included)
    probs = jax.nn.softmax(jnp.where(in_topk, order, NEG_INF))
    prior_mass = jnp.cumsum(probs) - probs
    in_nucleus = in_topk & (prior_mass < top_p)
    pth = jnp.min(jnp.where(in_nucleus, order, jnp.inf))
    cut = jnp.maximum(kth, pth)
    return jnp.where(scaled < cut, NEG_INF, scaled)


def _sample_tokens(logits: jax.Array, temperature: jax.Array,
                   top_k: jax.Array, top_p: jax.Array, key_data: jax.Array):
    """Sample one token per lane.

    logits (B, V) float; temperature (B,), top_k (B,), top_p (B,),
    key_data (B, 2) uint32.  Returns (tokens (B,) int32, new key_data).
    """
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(l, t, k, p, kd):
        kk = jax.random.wrap_key_data(kd)
        kk, sub = jax.random.split(kk)
        tok = jax.random.categorical(sub, _filter_one(l, t, k, p))
        return tok.astype(jnp.int32), jax.random.key_data(kk)

    samp_tok, new_kd = jax.vmap(one)(logits, temperature, top_k, top_p,
                                     key_data)
    is_greedy = temperature <= 0.0
    tokens = jnp.where(is_greedy, greedy_tok, samp_tok)
    # greedy lanes leave their stream untouched (reproducible mid-flight
    # policy switches, and admission of a fresh request into a reused lane)
    new_kd = jnp.where(is_greedy[:, None], key_data, new_kd)
    return tokens, new_kd


sample_tokens = jax.jit(_sample_tokens)


def _sample_tokens_masked(logits, temperature, top_k, top_p, key_data, mask):
    """:func:`_sample_tokens` with a lane mask: masked-out lanes keep their
    PRNG stream untouched (their returned token is garbage).  The
    speculative accept loop needs this — a lane that already ended its
    round must not consume key splits for window positions it never
    reaches, or its stream would diverge from the baseline engine's."""
    tokens, new_kd = _sample_tokens(logits, temperature, top_k, top_p,
                                    key_data)
    new_kd = jnp.where(mask[:, None], new_kd, key_data)
    return tokens, new_kd


sample_tokens_masked = jax.jit(_sample_tokens_masked)


def resolve_sampling(sampling: Optional[SamplingParams],
                     extra: dict) -> Optional[SamplingParams]:
    """Resolve an engine ``submit``'s decode policy.

    ``SamplingParams`` is the single supported argument; the loose
    ``temperature=`` / ``top_k=`` / ``top_p=`` / ``seed=`` kwargs of the
    pre-Sampler API are kept as a DEPRECATED shim — popped out of
    ``extra`` (mutating it, so leftovers keep their existing meaning) and
    folded into an equivalent ``SamplingParams``.  Mixing both is an
    error rather than a silent precedence rule.
    """
    legacy = {k: extra.pop(k) for k in ("temperature", "top_k", "top_p",
                                        "seed") if k in extra}
    if not legacy:
        return sampling
    if sampling is not None:
        raise TypeError(
            f"pass decode policy either as sampling=SamplingParams(...) or "
            f"as legacy kwargs, not both (got sampling= and {sorted(legacy)})")
    warnings.warn(
        "loose temperature/top_k/top_p/seed kwargs are deprecated; pass "
        "sampling=SamplingParams(...)", DeprecationWarning, stacklevel=3)
    return SamplingParams(**legacy)


class Sampler:
    """Owns the per-lane filter + PRNG state and both sampling entry
    points — the plain engine's one-token :meth:`sample` and the
    speculative engine's window :meth:`accept` share this object, so the
    speculative path cannot drift from the baseline discipline.

    The state is the same :class:`LaneSampling` SoA the engine always
    kept (exposed as ``.lanes`` — engine/fleet code that snapshots a
    lane's key for preemption keeps working on the arrays in place).
    """

    def __init__(self, n_lanes: int):
        self.n_lanes = n_lanes
        self.lanes = LaneSampling.empty(n_lanes)

    # -- lane state ----------------------------------------------------
    def set_lane(self, lane: int, params: SamplingParams) -> None:
        self.lanes.set_lane(lane, params)

    def clear_lane(self, lane: int) -> None:
        self.lanes.clear_lane(lane)

    def copy_state_from(self, other: "Sampler") -> None:
        """Adopt ``other``'s full lane state (filters + PRNG counters) —
        the draft sampler mirrors the target sampler at the start of
        every speculative round, so a perfectly-aligned draft model
        proposes exactly what the target would sample."""
        np.copyto(self.lanes.temperature, other.lanes.temperature)
        np.copyto(self.lanes.top_k, other.lanes.top_k)
        np.copyto(self.lanes.top_p, other.lanes.top_p)
        np.copyto(self.lanes.key, other.lanes.key)

    # -- sampling ------------------------------------------------------
    def sample(self, logits, lanes: Optional[Sequence[int]] = None,
               mask: Optional[np.ndarray] = None) -> np.ndarray:
        """Sample one token per row of ``logits`` and advance the rows'
        PRNG streams in place.  ``lanes`` maps rows to lane indices
        (default: row i is lane i); ``mask`` freezes masked-out lanes'
        streams (their tokens are garbage)."""
        ls = self.lanes
        idx = (np.arange(logits.shape[0]) if lanes is None
               else np.asarray(lanes))
        args = (jnp.asarray(ls.temperature[idx]), jnp.asarray(ls.top_k[idx]),
                jnp.asarray(ls.top_p[idx]), jnp.asarray(ls.key[idx]))
        if mask is None:
            toks, new_kd = sample_tokens(jnp.asarray(logits), *args)
        else:
            toks, new_kd = sample_tokens_masked(
                jnp.asarray(logits), *args, jnp.asarray(mask))
        ls.key[idx] = np.asarray(new_kd)
        return np.asarray(toks)

    def accept(self, window_logits, drafted: np.ndarray,
               active: np.ndarray, limit: Sequence[int],
               eos_id: Optional[int] = None
               ) -> Tuple[List[List[int]], np.ndarray, np.ndarray]:
        """Coupled acceptance over one verify window.

        ``window_logits`` (B, W, V) are the target's logits after each of
        the W = k + 1 window tokens; ``drafted`` (B, k) the draft's
        proposals; ``active`` (B,) which lanes ran the round; ``limit``
        (B,) tokens each lane may still emit; ``eos_id`` ends a lane.

        Position j's logits are sampled from the TARGET's filtered
        distribution via the lane's frozen stream — exactly the token the
        baseline engine would emit next — and the lane continues past j
        iff that token equals ``drafted[:, j]``.  The draft therefore
        only ever controls how FAR a round reaches, never what is
        emitted: the output stream is bit-for-bit the baseline stream
        for greedy AND stochastic targets, and each lane consumes
        exactly one key split per emitted token (masked sampling), so
        preempt/resume identity is preserved mid-round.

        Returns (per-lane emitted tokens, n_emitted (B,), n_accepted
        (B,) drafted tokens matched).  With k = 1 and an always-ending
        first position this reduces to the baseline sampler exactly.
        """
        b, w, _ = np.asarray(window_logits).shape
        k = w - 1
        alive = np.asarray(active, bool).copy()
        emitted: List[List[int]] = [[] for _ in range(b)]
        n_acc = np.zeros(b, np.int64)
        limit = np.asarray(limit)
        for j in range(w):
            if not alive.any():
                break
            toks = self.sample(window_logits[:, j], mask=alive)
            for i in range(b):
                if not alive[i]:
                    continue
                t = int(toks[i])
                emitted[i].append(t)
                done = (len(emitted[i]) >= limit[i]
                        or (eos_id is not None and t == eos_id))
                # a drafted token the target also sampled is ACCEPTED even
                # when the lane ends here (limit/eos) — done controls
                # continuation, not the proposal's correctness
                if j < k and t == int(drafted[i, j]):
                    n_acc[i] += 1
                if j == k or done or t != int(drafted[i, j]):
                    alive[i] = False
        n_emitted = np.array([len(e) for e in emitted], np.int64)
        return emitted, n_emitted, n_acc
