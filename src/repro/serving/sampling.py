"""Token sampling for the serving engine.

Per-request :class:`SamplingParams` are flattened into per-lane arrays
(temperature / top-k / top-p / PRNG key) so one jitted :func:`sample_tokens`
call serves every active lane of the continuous batch at once — greedy lanes
and stochastic lanes coexist in the same dispatch.

Semantics (matching the usual serving conventions):

* ``temperature <= 0``  -> greedy argmax; the PRNG is not consumed.
* ``top_k > 0``         -> restrict to the k highest logits.
* ``top_p < 1``         -> restrict to the smallest prefix of the
  probability-sorted vocab whose cumulative mass reaches ``top_p``
  (the nucleus; the boundary token is always kept).
* filters compose: top-k first, then top-p over the RENORMALIZED
  survivor distribution (HF-style).

Each lane owns an independent counter-mode PRNG stream derived from the
request's ``seed``, so decode order / lane placement / batch composition
never change a request's sampled tokens.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy.  Defaults reproduce the old greedy engine."""
    temperature: float = 0.0
    top_k: int = 0                 # 0 = disabled
    top_p: float = 1.0             # 1 = disabled
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass
class LaneSampling:
    """SoA view of the sampling state of every lane (host side).

    The engine owns one of these sized ``max_batch``; admission writes a
    request's params into its lane, and every decode step ships the arrays
    to :func:`sample_tokens` and writes back the advanced PRNG counters.
    """
    temperature: np.ndarray        # (B,) float32
    top_k: np.ndarray              # (B,) int32
    top_p: np.ndarray              # (B,) float32
    key: np.ndarray                # (B, 2) uint32 (jax threefry key data)

    @classmethod
    def empty(cls, n_lanes: int) -> "LaneSampling":
        # key width depends on the active PRNG impl (threefry: 2 uint32,
        # rbg: 4) — ask jax rather than hardcoding
        kd = jax.random.key_data(jax.random.key(0))
        return cls(
            temperature=np.zeros((n_lanes,), np.float32),
            top_k=np.zeros((n_lanes,), np.int32),
            top_p=np.ones((n_lanes,), np.float32),
            key=np.zeros((n_lanes,) + kd.shape, kd.dtype),
        )

    def set_lane(self, lane: int, params: SamplingParams) -> None:
        self.temperature[lane] = params.temperature
        self.top_k[lane] = params.top_k
        self.top_p[lane] = params.top_p
        self.key[lane] = jax.random.key_data(jax.random.key(params.seed))

    def clear_lane(self, lane: int) -> None:
        self.set_lane(lane, GREEDY)


def _filter_one(logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
                top_p: jax.Array) -> jax.Array:
    """Temperature-scale then top-k/top-p mask one lane's logits (V,)."""
    v = logits.shape[-1]
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    order = jnp.sort(scaled)[::-1]                       # descending
    # top-k threshold: value of the k-th largest logit (k==0 -> whole vocab)
    k = jnp.where(top_k > 0, top_k, v)
    in_topk = jnp.arange(v) < k
    kth = order[jnp.clip(k - 1, 0, v - 1)]
    # top-p over the RENORMALIZED top-k survivors: keep entries whose
    # *preceding* cumulative survivor mass is < top_p (boundary included)
    probs = jax.nn.softmax(jnp.where(in_topk, order, NEG_INF))
    prior_mass = jnp.cumsum(probs) - probs
    in_nucleus = in_topk & (prior_mass < top_p)
    pth = jnp.min(jnp.where(in_nucleus, order, jnp.inf))
    cut = jnp.maximum(kth, pth)
    return jnp.where(scaled < cut, NEG_INF, scaled)


def _sample_tokens(logits: jax.Array, temperature: jax.Array,
                   top_k: jax.Array, top_p: jax.Array, key_data: jax.Array):
    """Sample one token per lane.

    logits (B, V) float; temperature (B,), top_k (B,), top_p (B,),
    key_data (B, 2) uint32.  Returns (tokens (B,) int32, new key_data).
    """
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(l, t, k, p, kd):
        kk = jax.random.wrap_key_data(kd)
        kk, sub = jax.random.split(kk)
        tok = jax.random.categorical(sub, _filter_one(l, t, k, p))
        return tok.astype(jnp.int32), jax.random.key_data(kk)

    samp_tok, new_kd = jax.vmap(one)(logits, temperature, top_k, top_p,
                                     key_data)
    is_greedy = temperature <= 0.0
    tokens = jnp.where(is_greedy, greedy_tok, samp_tok)
    # greedy lanes leave their stream untouched (reproducible mid-flight
    # policy switches, and admission of a fresh request into a reused lane)
    new_kd = jnp.where(is_greedy[:, None], key_data, new_kd)
    return tokens, new_kd


sample_tokens = jax.jit(_sample_tokens)
